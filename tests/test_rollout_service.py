"""Rollout-as-a-Service tier (ROADMAP item 1): multi-tenant admission,
stride-weighted QoS, streaming token delivery, and lifecycle safety.

Streaming-ordering coverage (the satellite contract): per-job token
streams must be monotonic and gap-free — ``TokenStream.tokens_for``
asserts chunk tiling internally — across

- plain single-engine generation,
- a PD prefill->decode engine handoff,
- a suspend -> update_all -> resume weight-sync barrier mid-stream,
- an abort mid-stream, and
- an injected engine kill + supervised FT recovery (a second, streamed
  tenant riding on the trainer's service).

Plus: stride shares track configured weights under overload, full queues
reject at submit (backpressure), and ``LiveRLRunner.close`` is idempotent
and exception-safe (double-close, close-after-crash).
"""
import inspect
import time

import jax
import pytest

from repro.configs import get_config
from repro.core import (EngineHandle, LiveRLRunner, LLMProxy, RunnerConfig,
                        ServerlessPlatform, build_pd_proxy)
from repro.core.envmanager import EMState, RolloutPolicy
from repro.envs import make_env
from repro.ft import FTConfig, FTSupervisor, FailureInjector
from repro.models import Model
from repro.rewards.rule_based import REWARD_FNS
from repro.rl.engine import GenRequest, InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)
from repro.serve import JobState, RolloutJob, RolloutService, TokenStream


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _make_service(model, params, *, max_slots=4, max_len=128, seed=3):
    eng = InferenceEngine(model, params, max_slots=max_slots,
                          max_len=max_len, seed=seed)
    return RolloutService(LLMProxy([EngineHandle(eng, "H20")]))


def _tick_until(svc, pred, limit=3000):
    for _ in range(limit):
        if pred():
            return
        svc.tick()
    raise AssertionError("condition not reached within tick limit")


def _assert_stream_matches(ticket):
    """The stream must reproduce the job's final result exactly, with
    gap-free chunk tiling (tokens_for asserts contiguity)."""
    [res] = ticket.results
    rid = f"{ticket.job_id}.r0"
    assert ticket.stream.tokens_for(rid) == res.tokens
    assert ticket.stream.result_tokens(timeout=1) == res.tokens
    lp = [p for c in ticket.stream.chunks() for p in c.logprobs]
    assert lp == res.logprobs


# ---------------------------------------------------------------------------
# TokenStream: idempotent cumulative delivery
# ---------------------------------------------------------------------------
def test_token_stream_idempotent_and_gap_free():
    st = TokenStream("j0")
    assert st.push("r", [1, 2, 3], [-0.1, -0.2, -0.3]) == 3
    assert st.push("r", [1, 2, 3], [-0.1, -0.2, -0.3]) == 0   # replay
    assert st.push("r", [1, 2], [-0.1, -0.2]) == 0            # shorter
    assert st.push("r", [1, 2, 3, 4, 5], [-0.1, -0.2, -0.3, -0.4, -0.5]) == 2
    assert st.tokens_for("r") == [1, 2, 3, 4, 5]
    assert st.token_count() == 5
    starts = [c.start for c in st.chunks()]
    ends = [c.end for c in st.chunks()]
    assert starts == [0, 3] and ends == [3, 5]
    st.close("stop")
    st.close("aborted")                   # idempotent: first close wins
    assert st.closed and st.finish_reason == "stop"
    assert st.push("r", list(range(9)), [0.0] * 9) == 0   # closed: no-op
    assert st.result_tokens(timeout=1) == [1, 2, 3, 4, 5]


def test_token_stream_multiplexes_request_ids():
    st = TokenStream("j1")
    st.push("a", [1, 2], [0.0, 0.0])
    st.push("b", [7], [0.0])
    st.push("a", [1, 2, 3], [0.0, 0.0, 0.0])
    assert st.tokens_for("a") == [1, 2, 3]
    assert st.tokens_for("b") == [7]
    assert st.token_count() == 4


# ---------------------------------------------------------------------------
# streaming delivery against live engines
# ---------------------------------------------------------------------------
def test_prompt_job_streams_incrementally(tiny_setup):
    cfg, model, params = tiny_setup
    with _make_service(model, params) as svc:
        svc.register_tenant("cli")
        job = RolloutJob(kind="prompt", prompt=[1, 5, 7, 9],
                         max_new_tokens=24, temperature=0.0,
                         stop_tokens=())
        ticket = svc.submit("cli", job)
        assert ticket.state == JobState.QUEUED
        _tick_until(svc, lambda: ticket.done)
        assert ticket.state == JobState.DONE
        assert ticket.stream.closed and ticket.stream.finish_reason == "stop"
        _assert_stream_matches(ticket)
        # genuinely incremental: tokens arrived across several deliveries,
        # starting before the job finished
        assert len(ticket.stream.chunks()) >= 2
        assert ticket.stream.first_token_t < ticket.t_done
        assert svc.tenant("cli").stats["stream_tokens"] == \
            len(ticket.results[0].tokens)


def test_stream_across_pd_engine_handoff(tiny_setup):
    cfg, model, params = tiny_setup
    proxy = build_pd_proxy(model, params, max_slots=4, max_len=96, seed=7)
    with RolloutService(proxy) as svc:
        svc.register_tenant("cli")
        tickets = [svc.submit("cli", RolloutJob(
            kind="prompt", prompt=[1, 5, 7, 9 + i], max_new_tokens=20,
            temperature=0.0, stop_tokens=())) for i in range(3)]
        _tick_until(svc, lambda: all(t.done for t in tickets))
        assert proxy.handoffs >= 3, "prefill->decode handoff not exercised"
        for t in tickets:
            assert t.state == JobState.DONE
            _assert_stream_matches(t)


def test_stream_across_weight_sync_barrier(tiny_setup):
    cfg, model, params = tiny_setup
    with _make_service(model, params) as svc:
        svc.register_tenant("cli")
        ticket = svc.submit("cli", RolloutJob(
            kind="prompt", prompt=[1, 5, 7, 9], max_new_tokens=32,
            temperature=0.0, stop_tokens=()))
        for _ in range(3):
            svc.tick()                        # mid-stream
        n_before = ticket.stream.token_count()
        assert 0 < n_before < 32
        with svc.barrier():                   # suspend -> update -> resume
            svc.proxy.suspend()
            svc.proxy.update_all(params, version=1)   # re-prefills the
            svc.proxy.resume()                # in-flight slot (replays its
            #                                   cumulative token list)
        _tick_until(svc, lambda: ticket.done)
        assert ticket.state == JobState.DONE
        # the re-prefill replay collapsed into a no-op: no duplicates, no
        # gaps, and the stream still equals the final result exactly
        _assert_stream_matches(ticket)
        assert len(ticket.results[0].tokens) >= n_before


def test_abort_mid_stream_closes_aborted(tiny_setup):
    cfg, model, params = tiny_setup
    with _make_service(model, params) as svc:
        svc.register_tenant("cli")
        ticket = svc.submit("cli", RolloutJob(
            kind="prompt", prompt=[1, 5, 7, 9], max_new_tokens=64,
            temperature=0.0, stop_tokens=()))
        for _ in range(2):
            svc.tick()
        assert ticket.state == JobState.RUNNING
        assert ticket.stream.token_count() > 0
        svc.abort_job(ticket)
        _tick_until(svc, lambda: ticket.done)
        assert ticket.state == JobState.ABORTED
        assert ticket.stream.closed
        assert ticket.stream.finish_reason == JobState.ABORTED
        # the delivered prefix is exactly what the engine generated before
        # the cancel landed — gap-free, nothing fabricated after close
        [res] = ticket.results
        assert res.finish_reason == "aborted"
        assert ticket.stream.tokens_for(f"{ticket.job_id}.r0") == res.tokens
        assert 0 < len(res.tokens) < 64
        assert svc.tenant("cli").stats["aborted"] == 1


def test_abort_queued_job_never_launches(tiny_setup):
    cfg, model, params = tiny_setup
    with _make_service(model, params) as svc:
        svc.register_tenant("cli")
        ticket = svc.submit("cli", RolloutJob(kind="prompt", prompt=[1]))
        svc.abort_job(ticket)
        assert ticket.state == JobState.ABORTED and ticket.done
        svc.tick()
        assert svc.tenant("cli").stats["admitted"] == 0


# ---------------------------------------------------------------------------
# admission control: backpressure + stride-weighted QoS
# ---------------------------------------------------------------------------
def test_queue_backpressure_rejects_at_submit(tiny_setup):
    cfg, model, params = tiny_setup
    with _make_service(model, params) as svc:
        svc.register_tenant("cli", max_queue=2)
        t1 = svc.submit("cli", RolloutJob(kind="prompt", prompt=[1]))
        t2 = svc.submit("cli", RolloutJob(kind="prompt", prompt=[1]))
        t3 = svc.submit("cli", RolloutJob(kind="prompt", prompt=[1]))
        assert (t1.state, t2.state) == (JobState.QUEUED, JobState.QUEUED)
        assert t3.state == JobState.REJECTED and t3.done
        assert t3.stream.closed
        assert t3.stream.finish_reason == JobState.REJECTED
        assert svc.tenant("cli").stats["rejected"] == 1


def test_max_inflight_caps_admission(tiny_setup):
    cfg, model, params = tiny_setup
    with _make_service(model, params) as svc:
        svc.register_tenant("cli", max_inflight=2)
        tickets = [svc.submit("cli", RolloutJob(
            kind="prompt", prompt=[1, 5], max_new_tokens=16,
            temperature=0.0, stop_tokens=())) for _ in range(5)]
        svc.admit()
        states = [t.state for t in tickets]
        assert states.count(JobState.RUNNING) == 2
        assert states.count(JobState.QUEUED) == 3
        _tick_until(svc, lambda: all(t.done for t in tickets))
        assert all(t.state == JobState.DONE for t in tickets)


def test_global_admission_window_gates_on_stride(tiny_setup):
    """With a service-wide in-flight cap, overload queues at the service
    and the window's slots split by weight."""
    cfg, model, params = tiny_setup
    with _make_service(model, params) as svc:
        svc.max_inflight = 4
        svc.register_tenant("heavy", weight=3.0)
        svc.register_tenant("light", weight=1.0)
        mk = lambda: RolloutJob(kind="prompt", prompt=[1, 5],
                                max_new_tokens=8, temperature=0.0,
                                stop_tokens=())
        hv = [svc.submit("heavy", mk()) for _ in range(8)]
        lt = [svc.submit("light", mk()) for _ in range(8)]
        svc.admit()
        assert sum(t.state == JobState.RUNNING for t in hv + lt) == 4
        # the first window fills in stride order: h(1/3) l(1) h(2/3) h(1)
        assert sum(t.state == JobState.RUNNING for t in hv) == 3
        assert sum(t.state == JobState.RUNNING for t in lt) == 1
        _tick_until(svc, lambda: all(t.done for t in hv + lt))
        assert all(t.state == JobState.DONE for t in hv + lt)


def test_stride_shares_track_weights_under_overload(tiny_setup):
    """Two tenants saturate one small engine; admission order (and hence
    service order — the engine admits FIFO) must interleave 3:1."""
    cfg, model, params = tiny_setup
    with _make_service(model, params, max_slots=2) as svc:
        svc.register_tenant("heavy", weight=3.0)
        svc.register_tenant("light", weight=1.0)
        mk = lambda: RolloutJob(kind="prompt", prompt=[1, 5, 7],
                                max_new_tokens=8, temperature=0.0,
                                stop_tokens=())
        heavy = [svc.submit("heavy", mk()) for _ in range(24)]
        light = [svc.submit("light", mk()) for _ in range(24)]
        done = lambda: sum(t.done for t in heavy + light)
        _tick_until(svc, lambda: done() >= 16)
        first = sorted((t for t in heavy + light if t.done),
                       key=lambda t: t.t_done)[:16]
        n_heavy = sum(t.tenant == "heavy" for t in first)
        # stride order is exact; completion order can wobble by one
        # engine batch (max_slots) around it
        assert n_heavy >= 10, f"heavy got {n_heavy}/16 under a 3:1 weight"
        assert 16 - n_heavy >= 2, "light starved outright"
        _tick_until(svc, lambda: all(t.done for t in heavy + light))
        st = svc.stats()
        assert st["heavy"]["completed"] == 24
        assert st["light"]["completed"] == 24
        # stride bookkeeping: equal admissions cost light 3x the vtime
        assert st["light"]["vtime"] == pytest.approx(
            3 * st["heavy"]["vtime"])


def test_newcomer_tenant_gets_no_retroactive_burst(tiny_setup):
    cfg, model, params = tiny_setup
    with _make_service(model, params) as svc:
        a = svc.register_tenant("a")
        a.vtime = 7.0                      # a has been admitted for a while
        b = svc.register_tenant("b")
        assert b.vtime == 7.0              # joins at the live max


# ---------------------------------------------------------------------------
# the trainer is tenant #0: no private dispatch path remains
# ---------------------------------------------------------------------------
def test_runner_has_no_direct_pump_call():
    import ast

    import repro.core.scheduler as sched
    tree = ast.parse(inspect.getsource(sched))
    pumps = [n for n in ast.walk(tree)
             if isinstance(n, ast.Call)
             and isinstance(n.func, ast.Attribute)
             and n.func.attr == "pump"]
    assert not pumps, \
        "LiveRLRunner must reach the engines through RolloutService only"


def _make_runner(state, mode="sync", tasks=("game",), max_new=16,
                 max_len=320):
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    opt = default_optimizer(1e-3)
    eng = InferenceEngine(model, state.params, max_slots=8,
                          max_len=max_len, seed=3)
    proxy = LLMProxy([EngineHandle(eng, "local")])
    return LiveRLRunner(
        RunnerConfig(batch_size=4, group_size=2, alpha=2, mode=mode,
                     tasks=tasks, max_new_tokens=max_new, temperature=0.0),
        proxy, state, jax.jit(make_grpo_train_step(model, opt)),
        ServerlessPlatform(), REWARD_FNS["format_bonus"], seq_len=max_len)


def _fresh_state():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    return init_train_state(model, jax.random.PRNGKey(0),
                            default_optimizer(1e-3))


@pytest.mark.slow
def test_second_tenant_rides_trainer_service():
    """An external client streams an env-group job through the SAME
    service the trainer trains through — and the trainer still trains."""
    runner = _make_runner(_fresh_state())
    try:
        svc = runner.service
        sink = []
        svc.register_tenant("client", tokenizer=runner.tok,
                            sink=sink.append, weight=1.0)
        job = RolloutJob(
            kind="env", tag="game",
            envs=[make_env("game", seed=91), make_env("game", seed=92)],
            seeds=[91, 92],
            policy=RolloutPolicy(max_new_tokens=12, temperature=0.0),
            stream=True)
        ticket = svc.submit("client", job)
        runner.run_steps(2)                     # trainer makes progress
        _tick_until(svc, lambda: ticket.done)
        assert ticket.state == JobState.DONE
        assert len(runner.history) == 2
        assert len(sink) == 2                   # both trajectories scored
        assert all(t.meta["state"] == "DONE" for t in sink)
        # streamed tokens tile gap-free for every request (turn) the
        # job's managers issued
        for rid in {c.request_id for c in ticket.stream.chunks()}:
            assert ticket.stream.tokens_for(rid)
    finally:
        runner.close()


@pytest.mark.slow
def test_stream_across_engine_kill_and_ft_recovery():
    """Engine kill mid-stream: supervised recovery re-homes BOTH the
    trainer's and the client tenant's in-flight requests, and the client's
    token stream stays monotonic and gap-free through the replay."""
    runner = _make_runner(_fresh_state(), max_new=64, max_len=640)
    svc = runner.service
    sup = FTSupervisor(runner, FTConfig(snapshot_every=1),
                       injector=FailureInjector(seed=3))
    try:
        sink = []
        client = svc.register_tenant("client", tokenizer=runner.tok,
                                     sink=sink.append)
        ticket = svc.submit("client", RolloutJob(
            kind="env", tag="game",
            envs=[make_env("game", seed=71), make_env("game", seed=72)],
            seeds=[71, 72],
            policy=RolloutPolicy(max_new_tokens=64, temperature=0.0),
            stream=True))
        runner._ensure_inflight()
        svc.admit()
        for _ in range(2):
            svc.tick()
        sup.last_snapshot = sup.snapshotter.capture(runner, 0)
        for _ in range(2):
            svc.tick()
        client_rids = {em._active_req for em in client.active
                       if em._active_req}
        assert client_rids, "no client request in flight at the kill"
        assert ticket.stream.token_count() > 0
        ev = sup.inject_and_recover("engine", 0)
        assert set(ev.lost_rids) & client_rids, \
            "the kill missed the client tenant's requests"
        assert ev.recovered
        _tick_until(svc, lambda: ticket.done, limit=5000)
        assert ticket.state == JobState.DONE
        assert ticket.stream.closed
        assert ticket.stream.finish_reason == "stop"
        for rid in {c.request_id for c in ticket.stream.chunks()}:
            ticket.stream.tokens_for(rid)       # asserts gap-free tiling
        assert len(sink) == 2
        assert all(t.meta["state"] == "DONE" for t in sink)
        assert not any(em.state == EMState.GENERATING
                       for em in client.active)
    finally:
        runner.close()
        sup.close()


# ---------------------------------------------------------------------------
# lifecycle: close is idempotent and exception-safe
# ---------------------------------------------------------------------------
def test_runner_close_is_idempotent():
    runner = _make_runner(_fresh_state(), mode="rollart")
    runner._start_rollout_worker()
    runner.close()
    assert runner.service._thread is None
    runner.close()                              # double-close: no-op
    with pytest.raises(RuntimeError, match="closed"):
        runner.service.start()                  # closed services stay down


def test_runner_close_after_worker_crash_returns_promptly():
    runner = _make_runner(_fresh_state(), mode="rollart")

    def boom():
        raise RuntimeError("injected tick crash")

    runner._tenant.pre_tick = boom
    runner._start_rollout_worker()
    deadline = time.monotonic() + 10
    while runner.service.error is None:
        assert time.monotonic() < deadline, "worker never crashed"
        time.sleep(0.005)
    t0 = time.monotonic()
    runner.close()                              # must not hang or raise
    runner.close()
    assert time.monotonic() - t0 < 5
    assert isinstance(runner.service.error, RuntimeError)


def test_service_close_is_reentrant_and_safe(tiny_setup):
    cfg, model, params = tiny_setup
    svc = _make_service(model, params)
    svc.start()
    svc.close()
    svc.close()
    assert svc._thread is None
    with pytest.raises(RuntimeError, match="closed"):
        svc.start()

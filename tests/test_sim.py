"""Discrete-event simulator tests: engine semantics + the RL pipeline sim
(mode ordering, staleness behavior, determinism)."""
import pytest

from repro.core.simclock import Resource, Simulator, all_of
from repro.core.simrl import SimRL, SimRLConfig, run_sim


def test_sim_timeout_ordering():
    sim = Simulator()
    log = []

    def p(name, delay):
        yield sim.timeout(delay)
        log.append((name, sim.now))

    sim.process(p("b", 2.0))
    sim.process(p("a", 1.0))
    sim.run()
    assert log == [("a", 1.0), ("b", 2.0)]


def test_sim_event_wait():
    sim = Simulator()
    ev = sim.event()
    out = []

    def waiter():
        v = yield ev
        out.append((v, sim.now))

    def trigger():
        yield sim.timeout(5.0)
        ev.trigger("done")

    sim.process(waiter())
    sim.process(trigger())
    sim.run()
    assert out == [("done", 5.0)]


def test_sim_resource_queuing():
    sim = Simulator()
    res = Resource(sim, capacity=1)
    order = []

    def worker(name, hold):
        yield from res.acquire()
        yield sim.timeout(hold)
        order.append((name, sim.now))
        res.release()

    sim.process(worker("a", 2.0))
    sim.process(worker("b", 1.0))
    sim.run()
    # b waits for a: finishes at 2 + 1
    assert order == [("a", 2.0), ("b", 3.0)]
    assert res.utilization() == pytest.approx(1.0)


def test_sim_all_of():
    sim = Simulator()
    evs = [sim.event() for _ in range(3)]
    done = []

    def waiter():
        vals = yield all_of(sim, evs)
        done.append((sim.now, vals))

    def fire(i, t):
        yield sim.timeout(t)
        evs[i].trigger(i)

    sim.process(waiter())
    for i, t in enumerate([3.0, 1.0, 2.0]):
        sim.process(fire(i, t))
    sim.run()
    assert done[0][0] == 3.0


# ---------------------------------------------------------------------------
# pipeline simulation
# ---------------------------------------------------------------------------
FAST = dict(model="qwen3-8b", batch_size=32, group_size=4, num_steps=3,
            tasks=("math", "game"), gen_pools=(("H800", 8),),
            reward_serverless=True)


def test_sim_modes_complete():
    for mode in ("sync", "sync_plus", "one_off", "areal", "rollart"):
        m = run_sim(mode=mode, async_weight_sync=(mode in ("areal",
                                                           "rollart")),
                    **FAST)
        assert len(m.step_times) == 3, mode
        assert all(t > 0 for t in m.step_times), mode


def test_sim_deterministic():
    m1 = run_sim(mode="rollart", seed=5, async_weight_sync=True, **FAST)
    m2 = run_sim(mode="rollart", seed=5, async_weight_sync=True, **FAST)
    assert m1.step_times == m2.step_times


def test_sync_slower_than_async():
    m_sync = run_sim(mode="sync", async_weight_sync=False, **FAST)
    m_async = run_sim(mode="rollart", async_weight_sync=True, **FAST)
    assert m_sync.avg_step_s > m_async.avg_step_s


def test_areal_never_aborts_rollart_bounds():
    m_areal = run_sim(mode="areal", async_weight_sync=True, seed=1, **FAST)
    assert m_areal.aborted == 0        # start-only staleness bound
    cfg = SimRLConfig(mode="rollart", alpha=0, seed=1,
                      async_weight_sync=True, **FAST)
    sim = SimRL(cfg)
    sim.run()
    # alpha=0 forces aggressive aborts of cross-version trajectories
    assert sim.metrics.aborted >= 0
    # staleness invariant on everything that reached the buffer
    assert sim.buffer.total_evicted >= 0


def test_redundancy_reduces_rollout_tail():
    base = run_sim(mode="sync_plus", redundancy=1.0, seed=3,
                   async_weight_sync=False, **FAST)
    red = run_sim(mode="sync_plus", redundancy=2.0, seed=3,
                  async_weight_sync=False, **FAST)
    avg = lambda xs: sum(xs) / max(len(xs), 1)
    assert avg(red.rollout_s) <= avg(base.rollout_s) * 1.05

"""End-to-end behaviour tests for the RollArt system: live pipeline,
engine/proxy semantics, weight sync, resource plane, and the declarative
worker/cluster programming model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (Cluster, EngineHandle, LiveRLRunner, LLMProxy,
                        MooncakeStore, ResourceManager, RunnerConfig,
                        ServerlessPlatform, pull_params, push_params)
from repro.core.worker import (ActorGenCls, RewardCls,
                               hw_mapping, register, register_serverless)
from repro.models import Model
from repro.rewards.rule_based import format_bonus_reward
from repro.rl.engine import GenRequest, InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------
def test_engine_greedy_matches_manual(tiny_setup):
    cfg, model, params = tiny_setup
    eng = InferenceEngine(model, params, max_slots=2, max_len=96)
    eng.add_request(GenRequest(request_id="g", prompt=[1, 5, 7, 9],
                               max_new_tokens=5, temperature=0.0))
    eng.run_until_idle()
    res = eng.pop_result("g")
    cache = model.init_cache(1, 96)
    lg, cache = model.prefill(params, jnp.asarray([[1, 5, 7, 9]]), cache)
    out = []
    for t in range(5):
        nt = int(jnp.argmax(lg[0]))
        out.append(nt)
        lg, cache = model.decode_step(params, jnp.asarray([[nt]]), cache,
                                      jnp.asarray([4 + t]))
    assert res.tokens == out


def test_engine_abort_between_steps(tiny_setup):
    cfg, model, params = tiny_setup
    eng = InferenceEngine(model, params, max_slots=2, max_len=96)
    eng.add_request(GenRequest(request_id="a", prompt=[1, 2],
                               max_new_tokens=50, temperature=1.0))
    eng.step()
    eng.step()
    eng.abort("a")
    eng.run_until_idle()
    res = eng.pop_result("a")
    assert res.finish_reason == "aborted"
    assert len(res.tokens) < 50


def test_engine_weight_update_recomputes_cache(tiny_setup):
    """Protocol step (5): after update_params the in-flight trajectory
    continues under the NEW weights, exactly as a fresh prefill would."""
    cfg, model, params = tiny_setup
    params2 = model.init(jax.random.PRNGKey(42))
    eng = InferenceEngine(model, params, max_slots=1, max_len=96)
    eng.add_request(GenRequest(request_id="w", prompt=[1, 3, 5],
                               max_new_tokens=6, temperature=0.0))
    for _ in range(3):
        eng.step()
    prefix = list(eng._slots[0].tokens)
    eng.update_params(params2, version=1, recompute_caches=True)
    eng.run_until_idle()
    res = eng.pop_result("w")
    # replay: greedy continuation of `prefix` under params2
    cache = model.init_cache(1, 96)
    lg, cache = model.prefill(params2, jnp.asarray([prefix]), cache)
    expect = []
    pos = len(prefix)
    while len(prefix) - 3 + len(expect) < 6:
        nt = int(jnp.argmax(lg[0]))
        expect.append(nt)
        lg, cache = model.decode_step(params2, jnp.asarray([[nt]]), cache,
                                      jnp.asarray([pos]))
        pos += 1
    got_after_update = res.tokens[len(prefix) - 3:]
    assert got_after_update == expect[: len(got_after_update)]


# ---------------------------------------------------------------------------
# proxy (R1 routing + suspend/resume)
# ---------------------------------------------------------------------------
def test_proxy_affinity_routing(tiny_setup):
    cfg, model, params = tiny_setup
    e1 = InferenceEngine(model, params, max_slots=4, max_len=64, seed=1)
    e2 = InferenceEngine(model, params, max_slots=4, max_len=64, seed=2)
    proxy = LLMProxy([EngineHandle(e1, "H800"), EngineHandle(e2, "H20")],
                     hw_affinity={"frozenlake": "H800", "math": "H20",
                                  "default": "H20"})
    done = []
    for i, tag in enumerate(["frozenlake", "math", "frozenlake", "math"]):
        proxy.submit(GenRequest(request_id=f"r{i}", prompt=[1, 2],
                                max_new_tokens=3, tag=tag),
                     callback=done.append)
    while proxy.busy:
        proxy.pump()
    assert len(done) == 4
    assert proxy.routed_by_pool == {"H800": 2, "H20": 2}


def test_proxy_suspend_preserves_inflight(tiny_setup):
    cfg, model, params = tiny_setup
    e1 = InferenceEngine(model, params, max_slots=2, max_len=64)
    proxy = LLMProxy([EngineHandle(e1, "H20")])
    done = []
    proxy.submit(GenRequest(request_id="x", prompt=[1], max_new_tokens=8),
                 callback=done.append)
    proxy.pump()
    proxy.suspend()
    proxy.submit(GenRequest(request_id="y", prompt=[1], max_new_tokens=2),
                 callback=done.append)
    for _ in range(20):
        proxy.pump()
    assert [d.request_id for d in done] == ["x"]
    proxy.resume()
    while proxy.busy:
        proxy.pump()
    assert {d.request_id for d in done} == {"x", "y"}


# ---------------------------------------------------------------------------
# weight store
# ---------------------------------------------------------------------------
def test_mooncake_roundtrip(tiny_setup):
    cfg, model, params = tiny_setup
    store = MooncakeStore(bucket_mb=1)
    n = push_params(store, params, version=3)
    assert n > 0 and store.latest_version == 3
    pulled, v = pull_params(store, params)
    assert v == 3
    for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(pulled)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_mooncake_latest_wins_and_bounded(tiny_setup):
    cfg, model, params = tiny_setup
    store = MooncakeStore(bucket_mb=1)
    for v in range(5):
        push_params(store, params, version=v)
    assert store.latest_version == 4
    assert set(store._buckets) == {3, 4}     # bounded retention


# ---------------------------------------------------------------------------
# resource plane + declarative data plane
# ---------------------------------------------------------------------------
def test_resource_binding_and_fallback():
    rm = ResourceManager({"H800": 2, "H20": 4, "CPU": 8})
    b1 = rm.bind("w1", "train", "H800", n_devices=2)
    assert b1 is not None and not b1.fallback
    b2 = rm.bind("w2", "generate", "H800", n_devices=2)
    assert b2 is not None and b2.fallback and b2.group.pool == "H20"
    assert rm.bind("w3", "train", "H800", n_devices=8) is None
    rm.release("w1")
    assert rm.available("H800") == 2


def test_cluster_decorators():
    class MyGen(ActorGenCls):
        DEFAULT_HW = "H20"

        @register(mode="execute_all")
        def ping(self, x):
            return (self.info.worker_id, x)

        @hw_mapping(hw_affinity={"frozenlake": "H800", "default": "H20"})
        def generate(self, prompt, tag_name="default"):
            return self.resource_type

    rm = ResourceManager({"H800": 2, "H20": 2})
    cluster = Cluster(rm, MyGen, num_workers=4)  # 2 on H20, fallback 2 H800
    pools = sorted(w.resource_type for w in cluster.workers)
    assert pools == ["H20", "H20", "H800", "H800"]
    out = cluster.ping(7)
    assert len(out) == 4 and all(x == 7 for _, x in out)
    assert cluster.generate("p", tag_name="frozenlake") == "H800"
    assert cluster.generate("p", tag_name="math") == "H20"
    cluster.shutdown()


def test_serverless_registration():
    class MyReward(RewardCls):
        @register_serverless(attribute="reward_proxy",
                             serverless_url="fc://test/reward")
        def compute_rewards(self, traj):
            return self.reward_proxy(traj)

    sls = ServerlessPlatform()
    sls.deploy("fc://test/reward", lambda traj: sum(traj))
    rm = ResourceManager({"Serverless": 10})
    cluster = Cluster(rm, MyReward, num_workers=1, serverless=sls)
    assert cluster.compute_rewards([1, 2, 3]) == [6]
    assert sls.stats.invocations == 1
    cluster.shutdown()


# ---------------------------------------------------------------------------
# full live pipeline (the paper's six-step protocol, real compute)
# ---------------------------------------------------------------------------
def test_live_pipeline_two_steps(tiny_setup):
    cfg, model, params = tiny_setup
    opt = default_optimizer(1e-3)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    eng = InferenceEngine(model, state.params, max_slots=8, max_len=256,
                          seed=3)
    proxy = LLMProxy([EngineHandle(eng, "H20")])
    with LiveRLRunner(
            RunnerConfig(batch_size=4, group_size=2, alpha=1,
                         tasks=("game",), max_new_tokens=12),
            proxy, state, jax.jit(make_grpo_train_step(model, opt)),
            ServerlessPlatform(), format_bonus_reward,
            seq_len=256) as runner:
        hist = runner.run_steps(2)
        assert len(hist) == 2
        assert runner.version == 2
        assert all(np.isfinite(h.loss) for h in hist)
        assert runner.serverless.stats.invocations >= 8
        assert runner.store.latest_version == 2

"""Tests for the genuinely-asynchronous live runner (train/rollout overlap)
and the concurrency bugfixes that ride along:

- SampleBuffer under concurrent put/get_batch;
- threaded rollout worker vs cooperative pump greedy-parity;
- async-reward submission-order buffering;
- EnvManager.abort on a non-GENERATING manager fires on_complete;
- update_params/update_all no-op on weight-version match;
- LLMProxy.abort ignores unknown/finished ids; per-step metric deltas;
- ServerlessPlatform thread-safety + max_concurrency + payload accounting;
- live one_off trains on the previous iteration's batch.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (EngineHandle, LiveRLRunner, LLMProxy, RunnerConfig,
                        ServerlessPlatform)
from repro.core.buffer import SampleBuffer
from repro.core.envmanager import EMState, EnvManager
from repro.core.serverless import ServerlessConfig
from repro.data.pipeline import Trajectory
from repro.models import Model
from repro.rewards.rule_based import format_bonus_reward
from repro.rl.engine import GenRequest, InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _make_runner(model, mode, **cfg_kw):
    opt = default_optimizer(1e-3)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    eng = InferenceEngine(model, state.params, max_slots=8, max_len=256,
                          seed=3)
    proxy = LLMProxy([EngineHandle(eng, "H20")])
    kw = dict(batch_size=4, group_size=2, alpha=1, tasks=("game",),
              max_new_tokens=12, temperature=0.0)
    kw.update(cfg_kw)
    return LiveRLRunner(
        RunnerConfig(mode=mode, **kw), proxy, state,
        jax.jit(make_grpo_train_step(model, opt)),
        ServerlessPlatform(), format_bonus_reward, seq_len=256)


def _traj(i, sv=0):
    return Trajectory(traj_id=f"t{i}", task="math", tokens=[1, 2],
                      loss_mask=[0, 1], logprobs=[0.0, -1.0],
                      start_version=sv)


# ---------------------------------------------------------------------------
# SampleBuffer under concurrency
# ---------------------------------------------------------------------------
def test_buffer_concurrent_put_get():
    buf = SampleBuffer(alpha=100)
    n_producers, per_producer, batch = 4, 25, 10
    total = n_producers * per_producer

    def produce(base):
        for i in range(per_producer):
            buf.put(_traj(base * per_producer + i))
            if i % 7 == 0:
                time.sleep(0.001)

    got = []

    def consume():
        for _ in range(total // batch):
            got.extend(buf.get_batch(batch, timeout=10))

    threads = [threading.Thread(target=produce, args=(b,))
               for b in range(n_producers)]
    threads.append(threading.Thread(target=consume))
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=20)
    assert len(got) == total
    assert len({t.traj_id for t in got}) == total      # no dup, no loss
    assert buf.size() == 0
    assert buf.total_consumed == total


# ---------------------------------------------------------------------------
# async reward: submission-order buffering
# ---------------------------------------------------------------------------
def test_async_reward_preserves_submission_order(tiny_setup):
    cfg, model, params = tiny_setup
    runner = _make_runner(model, "rollart")
    try:
        sls = runner.serverless
        gate = threading.Event()
        sls.deploy("fc://t/slow", lambda p: (gate.wait(5), 1.0)[1])
        sls.deploy("fc://t/fast", lambda p: 2.0)
        t_slow, t_fast = _traj("slow"), _traj("fast")
        runner._pending_rewards.append(
            [t_slow, {}, sls.invoke_async("fc://t/slow", {}), 0])
        runner._pending_rewards.append(
            [t_fast, {}, sls.invoke_async("fc://t/fast", {}), 0])
        deadline = time.monotonic() + 5
        while not runner._pending_rewards[1][2].done():
            assert time.monotonic() < deadline
            time.sleep(0.005)
        # the LATER future resolved first, but the head gates the drain
        assert runner._drain_rewards() == 0
        assert runner.buffer.size() == 0
        gate.set()
        assert runner._drain_rewards(block=True) == 2
        batch = runner.buffer.try_get_batch(2)
        assert [t.traj_id for t in batch] == ["tslow", "tfast"]
        assert [t.reward for t in batch] == [1.0, 2.0]
    finally:
        runner.close()


# ---------------------------------------------------------------------------
# EnvManager.abort completion semantics
# ---------------------------------------------------------------------------
class _DummyEnv:
    TASK = "dummy"


def test_envmanager_abort_idle_fires_on_complete():
    done = []
    em = EnvManager(_DummyEnv(), proxy=None, tag="dummy",
                    on_complete=done.append)
    em.abort()
    assert em.state is EMState.ABORTED
    assert done == [em]            # the runner can reap it from `active`
    em.abort()                     # idempotent: no double completion
    assert done == [em]


def test_envmanager_abort_completed_is_noop():
    done = []
    em = EnvManager(_DummyEnv(), proxy=None, tag="dummy",
                    on_complete=done.append)
    em.state = EMState.DONE
    em.abort()
    assert em.state is EMState.DONE and done == []


# ---------------------------------------------------------------------------
# weight-version no-op (protocol step (3)/(5))
# ---------------------------------------------------------------------------
def test_update_params_version_match_is_noop(tiny_setup):
    cfg, model, params = tiny_setup
    # max_new_tokens > 3 macro-steps * steps_per_dispatch so the request
    # is still mid-flight when update_params fires
    n_new = 30
    ref = InferenceEngine(model, params, max_slots=2, max_len=96)
    ref.add_request(GenRequest(request_id="r", prompt=[1, 5, 7],
                               max_new_tokens=n_new, temperature=0.0))
    ref.run_until_idle()
    expect = ref.pop_result("r").tokens

    eng = InferenceEngine(model, params, max_slots=2, max_len=96)
    eng.add_request(GenRequest(request_id="r", prompt=[1, 5, 7],
                               max_new_tokens=n_new, temperature=0.0))
    for _ in range(3):
        eng.step()
    eng.update_params(params, version=0)       # same version: must no-op
    assert eng.recomputes == 0
    eng.run_until_idle()
    assert eng.pop_result("r").tokens == expect

    params2 = model.init(jax.random.PRNGKey(7))
    eng.add_request(GenRequest(request_id="r2", prompt=[1, 5, 7],
                               max_new_tokens=n_new, temperature=0.0))
    eng.step()
    eng.update_params(params2, version=1)      # real update: recomputes
    assert eng.weight_version == 1
    assert eng.recomputes == 1
    eng.run_until_idle()


# ---------------------------------------------------------------------------
# proxy abort accounting
# ---------------------------------------------------------------------------
def test_proxy_abort_unknown_and_finished_ids_not_counted(tiny_setup):
    cfg, model, params = tiny_setup
    eng = InferenceEngine(model, params, max_slots=2, max_len=96)
    proxy = LLMProxy([EngineHandle(eng, "H20")])
    proxy.abort("never-submitted")
    assert proxy.aborted == 0
    done = []
    proxy.submit(GenRequest(request_id="a", prompt=[1, 2],
                            max_new_tokens=40), callback=done.append)
    proxy.pump()
    proxy.abort("a")
    assert proxy.aborted == 1
    while proxy.busy:
        proxy.pump()
    assert done and done[0].finish_reason == "aborted"
    proxy.abort("a")               # already finished: not an abort
    assert proxy.aborted == 1


# ---------------------------------------------------------------------------
# ServerlessPlatform concurrency
# ---------------------------------------------------------------------------
def test_serverless_thread_safety_and_max_concurrency():
    sls = ServerlessPlatform(ServerlessConfig(max_concurrency=2))
    peak = {"n": 0, "cur": 0}
    peak_lock = threading.Lock()

    def fn(payload):
        with peak_lock:
            peak["cur"] += 1
            peak["n"] = max(peak["n"], peak["cur"])
        time.sleep(0.02)
        with peak_lock:
            peak["cur"] -= 1
        return 1.0

    sls.deploy("fc://t/f", fn)
    futs = [sls.invoke_async("fc://t/f", {"tokens": [1, 2, 3], "text": "x"})
            for _ in range(8)]
    assert all(f.result(timeout=10) == 1.0 for f in futs)
    assert sls.stats.invocations == 8
    assert peak["n"] <= 2                      # admission control held
    assert sls.stats.peak_instances <= 2
    assert sls.stats.payload_bytes > 0         # live payloads accounted
    assert sls.stats.total_exec_s > 0


# ---------------------------------------------------------------------------
# threaded vs cooperative greedy parity + overlap + per-step deltas
# ---------------------------------------------------------------------------
def _batch_fingerprint(trajs):
    return sorted((t.task, tuple(t.tokens), round(t.reward, 6))
                  for t in trajs)


def test_threaded_pump_matches_cooperative_greedy(tiny_setup):
    cfg, model, params = tiny_setup
    with _make_runner(model, "sync") as sync_runner:
        sync_hist = sync_runner.run_steps(1)
        sync_batch = _batch_fingerprint(sync_runner.last_batch)
    with _make_runner(model, "rollart") as roll_runner:
        roll_hist = roll_runner.run_steps(1)
        roll_batch = _batch_fingerprint(roll_runner.last_batch)
    assert roll_batch == sync_batch
    assert np.isclose(roll_hist[0].loss, sync_hist[0].loss, atol=1e-5)
    # the synchronous baseline never decodes while train_step runs
    assert sync_hist[0].decode_during_train == 0


def test_one_off_trains_on_previous_batch_with_overlap(tiny_setup):
    cfg, model, params = tiny_setup
    with _make_runner(model, "one_off") as runner:
        hist = runner.run_steps(3)
        assert [h.batch_fetched_step for h in hist] == [-1, 0, 1]
        assert all(h.batch_fetched_step < h.step for h in hist)
        # trained batches predate the version being trained
        assert all(h.batch_max_version < runner.version for h in hist)
        # overlap is real: engines decoded while train_step ran
        assert sum(h.decode_during_train for h in hist) > 0
        # per-step metric deltas sum to the cumulative totals
        assert sum(h.evicted for h in hist) == runner.buffer.total_evicted
        assert sum(h.aborted for h in hist) == runner.proxy.aborted
        assert all(np.isfinite(h.loss) for h in hist)
        assert runner.store.latest_version == 3
    with pytest.raises(RuntimeError):      # closed runner fails fast
        runner.run_steps(1)

"""Observability plane: metrics registry semantics, Prometheus text
exposition grammar (hand-rolled v0.0.4 parser below — also imported by
the CI endpoint-scrape step), the HTTP endpoint, per-request lifecycle
records, ``stats()`` snapshot immutability under a concurrent scrape,
the ``StepMetrics`` export schema, watchdog stall semantics, and the
end-to-end silent-hang path: a GENUINELY wedged ``engine.step()`` (test
hook blocks inside the step lock), detected by heartbeat deadline,
hard-killed and recovered through ``FTSupervisor`` with greedy-parity
output."""
import re
import threading
import time
import urllib.error
import urllib.request

import jax
import pytest

from repro.configs import get_config
from repro.core import (EngineHandle, LiveRLRunner, LLMProxy, RunnerConfig,
                        ServerlessPlatform)
from repro.core.scheduler import STEP_METRICS_SCHEMA, StepMetrics
from repro.ft import FTConfig, FTSupervisor
from repro.models import Model
from repro.obs import (MetricsRegistry, MetricsServer, Watchdog,
                       instrument_proxy, instrument_runner,
                       instrument_service, watch_engines)
from repro.obs.server import CONTENT_TYPE
from repro.rewards.rule_based import REWARD_FNS
from repro.rl.engine import GenRequest, InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)
from repro.serve import JobState, RolloutJob, RolloutService

# ---------------------------------------------------------------------------
# Prometheus text exposition format v0.0.4 — strict grammar parser.
# No external dependency: this IS the golden-format check. The CI
# endpoint-scrape step imports ``parse_prometheus`` from here.
# ---------------------------------------------------------------------------
_NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_TYPES = ("counter", "gauge", "histogram", "summary", "untyped")
_ESC = {"\\": "\\", '"': '"', "n": "\n"}


def _parse_label_block(line, i):
    """Parse ``{name="value",...}`` starting at ``line[i] == '{'``;
    honors the \\\\, \\", \\n escapes (a literal ``}`` inside a quoted
    value must NOT close the block). Returns (labels, index past '}')."""
    assert line[i] == "{"
    i += 1
    labels = {}
    while line[i] != "}":
        j = line.index("=", i)
        name = line[i:j]
        assert _LABEL.match(name), f"bad label name {name!r}"
        assert name not in labels, f"duplicate label {name!r}"
        assert line[j + 1] == '"', f"unquoted label value after {name!r}"
        i = j + 2
        val = []
        while True:
            c = line[i]
            if c == "\\":
                nxt = line[i + 1]
                assert nxt in _ESC, f"bad escape \\{nxt!r}"
                val.append(_ESC[nxt])
                i += 2
            elif c == '"':
                i += 1
                break
            else:
                val.append(c)
                i += 1
        labels[name] = "".join(val)
        if line[i] == ",":
            i += 1
    return labels, i + 1


def _base_family(name, families):
    """A sample named ``x_bucket``/``x_sum``/``x_count`` belongs to the
    histogram family ``x`` when one is declared."""
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            base = name[: -len(suffix)]
            if families.get(base, {}).get("type") == "histogram":
                return base
    return name


def parse_prometheus(text):
    """Strict parse of the exposition body; any grammar violation raises
    AssertionError. Returns ``{family: {"help", "type", "samples":
    [(sample_name, labels_dict, value)]}}`` and enforces: TYPE/HELP
    declared at most once and before the family's samples; metric and
    label names match the spec charset; label values escape ``\\``,
    ``\"``, newline; histogram buckets are cumulative-monotone with an
    ascending ``le`` sequence ending at ``+Inf`` whose value equals
    ``_count``."""
    assert text.endswith("\n"), "exposition must end with a newline"
    families = {}
    seen_samples = set()
    for line in text.split("\n")[:-1]:
        assert line == line.strip("\r"), "no CR line endings"
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            kind, rest = line[2:6], line[7:]
            name, _, payload = rest.partition(" ")
            assert _NAME.match(name), f"bad metric name {name!r}"
            fam = families.setdefault(name, {"help": None, "type": None,
                                             "samples": []})
            key = "help" if kind == "HELP" else "type"
            assert fam[key] is None, f"duplicate {kind} for {name}"
            assert name not in seen_samples, \
                f"{kind} for {name} after its samples"
            if kind == "TYPE":
                assert payload in _TYPES, f"bad type {payload!r}"
            fam[key] = payload
            continue
        if not line or line.startswith("#"):
            continue
        i = 0
        while i < len(line) and line[i] not in "{ ":
            i += 1
        name = line[:i]
        assert _NAME.match(name), f"bad sample name {name!r}"
        labels = {}
        if i < len(line) and line[i] == "{":
            labels, i = _parse_label_block(line, i)
        rest = line[i:].split()
        assert 1 <= len(rest) <= 2, f"bad sample line {line!r}"
        value = float(rest[0])       # raises on malformed values
        base = _base_family(name, families)
        assert base in families and families[base]["type"] is not None, \
            f"sample {name!r} before its TYPE line"
        seen_samples.add(base)
        families[base]["samples"].append((name, labels, value))
    for fname, fam in families.items():
        if fam["type"] != "histogram":
            continue
        series = {}
        for name, labels, value in fam["samples"]:
            rest = {k: v for k, v in labels.items() if k != "le"}
            key = tuple(sorted(rest.items()))
            s = series.setdefault(key, {"buckets": [], "sum": None,
                                        "count": None})
            if name == fname + "_bucket":
                assert "le" in labels, "bucket without le label"
                s["buckets"].append((float(labels["le"]), value))
            elif name == fname + "_sum":
                s["sum"] = value
            elif name == fname + "_count":
                s["count"] = value
        for key, s in series.items():
            bounds = [b for b, _ in s["buckets"]]
            counts = [c for _, c in s["buckets"]]
            assert bounds == sorted(bounds), f"{fname}{key}: le not sorted"
            assert bounds and bounds[-1] == float("inf"), \
                f"{fname}{key}: missing +Inf bucket"
            assert counts == sorted(counts), \
                f"{fname}{key}: buckets not cumulative-monotone"
            assert s["sum"] is not None and s["count"] is not None, \
                f"{fname}{key}: missing _sum/_count"
            assert counts[-1] == s["count"], \
                f"{fname}{key}: +Inf bucket != _count"
    return families


# ---------------------------------------------------------------------------
# registry + exposition grammar
# ---------------------------------------------------------------------------
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help", ("role",))
    c.labels(role="decode").inc()
    c.labels(role="decode").inc(2)
    assert c.labels(role="decode").value == 3
    with pytest.raises(ValueError):
        c.labels(role="decode").inc(-1)
    c.labels(role="decode").set_total(10)
    c.labels(role="decode").set_total(4)          # clamps monotone
    assert c.labels(role="decode").value == 10
    g = reg.gauge("g", "help")
    g.child().set(5)
    g.child().dec(2)
    assert g.child().value == 3
    h = reg.histogram("h_seconds", "help", buckets=(0.1, 1.0))
    h.child().observe(0.05)
    h.child().observe(0.5)
    h.child().observe(99.0)
    cum, total, n = h.child().snapshot()
    assert cum == [1, 2, 3] and n == 3 and total == pytest.approx(99.55)
    assert h.child().percentile(0.5) == pytest.approx(1.0)


def test_registry_rejects_kind_and_label_mismatch():
    reg = MetricsRegistry()
    reg.counter("m_total", "help", ("a",))
    reg.counter("m_total", "help", ("a",))        # get-or-create: same ok
    with pytest.raises(ValueError):
        reg.gauge("m_total", "help", ("a",))
    with pytest.raises(ValueError):
        reg.counter("m_total", "help", ("b",))
    with pytest.raises(ValueError):
        reg.counter("m_total", "help", ("a",)).labels(wrong="x")
    with pytest.raises(ValueError):
        reg.counter("1bad", "help")


def test_exposition_passes_grammar_with_nasty_labels():
    reg = MetricsRegistry()
    c = reg.counter("repro_nasty_total", "escapes: \\ and \n inside",
                    ("path", "q"))
    c.labels(path='a"b\\c\nd', q="x}y{z,w=v").inc(2)
    c.labels(path="plain", q="").inc()
    h = reg.histogram("repro_lat_seconds", "latency", ("op",),
                      buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.labels(op="scrape").observe(v)
    reg.gauge("repro_g", "a gauge").child().set(-1.5)
    text = reg.render()
    fams = parse_prometheus(text)
    assert fams["repro_nasty_total"]["type"] == "counter"
    samples = {tuple(sorted(lab.items())): v
               for _, lab, v in fams["repro_nasty_total"]["samples"]}
    # the escaped label value round-trips exactly
    assert samples[(("path", 'a"b\\c\nd'), ("q", "x}y{z,w=v"))] == 2
    hist = fams["repro_lat_seconds"]
    assert hist["type"] == "histogram"
    buckets = [(lab["le"], v) for n, lab, v in hist["samples"]
               if n.endswith("_bucket")]
    assert [v for _, v in buckets] == [1, 2, 3, 4]
    assert buckets[-1][0] == "+Inf"


def test_http_endpoint_serves_exposition_and_404s():
    reg = MetricsRegistry()
    reg.counter("repro_x_total", "x").child().inc(7)
    calls = []
    reg.register_collector(lambda: calls.append(1))
    with MetricsServer(reg) as srv:
        resp = urllib.request.urlopen(srv.url)
        body = resp.read().decode("utf-8")
        assert resp.headers["Content-Type"] == CONTENT_TYPE
        assert calls, "scrape did not run collectors"
        fams = parse_prometheus(body)
        assert fams["repro_x_total"]["samples"][0][2] == 7
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url.replace("/metrics", "/nope"))
        assert ei.value.code == 404


# ---------------------------------------------------------------------------
# StepMetrics schema
# ---------------------------------------------------------------------------
def test_step_metrics_to_dict_matches_schema():
    sm = StepMetrics(step=3, wall_s=1.5, loss=0.25, reward_mean=0.5,
                     evicted=1, aborted=2, trajs=4, fetch_s=0.2,
                     barrier_s=0.1, train_s=1.1, staleness=1)
    d = sm.to_dict()
    assert list(d) == [name for name, _ in STEP_METRICS_SCHEMA]
    for name, typ in STEP_METRICS_SCHEMA:
        assert type(d[name]) is typ, f"{name}: {type(d[name])} != {typ}"
    assert d["step"] == 3 and d["train_s"] == 1.1 and d["staleness"] == 1


# ---------------------------------------------------------------------------
# watchdog unit semantics (deterministic clock via check_once(now))
# ---------------------------------------------------------------------------
def test_watchdog_fires_once_per_episode_and_rearms():
    reg = MetricsRegistry()
    wd = Watchdog(deadline_s=0.5, registry=reg)
    beat, queued, stalls = [0], [True], []
    wd.register("eng", progress_fn=lambda: beat[0],
                queued_fn=lambda: queued[0],
                on_stall=lambda: stalls.append(1))
    assert wd.check_once(now=0.0) == []          # first poll arms
    beat[0] += 1
    assert wd.check_once(now=0.4) == []          # beat advanced: re-arm
    assert wd.check_once(now=0.8) == []          # 0.4s silent < deadline
    assert wd.check_once(now=1.0) == ["eng"]     # fired
    assert wd.check_once(now=5.0) == []          # once per episode
    beat[0] += 1
    assert wd.check_once(now=5.1) == []          # recovery beat re-arms
    assert wd.check_once(now=9.9) == ["eng"]     # new episode fires again
    assert stalls == [1, 1]
    text = reg.render()
    assert 'repro_watchdog_stalls_total{component="eng"} 2' in text


def test_watchdog_idle_component_never_fires():
    wd = Watchdog(deadline_s=0.1)
    wd.register("idle", progress_fn=lambda: 0, queued_fn=lambda: False,
                on_stall=lambda: pytest.fail("idle target fired"))
    for now in (0.0, 1.0, 2.0, 3.0):
        assert wd.check_once(now=now) == []


def test_watchdog_probe_exception_skips_poll():
    wd = Watchdog(deadline_s=0.1)
    fired = []
    wd.register("flaky", progress_fn=lambda: 1 / 0,
                queued_fn=lambda: True, on_stall=lambda: fired.append(1))
    assert wd.check_once(now=0.0) == []
    assert wd.check_once(now=9.0) == [] and not fired


# ---------------------------------------------------------------------------
# live-plane fixtures
# ---------------------------------------------------------------------------
def _fresh_state():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    return init_train_state(model, jax.random.PRNGKey(0),
                            default_optimizer(1e-3))


def _make_runner_factory(mode="sync", tasks=("game",), max_new=16,
                         max_len=320):
    def make(state):
        cfg = get_config("tiny")
        model = Model(cfg, remat=False)
        opt = default_optimizer(1e-3)
        eng = InferenceEngine(model, state.params, max_slots=8,
                              max_len=max_len, seed=3)
        proxy = LLMProxy([EngineHandle(eng, "local")])
        return LiveRLRunner(
            RunnerConfig(batch_size=4, group_size=2, alpha=2, mode=mode,
                         tasks=tasks, max_new_tokens=max_new,
                         temperature=0.0),
            proxy, state, jax.jit(make_grpo_train_step(model, opt)),
            ServerlessPlatform(), REWARD_FNS["format_bonus"],
            seq_len=max_len)
    return make


def _tap(runner):
    runner._stream = []
    orig = runner._pack
    runner._pack = lambda t: (runner._stream.append(
        [(tuple(x.tokens), round(float(x.reward), 6)) for x in t])
        or orig(t))


def _tiny_proxy(max_slots=4, max_len=128):
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, max_slots=max_slots,
                          max_len=max_len, seed=0)
    return LLMProxy([EngineHandle(eng, "local")])


# ---------------------------------------------------------------------------
# lifecycle records (data-plane SLO timestamps)
# ---------------------------------------------------------------------------
def test_lifecycle_records_stamp_request_timeline():
    proxy = _tiny_proxy()
    ttfts, gaps = [], []
    proxy.on_ttft = ttfts.append
    proxy.on_gap = gaps.append
    done = []
    proxy.submit(GenRequest(request_id="r0", prompt=[1, 5, 7],
                            max_new_tokens=8, temperature=0.0),
                 callback=done.append)
    live = proxy.lifecycle("r0")
    assert live is not None and live.t_first_token is None
    while proxy.busy:
        proxy.pump()
    assert len(done) == 1
    [lc] = proxy.drain_completed_lifecycles()
    assert proxy.drain_completed_lifecycles() == []    # drained
    assert lc.request_id == "r0"
    assert (lc.t_submit <= lc.t_admit <= lc.t_first_token <= lc.t_finish)
    assert lc.tokens == len(done[0].tokens)
    assert lc.ttft == pytest.approx(lc.t_first_token - lc.t_submit)
    assert ttfts == [pytest.approx(lc.ttft)]
    # per-token gaps cover every token after the first delivery
    assert len(lc.gaps()) >= 1 and len(gaps) == len(lc.gaps())
    assert all(g >= 0 for g in lc.gaps())
    # the drained record is a snapshot: mutating it can't touch the plane
    lc.token_times.clear()


# ---------------------------------------------------------------------------
# stats() snapshots stay immutable under a concurrent scrape
# ---------------------------------------------------------------------------
def test_scrape_during_traffic_returns_immutable_snapshots():
    proxy = _tiny_proxy(max_slots=4)
    reg = MetricsRegistry()
    instrument_proxy(reg, proxy)
    svc = RolloutService(proxy, max_inflight=8)
    svc.register_tenant("t", weight=1.0)
    instrument_service(reg, svc)
    scrape_errors, stop = [], threading.Event()

    def scraper():
        try:
            while not stop.is_set():
                text = reg.render()
                parse_prometheus(text)
                # mutate every snapshot surface we can reach — the live
                # plane must not notice
                st = proxy.stats()
                st["engines"].clear()
                st["routed_by_pool"]["fake"] = 99
                st["switch_log"].append({"bogus": 1})
                svc.stats().clear()
                proxy.handles[0].engine.stats().clear()
        except Exception as e:                    # noqa: BLE001
            scrape_errors.append(e)

    t = threading.Thread(target=scraper, daemon=True)
    t.start()
    svc.start()
    try:
        tickets = [svc.submit("t", RolloutJob(
            kind="prompt", prompt=[1, 5, 7, 11 + i], max_new_tokens=8,
            temperature=1.0, stop_tokens=())) for i in range(12)]
        deadline = time.monotonic() + 60
        while any(not tk.done for tk in tickets):
            assert time.monotonic() < deadline, "traffic never drained"
            time.sleep(0.01)
    finally:
        stop.set()
        t.join(timeout=10)
        svc.close()
    assert not scrape_errors, scrape_errors
    assert svc.error is None
    st = proxy.stats()
    assert st["engines"], "scraper mutation leaked into live stats"
    assert "fake" not in st["routed_by_pool"]
    assert all("bogus" not in e for e in st["switch_log"])
    done = sum(1 for tk in tickets if tk.state == JobState.DONE)
    assert done == 12
    fams = parse_prometheus(reg.render())
    assert fams["repro_engine_decode_tokens_total"]["samples"][0][2] > 0
    assert fams["repro_slo_ttft_seconds"]["samples"], "no TTFT observed"


# ---------------------------------------------------------------------------
# full-stack exporter: every StepMetrics schema field becomes a gauge
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_full_stack_scrape_exports_step_schema():
    runner = _make_runner_factory()(_fresh_state())
    reg = MetricsRegistry()
    instrument_runner(reg, runner)
    with runner:
        runner.run_steps(1)
        fams = parse_prometheus(reg.render())
    for name, _ in STEP_METRICS_SCHEMA:
        metric = f"repro_step_{name}"
        assert metric in fams, f"schema field {name} not exported"
        assert fams[metric]["samples"], f"{metric} has no sample"
    d = runner.history[-1].to_dict()
    got = {f"repro_step_{k}": v for k, v in d.items()}
    for metric, want in got.items():
        assert fams[metric]["samples"][0][2] == pytest.approx(want)
    # the rest of the stack exported too
    for fam in ("repro_engine_decode_tokens_total",
                "repro_buffer_consumed_total",
                "repro_serverless_invocations_total",
                "repro_service_completed_total"):
        assert fams[fam]["samples"], f"{fam} missing"


# ---------------------------------------------------------------------------
# the PR-5 gap, closed end-to-end: a silently wedged engine step is
# detected by heartbeat deadline and recovered through FTSupervisor
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_watchdog_detects_wedged_engine_and_recovers_with_parity():
    make = _make_runner_factory()
    ref = make(_fresh_state())
    _tap(ref)
    with ref:
        ref.run_steps(2)

    runner = make(_fresh_state())
    _tap(runner)
    sup = FTSupervisor(runner, FTConfig(snapshot_every=1))
    eng = runner.proxy.handles[0].engine
    recover_errors = []
    try:
        runner.run_steps(1)
        # put real work in flight and cover it with a barrier snapshot
        runner._ensure_inflight()
        runner.proxy.pump()      # partial progress only: one K-step
        sup.last_snapshot = sup.snapshotter.capture(runner, 1)
        assert eng.has_pending
        # GENUINELY wedge the engine: the next step() blocks inside
        # _step_locked — holding _step_lock — until hard-killed. This is
        # a real hang, not a FailureInjector crash: without the watchdog
        # the pump thread below would block forever.
        eng._prestep_hook = lambda e: e._kill_evt.wait()
        recovered = threading.Event()

        def pump_loop():
            # sync mode has no service thread; tick like one would. The
            # first tick wedges inside engine.step() until the kill.
            while not recovered.is_set():
                runner.service.tick()

        pump_t = threading.Thread(target=pump_loop, daemon=True)
        pump_t.start()

        def recover(handle):
            try:
                sup.recover_hung_engine(handle)
            except Exception as e:                # noqa: BLE001
                recover_errors.append(e)
            finally:
                recovered.set()

        wd = Watchdog(deadline_s=0.4, poll_s=0.05)
        watch_engines(wd, runner.proxy, recover=recover)
        with wd:
            assert recovered.wait(timeout=60), "watchdog never recovered"
        pump_t.join(timeout=30)
        assert not pump_t.is_alive(), "pump thread still wedged"
        assert not recover_errors, recover_errors
        [ev] = sup.events
        assert ev.kind == "engine" and ev.recovered
        assert "watchdog" in ev.detail
        assert eng.crashes == 1, "hard kill did not reach the wedged step"
        # the reborn process carries neither the wedge nor the kill flag
        assert eng._prestep_hook is None and not eng._kill_evt.is_set()
        # stall bookkeeping: exactly one episode on the engine target
        [target] = wd._targets.values()
        assert target.stall_count == 1
        # the recovered plane trains on: greedy parity vs the unwedged
        # reference, and no traj_id trains twice
        runner.run_steps(1)
    finally:
        runner.close()
        sup.close()
    assert runner._stream == ref._stream
    ids = [i for b in runner.trained_log for i in b]
    assert len(ids) == len(set(ids))

"""Device-resident decode hot path (multi-token dispatch, donated KV
caches, bucketed prefill admission) + the satellite fixes riding along:

- K-step scanned decode (``Model.decode_block``) emits byte-identical
  tokens/logprobs to K single-step dispatches on attention and recurrent
  stacks; on the hybrid mamba/attn/MoE stack tokens are identical and
  logprobs agree to ~1 ULP (XLA fuses the scanned body differently);
- sampled (temperature > 0) streams are reproducible across
  ``steps_per_dispatch`` settings (one PRNG key per decode step in both
  paths);
- stop tokens fire mid-block via the on-device mask; per-slot budgets
  hold in a mixed batch of lengths/finish times;
- an ABORT takes effect within one macro-step (<= K extra tokens);
- donation safety: KV handoff extraction after donated steps, and a
  weight sync mid-flight over donated caches;
- bucketed first-admission prefill compiles O(log max_len) shapes;
- ``_emit_aborted_pending`` reports a never-admitted INJECT's
  already-sampled tokens as decode_tokens (accounting balance);
- ``_drain_commands`` early-outs without taking the lock when empty.
"""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _serve_one(model, params, prompt, *, k, n=20, temperature=0.0,
               stop=(), donate=True, seed=3, max_len=96, max_slots=2):
    eng = InferenceEngine(model, params, max_slots=max_slots,
                          max_len=max_len, seed=seed,
                          steps_per_dispatch=k, donate=donate)
    eng.add_request(GenRequest(request_id="r", prompt=list(prompt),
                               max_new_tokens=n, temperature=temperature,
                               stop_tokens=stop))
    eng.run_until_idle()
    return eng.pop_result("r"), eng


# ---------------------------------------------------------------------------
# tentpole: K-step scanned decode parity
# ---------------------------------------------------------------------------
def test_block_greedy_parity_attention(tiny_setup):
    """K scanned steps == K single steps, byte-identical, attention."""
    cfg, model, params = tiny_setup
    ref, eng1 = _serve_one(model, params, [1, 5, 7, 9], k=1)
    for k in (4, 8):
        res, engk = _serve_one(model, params, [1, 5, 7, 9], k=k)
        assert res.tokens == ref.tokens
        assert res.logprobs == ref.logprobs          # byte-identical
        assert engk.decode_dispatches < eng1.decode_dispatches
        assert engk.decode_tokens == eng1.decode_tokens


@pytest.mark.slow
def test_block_greedy_parity_recurrent():
    """Byte-identical K-step parity on a pure recurrent (rwkv) stack —
    the decode_block freeze semantics must not perturb live rows even
    though recurrent state, unlike a KV cache, advances every step."""
    cfg = get_config("rwkv6-7b").reduced()
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    ref, _ = _serve_one(model, params, [1, 5, 7], k=1, n=10, max_len=64)
    res, _ = _serve_one(model, params, [1, 5, 7], k=4, n=10, max_len=64)
    assert res.tokens == ref.tokens
    assert res.logprobs == ref.logprobs


@pytest.mark.slow
def test_block_greedy_parity_hybrid_tokens():
    """Hybrid mamba/attn/MoE stack: identical token stream; logprobs only
    to ~1 ULP (XLA fuses the scanned body differently than the
    standalone dispatch)."""
    cfg = get_config("jamba-v0.1-52b").reduced()
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    ref, _ = _serve_one(model, params, [1, 5, 7], k=1, n=10, max_len=64)
    res, _ = _serve_one(model, params, [1, 5, 7], k=4, n=10, max_len=64)
    assert res.tokens == ref.tokens
    np.testing.assert_allclose(res.logprobs, ref.logprobs,
                               rtol=0, atol=1e-5)


def test_sliding_window_slot_prefill_and_block_parity(tiny_setup):
    """In-place slot prefill on a ring-buffered sliding-window cache (the
    scalar-slot + advanced-index write must not transpose the KV layout),
    and K-step parity on top. Bucketing stays off for windowed stacks."""
    cfg, model, params = tiny_setup
    wmodel = Model(cfg, remat=False, window=8)
    assert wmodel.window == 8
    prompt = list(range(1, 13))                # prompt longer than window
    ref, eng1 = _serve_one(wmodel, params, prompt, k=1, n=10)
    assert not eng1._bucketed_prefill
    assert ref.finish_reason == "length" and len(ref.tokens) == 10
    res, _ = _serve_one(wmodel, params, prompt, k=4, n=10)
    assert res.tokens == ref.tokens
    assert res.logprobs == ref.logprobs
    # independent reference through the legacy batch-1 (non-slot) prefill
    # + raw decode_step loop: catches a silently transposed ring write
    import jax.numpy as jnp
    cache = wmodel.init_cache(1, 96)
    logits, cache = wmodel.prefill(params, jnp.asarray([prompt], jnp.int32),
                                   cache)
    toks = []
    pos = len(prompt)                    # index of the token being fed
    tok = int(jnp.argmax(logits[0]))
    toks.append(tok)
    for _ in range(9):
        logits, cache = wmodel.decode_step(
            params, jnp.asarray([[tok]], jnp.int32), cache,
            jnp.asarray([pos], jnp.int32))
        tok = int(jnp.argmax(logits[0]))
        toks.append(tok)
        pos += 1
    assert ref.tokens == toks


def test_sampled_stream_reproducible_across_block_sizes(tiny_setup):
    """temperature > 0: the block path consumes one key per decode step
    (the same schedule as K single dispatches), so the sampled stream is
    a function of the seed, not of steps_per_dispatch."""
    cfg, model, params = tiny_setup
    ref, _ = _serve_one(model, params, [1, 5, 7, 9], k=1, temperature=1.0)
    res, _ = _serve_one(model, params, [1, 5, 7, 9], k=4, temperature=1.0)
    assert res.tokens == ref.tokens
    assert res.logprobs == ref.logprobs


# ---------------------------------------------------------------------------
# on-device stop/length masking
# ---------------------------------------------------------------------------
def test_stop_token_mid_block(tiny_setup):
    cfg, model, params = tiny_setup
    ref, _ = _serve_one(model, params, [1, 5, 7, 9], k=1, n=12)
    stop = ref.tokens[4]                       # fires mid-macro-step
    want = ref.tokens[: ref.tokens.index(stop) + 1]
    res, eng = _serve_one(model, params, [1, 5, 7, 9], k=8, n=12,
                          stop=(stop,))
    assert res.finish_reason == "stop"
    assert res.tokens == want
    # the device mask froze the slot: tokens past the stop were sampled
    # in the same dispatch but never emitted/accounted
    assert eng.decode_tokens == len(want) - 1  # first token from prefill


def test_mixed_batch_budgets_and_finishes(tiny_setup):
    """Three concurrent slots with different lengths finishing at
    different inner steps of shared macro-blocks: per-slot budgets and
    freeze masks must not bleed across rows (greedy => row-independent
    references)."""
    cfg, model, params = tiny_setup
    lens = {"a": 3, "b": 9, "c": 17}
    prompts = {"a": [1, 4], "b": [1, 5, 7], "c": [1, 9, 9, 4]}
    refs = {r: _serve_one(model, params, prompts[r], k=1, n=lens[r])[0]
            for r in lens}
    eng = InferenceEngine(model, params, max_slots=4, max_len=96, seed=5,
                          steps_per_dispatch=8)
    for r in lens:
        eng.add_request(GenRequest(request_id=r, prompt=prompts[r],
                                   max_new_tokens=lens[r], temperature=0.0))
    eng.run_until_idle()
    for r in lens:
        res = eng.pop_result(r)
        assert res.tokens == refs[r].tokens, r
        assert res.finish_reason == refs[r].finish_reason


# ---------------------------------------------------------------------------
# command latency bound
# ---------------------------------------------------------------------------
def test_abort_latency_bounded_by_one_macro_step(tiny_setup):
    cfg, model, params = tiny_setup
    k = 8
    eng = InferenceEngine(model, params, max_slots=2, max_len=256, seed=3,
                          steps_per_dispatch=k)
    eng.add_request(GenRequest(request_id="r", prompt=[1, 5, 7],
                               max_new_tokens=200, temperature=0.0))
    eng.step()                     # admit + first macro-step
    emitted_at_abort = eng.decode_tokens
    eng.abort("r")
    eng.run_until_idle()
    res = eng.pop_result("r")
    assert res.finish_reason == "aborted"
    # the ABORT drains before the next decode dispatch: no token lands
    # after it is processed, and at most one macro-step's worth (K) could
    # have landed between issue and drain
    assert eng.decode_tokens == emitted_at_abort
    assert len(res.tokens) <= 1 + k


# ---------------------------------------------------------------------------
# donation safety
# ---------------------------------------------------------------------------
def test_donation_safety_handoff_extraction(tiny_setup):
    """Extracting slot caches right after donated decode steps, then
    continuing the trajectories on another engine, matches an
    uninterrupted run — proves the engine's only live cache reference is
    the (re-bound) jit result, never a donated/deleted buffer."""
    cfg, model, params = tiny_setup
    prompts = {"a": [1, 4, 2], "b": [1, 5, 7, 9]}
    refs = {r: _serve_one(model, params, prompts[r], k=8, n=20)[0]
            for r in prompts}
    src = InferenceEngine(model, params, max_slots=2, max_len=96, seed=9,
                          steps_per_dispatch=8)
    for r, p in prompts.items():
        src.add_request(GenRequest(request_id=r, prompt=p,
                                   max_new_tokens=20, temperature=0.0))
    src.step()                                   # donated macro-step
    handoffs = src.drain_active_handoffs()
    assert len(handoffs) == 2
    assert src.num_active == 0
    dst = InferenceEngine(model, params, max_slots=2, max_len=96, seed=21,
                          steps_per_dispatch=8)
    out = {}
    dst.on_finish = lambda res: out.__setitem__(res.request_id, res)
    for h in handoffs:
        dst.inject(h)
    dst.run_until_idle()
    for r in prompts:
        assert out[r].tokens == refs[r].tokens, r


def test_donation_safety_weight_sync_midflight(tiny_setup):
    """update_params + in-flight KV recompute over donated caches, at the
    same token boundary in a K=8 and a K=1 engine, continues to an
    identical stream."""
    cfg, model, params = tiny_setup
    params2 = model.init(jax.random.PRNGKey(7))

    def run(k, steps_before_sync):
        eng = InferenceEngine(model, params, max_slots=2, max_len=96,
                              seed=3, steps_per_dispatch=k)
        eng.add_request(GenRequest(request_id="r", prompt=[1, 5, 7],
                                   max_new_tokens=30, temperature=0.0))
        for _ in range(steps_before_sync):
            eng.step()
        assert eng.num_active == 1               # genuinely mid-flight
        eng.update_params(params2, version=1)
        assert eng.recomputes == 1
        eng.run_until_idle()
        return eng.pop_result("r")

    # 1 macro-step at K=8 == 8 single steps: same 9-token boundary
    res8 = run(8, 1)
    res1 = run(1, 8)
    assert res8.tokens == res1.tokens
    assert res8.weight_version == res1.weight_version == 1


# ---------------------------------------------------------------------------
# bucketed prefill admission
# ---------------------------------------------------------------------------
def test_bucketed_admission_bounds_prefill_compiles(tiny_setup):
    """12 distinct prompt lengths must reuse O(log max_len) compiled
    prefill shapes (power-of-two buckets), not one shape per length."""
    cfg, model, params = tiny_setup
    eng = InferenceEngine(model, params, max_slots=2, max_len=256, seed=3)
    assert eng._bucketed_prefill
    rng = np.random.RandomState(0)
    lengths = [3, 5, 7, 9, 12, 15, 17, 20, 24, 29, 33, 40]
    for j, n in enumerate(lengths):
        prompt = [1] + list(rng.randint(3, cfg.vocab_size - 1, size=n - 1))
        eng.add_request(GenRequest(request_id=f"r{j}", prompt=prompt,
                                   max_new_tokens=2, temperature=0.0))
        eng.run_until_idle()
        assert eng.pop_result(f"r{j}").finish_reason == "length"
    if hasattr(eng._prefill_jit, "_cache_size"):
        # lengths 3..40 -> buckets {16, 32, 64}
        assert eng._prefill_jit._cache_size() <= 3


# ---------------------------------------------------------------------------
# satellites: accounting + idle-pump fast path
# ---------------------------------------------------------------------------
def test_aborted_pending_inject_reports_decode_tokens(tiny_setup):
    """A never-admitted INJECT that gets aborted must report its
    already-sampled tokens as decode_tokens, not 0."""
    cfg, model, params = tiny_setup
    captured = []
    pre = InferenceEngine(model, params, max_slots=2, max_len=96, seed=3,
                          role="prefill", on_handoff=captured.append)
    pre.add_request(GenRequest(request_id="h", prompt=[1, 5, 7],
                               max_new_tokens=10, temperature=0.0))
    pre.step()
    (handoff,) = captured
    assert len(handoff.new_tokens) == 1
    dec = InferenceEngine(model, params, max_slots=2, max_len=96, seed=4,
                          role="decode")
    dec.suspend()                    # the INJECT can never be admitted
    dec.inject(handoff)
    dec.abort("h")
    dec.step()
    res = dec.pop_result("h")
    assert res.finish_reason == "aborted"
    assert res.tokens == handoff.new_tokens
    assert res.decode_tokens == len(handoff.new_tokens) == 1
    assert res.prefill_tokens == 3


def test_drain_commands_empty_queue_is_lock_free(tiny_setup):
    cfg, model, params = tiny_setup
    eng = InferenceEngine(model, params, max_slots=2, max_len=96)

    class CountingLock:
        def __init__(self):
            self.acquisitions = 0
            self._lock = threading.Lock()

        def __enter__(self):
            self.acquisitions += 1
            self._lock.acquire()
            return self

        def __exit__(self, *exc):
            self._lock.release()

    eng._lock = CountingLock()
    for _ in range(5):
        eng.step()                       # idle pumps: empty command queue
    assert eng._lock.acquisitions == 0
    eng.add_request(GenRequest(request_id="r", prompt=[1, 4],
                               max_new_tokens=2, temperature=0.0))
    eng.step()                           # non-empty queue still drains
    assert eng._lock.acquisitions > 0
    assert eng.pop_result("r") is not None


# ---------------------------------------------------------------------------
# CI smoke of the benchmark (fast job runs -m "not slow")
# ---------------------------------------------------------------------------
def test_decode_hotpath_benchmark_smoke():
    from benchmarks.decode_hotpath import run
    b = run(n_requests=2, max_new=8, steps_per_dispatch=4, reps=1,
            cold_lengths=2, save=False)
    rows = {r["metric"]: r["value"] for r in b.rows}
    assert rows["greedy_parity"] == 1
    assert 0 < rows["block_dispatches_per_token"] <= 1

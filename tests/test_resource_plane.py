"""Resource plane + hardware-affinity workload mapping for the live data
plane:

- role-affine binding (prefill -> compute-class, decode -> bandwidth-class)
  with preferred-pool-exhausted fallback and release-then-rebind reuse;
- ResourceManager under concurrent bind/release;
- rebind (the role-switch path) migrates the device group to the new
  role's preferred class;
- Cluster._create_workers releases earlier bindings when the k-th bind
  (or a worker setup) fails;
- the dynamic prefill<->decode rebalancer: hysteresis band, role switch
  with device re-bind, in-flight KV migration with greedy parity, and the
  switch recorded in StepMetrics;
- PerfModel placement pricing reproduces the Table 2 ordering;
- TaskSampler weight validation; empty-payload env actions are penalties,
  not crashes.
"""
import threading

import jax
import pytest

from repro.configs import get_config
from repro.core import (H20, H800, PERF, Cluster, LiveRLRunner, LLMProxy,
                        RebalancerConfig, ResourceManager, RunnerConfig,
                        ServerlessPlatform, build_pd_proxy, parse_pools)
from repro.core.scheduler import DEFAULT_TASKS
from repro.core.worker import Worker
from repro.data.pipeline import TaskSampler
from repro.envs.math_env import MathEnv
from repro.envs.swe_sim import SWEEnv
from repro.models import Model
from repro.rewards.rule_based import format_bonus_reward
from repro.rl.engine import GenRequest, InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# role-affine binding
# ---------------------------------------------------------------------------
def test_bind_affine_prefers_role_class():
    rm = ResourceManager({"H800": 2, "H20": 2})
    bp = rm.bind_affine("p0", "prefill")
    bd = rm.bind_affine("d0", "decode")
    assert bp.group.pool == "H800" and not bp.fallback
    assert bd.group.pool == "H20" and not bd.fallback


def test_bind_affine_falls_back_then_rebinds_preferred():
    rm = ResourceManager({"H800": 1, "H20": 1})
    b0 = rm.bind_affine("p0", "prefill")
    assert b0.group.pool == "H800"
    # preferred (compute) pool exhausted: opportunistic fallback, flagged
    b1 = rm.bind_affine("p1", "prefill")
    assert b1 is not None and b1.group.pool == "H20" and b1.fallback
    # both pools exhausted: bind is impossible, not an exception
    assert rm.bind_affine("p2", "prefill") is None
    # release-then-rebind reuse: the freed H800 device comes back
    rm.release("p0")
    b2 = rm.bind_affine("p2", "prefill")
    assert b2.group.pool == "H800" and not b2.fallback
    assert b2.group.device_ids == b0.group.device_ids


def test_rebind_migrates_to_new_role_class():
    rm = ResourceManager({"H800": 1, "H20": 1})
    b = rm.bind_affine("e0", "prefill")
    assert b.group.pool == "H800"
    b2 = rm.rebind("e0", "decode")
    assert b2.group.pool == "H20" and b2.role == "decode"
    assert rm.available("H800") == 1          # old group released
    assert rm.available("H20") == 0
    assert rm.rebind("ghost", "decode") is None


def test_rebind_single_pool_rebinds_in_place():
    rm = ResourceManager({"H800": 1})
    rm.bind_affine("e0", "prefill")
    b = rm.rebind("e0", "decode")             # nowhere else to go
    assert b is not None and b.group.pool == "H800" and b.fallback
    assert rm.available("H800") == 0


def test_concurrent_bind_release_no_double_allocation():
    rm = ResourceManager({"H20": 4})
    held, errors = set(), []
    held_lock = threading.Lock()

    def worker(tid):
        try:
            for i in range(100):
                wid = f"w{tid}.{i}"
                b = rm.bind_affine(wid, "decode")
                if b is None:
                    continue
                with held_lock:
                    for d in b.group.device_ids:
                        assert d not in held, "device double-allocated"
                        held.add(d)
                with held_lock:
                    for d in b.group.device_ids:
                        held.discard(d)
                rm.release(wid)
        except BaseException as e:             # surfaced after join
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert not errors
    assert rm.available("H20") == 4            # everything returned


# ---------------------------------------------------------------------------
# Cluster binding-leak fix
# ---------------------------------------------------------------------------
class _GenWorker(Worker):
    ROLE = "generate"
    DEFAULT_HW = "H20"
    torn_down = []

    def teardown(self):
        _GenWorker.torn_down.append(self.info.worker_id)


class _ExplodingWorker(_GenWorker):
    created = 0

    def setup(self):
        _ExplodingWorker.created += 1
        if _ExplodingWorker.created >= 2:
            raise RuntimeError("boom on worker 2")


def test_cluster_partial_bind_failure_releases_bindings():
    rm = ResourceManager({"H20": 2})
    with pytest.raises(RuntimeError, match="cannot bind"):
        Cluster(rm, _GenWorker, num_workers=5)   # only 2 fit (no fallback
    #                                              pool is configured)
    snap = rm.snapshot()
    assert snap["free"]["H20"] == 2              # k-1 bindings released
    assert snap["bound"] == {}                   # no stale metadata


def test_cluster_setup_failure_tears_down_and_releases():
    rm = ResourceManager({"H20": 4})
    _GenWorker.torn_down = []
    _ExplodingWorker.created = 0
    with pytest.raises(RuntimeError, match="boom"):
        Cluster(rm, _ExplodingWorker, num_workers=3)
    assert rm.snapshot()["free"]["H20"] == 4
    assert len(_GenWorker.torn_down) == 1        # worker 1 torn down


# ---------------------------------------------------------------------------
# PerfModel placement pricing (Table 2 ordering)
# ---------------------------------------------------------------------------
def test_price_placement_table2_ordering():
    cfg = get_config("qwen3-8b")
    kw = dict(prompt_tokens=4096, new_tokens=256, concurrency=32)
    affine = PERF.price_placement(cfg, H800, H20, **kw)
    anti = PERF.price_placement(cfg, H20, H800, **kw)
    homog = max(PERF.price_placement(cfg, H800, H800, **kw),
                PERF.price_placement(cfg, H20, H20, **kw),
                key=lambda p: p["cost_norm_throughput"])
    assert affine["cost_norm_throughput"] \
        >= 1.2 * anti["cost_norm_throughput"]
    assert affine["cost_norm_throughput"] > homog["cost_norm_throughput"]
    # the bottleneck-stage rate is what gets priced
    assert affine["rate_rps"] == pytest.approx(
        min(affine["prefill_rate_rps"], affine["decode_rate_rps"]))


def test_role_latency_matches_phases():
    cfg = get_config("qwen3-8b")
    t_p = PERF.role_latency(cfg, "prefill", H800, prompt_tokens=1024,
                            new_tokens=128)
    t_d = PERF.role_latency(cfg, "decode", H20, prompt_tokens=1024,
                            new_tokens=128)
    t_c = PERF.role_latency(cfg, "colocated", H800, prompt_tokens=1024,
                            new_tokens=128)
    assert t_p == pytest.approx(PERF.prefill_time(cfg, 1024, H800, 1))
    assert t_d == pytest.approx(PERF.decode_time(cfg, 128, H20, 1,
                                                 context=1152,
                                                 concurrency=32))
    assert t_c > t_p


# ---------------------------------------------------------------------------
# live proxy: affine placement + placement report
# ---------------------------------------------------------------------------
def test_build_pd_proxy_binds_affine_and_reports(tiny_setup):
    cfg, model, params = tiny_setup
    rm = ResourceManager({"H800": 2, "H20": 2})
    proxy = build_pd_proxy(model, params, max_slots=2, max_len=96,
                           n_prefill=1, n_decode=1, resource_manager=rm)
    pools = {h.name: h.pool for h in proxy.handles}
    assert pools == {"prefill-0": "H800", "decode-0": "H20"}
    report = {r["name"]: r for r in proxy.placement_report()}
    assert report["prefill-0"]["affine"] and report["decode-0"]["affine"]
    assert report["prefill-0"]["modeled_prefill_s"] \
        < report["decode-0"]["modeled_prefill_s"]
    proxy.release_bindings()
    assert rm.snapshot()["free"] == {"H800": 2, "H20": 2}


def test_build_pd_proxy_bind_failure_releases_partial(tiny_setup):
    cfg, model, params = tiny_setup
    rm = ResourceManager({"H800": 1})
    with pytest.raises(RuntimeError, match="cannot bind"):
        build_pd_proxy(model, params, max_slots=2, max_len=96,
                       n_prefill=1, n_decode=1, resource_manager=rm,
                       devices_per_engine=2)
    assert rm.snapshot()["free"] == {"H800": 1}
    assert rm.snapshot()["bound"] == {}


# ---------------------------------------------------------------------------
# dynamic rebalancer
# ---------------------------------------------------------------------------
def _serve(proxy, reqs, max_pumps=4000):
    out = {}
    for r in reqs:
        proxy.submit(r, callback=lambda res: out.__setitem__(
            res.request_id, res))
    pumps = 0
    while proxy.busy:
        proxy.pump()
        pumps += 1
        assert pumps < max_pumps, "proxy did not drain"
    return out


def _greedy_colocated(model, params, prompt, n, max_len=96):
    eng = InferenceEngine(model, params, max_slots=2, max_len=max_len)
    eng.add_request(GenRequest(request_id="ref", prompt=list(prompt),
                               max_new_tokens=n, temperature=0.0))
    eng.run_until_idle()
    return eng.pop_result("ref").tokens


def test_rebalancer_switches_and_rebinds_under_decode_backlog(tiny_setup):
    cfg, model, params = tiny_setup
    rm = ResourceManager({"H800": 2, "H20": 2})
    proxy = build_pd_proxy(
        model, params, max_slots=4, max_len=96, n_prefill=2, n_decode=1,
        resource_manager=rm,
        rebalancer=RebalancerConfig(high=2.0, window=2, cooldown=8))
    reqs = [GenRequest(request_id=f"r{i}", prompt=[1, 2 + i],
                       max_new_tokens=20, temperature=0.0)
            for i in range(6)]
    out = _serve(proxy, reqs)
    assert len(out) == 6
    assert all(r.finish_reason in ("stop", "length") for r in out.values())
    assert proxy.role_switches >= 1
    ev = proxy.switch_log[0]
    assert (ev["from_role"], ev["to_role"]) == ("prefill", "decode")
    # the flipped engine released its compute-class device and re-bound
    # on the free bandwidth-class one
    assert (ev["from_pool"], ev["to_pool"]) == ("H800", "H20")
    assert rm.snapshot()["free"]["H800"] == 1
    # greedy parity survives the switch
    for i in range(6):
        assert out[f"r{i}"].tokens == _greedy_colocated(
            model, params, [1, 2 + i], 20)
    proxy.release_bindings()


def test_rebalancer_hysteresis_no_switch_in_band(tiny_setup):
    cfg, model, params = tiny_setup
    proxy = build_pd_proxy(
        model, params, max_slots=4, max_len=96, n_prefill=2, n_decode=2,
        rebalancer=RebalancerConfig(high=1000.0, low=0.0, window=2,
                                    cooldown=0))
    reqs = [GenRequest(request_id=f"r{i}", prompt=[1, 2 + i],
                       max_new_tokens=8, temperature=0.0)
            for i in range(4)]
    _serve(proxy, reqs)
    assert proxy.role_switches == 0            # ratio never left the band


def test_switch_role_migrates_inflight_kv_with_parity(tiny_setup):
    cfg, model, params = tiny_setup
    proxy = build_pd_proxy(model, params, max_slots=4, max_len=96,
                           n_prefill=1, n_decode=2)
    out = {}
    prompts = {f"m{i}": [1, 3 + i] for i in range(2)}
    # long enough that 4 macro-step pumps (default steps_per_dispatch=8)
    # leave both trajectories mid-decode when the role switch fires
    n_new = 48
    for rid, p in prompts.items():
        proxy.submit(GenRequest(request_id=rid, prompt=p,
                                max_new_tokens=n_new, temperature=0.0),
                     callback=lambda r: out.__setitem__(r.request_id, r))
    for _ in range(4):                          # mid-decode on both engines
        proxy.pump()
    donor = max(proxy.decode_handles, key=lambda h: h.engine.num_active)
    n_active = donor.engine.num_active
    assert n_active >= 1
    proxy.switch_role(donor, "prefill")
    assert donor.role == "prefill"
    assert proxy.switch_migrations == n_active
    assert donor.engine.num_active == 0         # slots drained
    pumps = 0
    while proxy.busy:
        proxy.pump()
        pumps += 1
        assert pumps < 500
    for rid, p in prompts.items():
        assert out[rid].tokens == _greedy_colocated(model, params, p, n_new)
    assert len(proxy.prefill_handles) == 2
    assert len(proxy.decode_handles) == 1


def test_rebalancer_requires_pd(tiny_setup):
    cfg, model, params = tiny_setup
    eng = InferenceEngine(model, params, max_slots=2, max_len=96)
    from repro.core import EngineHandle
    with pytest.raises(ValueError, match="pd_disagg"):
        LLMProxy([EngineHandle(eng, "H20")],
                 rebalancer=RebalancerConfig())


def test_switch_role_refuses_last_engine_of_a_role(tiny_setup):
    cfg, model, params = tiny_setup
    proxy = build_pd_proxy(model, params, max_slots=2, max_len=96)
    with pytest.raises(ValueError, match="last"):
        proxy.switch_role(proxy.decode_handles[0], "prefill")
    with pytest.raises(ValueError, match="last"):
        proxy.switch_role(proxy.prefill_handles[0], "decode")
    assert proxy.role_switches == 0


# ---------------------------------------------------------------------------
# StepMetrics records the role switch (live runner, --pools/--affinity path)
# ---------------------------------------------------------------------------
def test_live_runner_records_role_switch_in_stepmetrics(tiny_setup):
    cfg, model, params = tiny_setup
    opt = default_optimizer(1e-3)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    rm = ResourceManager({"H800": 2, "H20": 2})
    # steps_per_dispatch=1: the test targets the rebalancer's queue-depth
    # dynamics, and the deliberately mis-split 1-decode backlog that
    # triggers the switch builds up per single-token pump; at K=8 the
    # decode side drains too fast to leave the hysteresis band
    proxy = build_pd_proxy(model, state.params, max_slots=4, max_len=256,
                           n_prefill=2, n_decode=1, resource_manager=rm,
                           rebalancer=RebalancerConfig(),
                           steps_per_dispatch=1)
    with LiveRLRunner(
            RunnerConfig(batch_size=4, group_size=2, mode="sync",
                         tasks=("game",), max_new_tokens=12,
                         pd_disagg=True, pools={"H800": 2, "H20": 2},
                         affinity=True, steps_per_dispatch=1),
            proxy, state, jax.jit(make_grpo_train_step(model, opt)),
            ServerlessPlatform(), format_bonus_reward,
            seq_len=256) as runner:
        hist = runner.run_steps(1)
    assert sum(h.role_switches for h in hist) >= 1
    assert runner.proxy.role_switches == sum(h.role_switches for h in hist)
    assert runner.placement_report()           # pricing available live
    proxy.release_bindings()
    assert rm.snapshot()["free"] == {"H800": 2, "H20": 2}


# ---------------------------------------------------------------------------
# satellites: parse_pools, TaskSampler validation, env empty payloads
# ---------------------------------------------------------------------------
def test_parse_pools():
    assert parse_pools("H800:8,H20:8") == {"H800": 8, "H20": 8}
    assert parse_pools(" H20:1 ") == {"H20": 1}
    with pytest.raises(ValueError, match="unknown hardware"):
        parse_pools("B200:4")
    with pytest.raises(ValueError, match="bad device count"):
        parse_pools("H20:lots")
    with pytest.raises(ValueError, match="positive"):
        parse_pools("H20:0")
    with pytest.raises(ValueError, match="empty"):
        parse_pools(",")


def test_task_sampler_validates_weights():
    with pytest.raises(ValueError, match="length"):
        TaskSampler(["a", "b"], weights=[])       # falsy != uniform
    with pytest.raises(ValueError, match="length"):
        TaskSampler(["a", "b"], weights=[1.0, 2.0, 3.0])
    with pytest.raises(ValueError, match="sum to zero"):
        TaskSampler(["a", "b"], weights=[0.0, 0.0])
    with pytest.raises(ValueError, match="finite"):
        TaskSampler(["a", "b"], weights=[-1.0, 2.0])
    with pytest.raises(ValueError, match="at least one task"):
        TaskSampler([])
    s = TaskSampler(["a", "b"], weights=[1.0, 0.0])
    assert {s.sample() for _ in range(50)} == {"a"}
    u = TaskSampler(["a", "b"], seed=1)           # uniform still works
    assert {u.sample() for _ in range(50)} == {"a", "b"}


def test_runner_default_mix_includes_long_tail():
    cfg = RunnerConfig()
    assert "swe" in cfg.tasks and "webshop" in cfg.tasks
    ws = cfg.sampler_weights()
    assert ws is not None and len(ws) == len(DEFAULT_TASKS)
    assert RunnerConfig(tasks=("game",)).sampler_weights() is None


def test_swe_env_empty_payloads_are_penalties_not_crashes():
    env = SWEEnv(seed=3)
    env.reset(seed=3)
    obs, r, done, _ = env.step("cat:")
    assert r < 0 and not done and "filename" in obs
    obs, r, done, _ = env.step("cat:   ")
    assert r < 0 and not done
    obs, r, done, _ = env.step("patch:")
    assert r < 0 and not done and "malformed" in obs
    obs, r, done, _ = env.step("cat: calc.py")   # well-formed still works
    assert r == 0.0 and "def add" in obs


def test_math_env_empty_calc_is_error_not_crash():
    env = MathEnv(seed=5)
    env.reset(seed=5)
    obs, r, done, _ = env.step("calc:")
    assert r < 0 and not done and "error" in obs
    obs, r, done, _ = env.step("calc: 2 + 2")
    assert "= 4" in obs

"""Fault-tolerance plane (paper §8): checkpointer crash-safety satellites,
SampleBuffer traj_id dedup, rollout snapshot/restore roundtrips (byte-
identical trajectories + KV slots across attention / rwkv / hybrid
stacks), supervised failure recovery, and the trainer-restart path with
corrupt-checkpoint fallback."""
import os
import pickle
import random

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpointer as CK
from repro.checkpoint.checkpointer import CorruptCheckpointError
from repro.configs import get_config
from repro.core import (EngineHandle, LiveRLRunner, LLMProxy, RunnerConfig,
                        ServerlessPlatform)
from repro.core.buffer import SampleBuffer
from repro.core.envmanager import EMState, EnvManager
from repro.core.serverless import ServerlessError
from repro.data.pipeline import Trajectory
from repro.envs import make_env
from repro.ft import (FTConfig, FTSupervisor, FailureInjector,
                      RolloutSnapshot, RolloutSnapshotter, restore_latest)
from repro.ft.snapshot import _handoff_record
from repro.models import Model
from repro.rewards.rule_based import REWARD_FNS
from repro.rl.engine import GenRequest, InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)


# ---------------------------------------------------------------------------
# checkpointer satellites
# ---------------------------------------------------------------------------
def _tree(x=0.0):
    return {"w": np.arange(6, dtype=np.float32).reshape(2, 3) + x,
            "b": np.float32(x)}


def test_save_creates_missing_path(tmp_path, monkeypatch):
    """A nonexistent target dir is created up front and the staging dir
    lives inside it — never in the CWD."""
    monkeypatch.chdir(tmp_path)
    target = tmp_path / "does" / "not" / "exist"
    out = CK.save(str(target), _tree(), step=3)
    assert os.path.isdir(out)
    restored, step = CK.restore(str(target), _tree())
    assert step == 3
    np.testing.assert_array_equal(restored["w"], _tree()["w"])
    stray = [d for d in os.listdir(tmp_path)
             if d.startswith(".tmp_ckpt_")]
    assert not stray, f"staging dirs leaked into CWD: {stray}"


def test_keep_last_prunes_and_sweeps_tmp(tmp_path):
    path = str(tmp_path)
    for s in range(5):
        CK.save(path, _tree(s), step=s)
    os.makedirs(tmp_path / ".tmp_ckpt_dead")     # crashed-save leftover
    CK.save(path, _tree(5), step=5, keep_last=2)
    assert CK.steps(path) == [4, 5]
    assert not any(d.startswith(".tmp_ckpt_") for d in os.listdir(path))


def test_latest_step_ignores_stale_staging_dirs(tmp_path):
    path = str(tmp_path)
    CK.save(path, _tree(), step=7)
    os.makedirs(tmp_path / ".tmp_ckpt_crashed")
    (tmp_path / ".tmp_ckpt_crashed" / "arrays.npz").write_bytes(b"partial")
    (tmp_path / "step_notanumber").mkdir()
    assert CK.latest_step(path) == 7


def test_crash_mid_save_leaves_previous_readable(tmp_path):
    """A save that dies before the atomic replace must not disturb the
    previous latest_step."""
    path = str(tmp_path)
    CK.save(path, _tree(1), step=1)
    stage = tmp_path / ".tmp_ckpt_inflight"
    stage.mkdir()
    (stage / "arrays.npz").write_bytes(b"truncated half-written npz")
    assert CK.latest_step(path) == 1
    restored, step = CK.restore(str(path), _tree())
    assert step == 1 and float(restored["b"]) == 1.0


def test_restore_mismatch_names_step_and_counts(tmp_path):
    path = str(tmp_path)
    CK.save(path, _tree(), step=4)
    with pytest.raises(ValueError, match=r"step 4.*template has 3.*2"):
        CK.restore(path, {"w": np.zeros((2, 3), np.float32),
                          "b": 0.0, "extra": 0.0})


def test_restore_corrupt_npz_and_meta(tmp_path):
    path = str(tmp_path)
    d = CK.save(path, _tree(), step=2)
    (tmp_path / "step_00000002" / "arrays.npz").write_bytes(b"garbage")
    with pytest.raises(CorruptCheckpointError, match="step 2"):
        CK.restore(path, _tree())
    CK.save(path, _tree(), step=2)
    with open(os.path.join(d, "meta.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(CorruptCheckpointError, match="step 2"):
        CK.restore(path, _tree())


# ---------------------------------------------------------------------------
# SampleBuffer: dedup + snapshot/restore
# ---------------------------------------------------------------------------
def _traj(tid, sv=0):
    return Trajectory(traj_id=tid, task="t", tokens=[1, 2], loss_mask=[0, 1],
                      logprobs=[0.0, -0.5], reward=1.0, start_version=sv)


def test_buffer_dedups_consumed_replays():
    buf = SampleBuffer(alpha=4)
    buf.put(_traj("a"))
    buf.put(_traj("b"))
    assert [t.traj_id for t in buf.get_batch(2)] == ["a", "b"]
    buf.put(_traj("a"))            # replay after a plane restore
    assert buf.size() == 0
    assert buf.total_deduped == 1


def test_buffer_dedups_buffered_duplicate():
    """A replay of a trajectory still WAITING in the buffer must not
    produce a second copy (first completion buffered, plane restored,
    trajectory regenerated)."""
    buf = SampleBuffer(alpha=4)
    buf.put(_traj("a"))
    buf.put(_traj("a"))
    assert buf.size() == 1 and buf.total_deduped == 1
    # after consumption the id moves to the consumed set
    buf.get_batch(1)
    buf.put(_traj("a"))
    assert buf.size() == 0 and buf.total_deduped == 2


def test_buffer_snapshot_restore_preserves_fifo_and_consumed():
    buf = SampleBuffer(alpha=8)
    for tid in ("a", "b", "c"):
        buf.put(_traj(tid))
    buf.get_batch(1)               # consume "a"
    state = buf.snapshot_state()
    buf2 = SampleBuffer(alpha=8)
    buf2.restore_state(state)
    assert [t.traj_id for t in buf2.get_batch(2)] == ["b", "c"]
    buf2.put(_traj("a"))           # consumed frontier survived
    assert buf2.total_deduped == 1
    buf2.put(_traj("d"))           # seq counter advanced past the restore
    assert buf2.get_batch(1)[0].seq > state["seq"] - 1


# ---------------------------------------------------------------------------
# serverless failure injection + EnvManager records
# ---------------------------------------------------------------------------
def test_serverless_fail_next():
    sls = ServerlessPlatform()
    sls.deploy("fc://t/r", lambda p: 1.0)
    sls.fail_next("fc://t/r")
    with pytest.raises(ServerlessError):
        sls.invoke("fc://t/r", {})
    assert sls.stats.failures == 1
    assert sls.invoke("fc://t/r", {}) == 1.0


class _StubProxy:
    def __init__(self):
        self.aborted = []
        self.submitted = []

    def abort(self, rid):
        self.aborted.append(rid)

    def submit(self, req, callback=None, on_tokens=None):
        self.submitted.append(req)


def test_envmanager_snapshot_restore_roundtrip():
    env = make_env("game", seed=11)
    proxy = _StubProxy()
    em = EnvManager(env, proxy, tag="game", group_id="g0")
    em.start(version=3, seed=11)
    assert em.state.name == "GENERATING"
    rec = em.snapshot_state()
    rec = pickle.loads(pickle.dumps(rec))     # disk-shaped roundtrip
    em2 = EnvManager.restore_from(rec, proxy)
    assert em2.em_id == em.em_id
    assert em2.tokens == em.tokens and em2.loss_mask == em.loss_mask
    assert em2.logprobs == em.logprobs
    assert em2.start_version == 3 and em2._active_req == em._active_req
    assert em2.env.a == em.env.a and em2.env.b == em.env.b
    # snapshotting twice must not perturb the request-id sequence
    assert em.snapshot_state()["req_counter"] == rec["req_counter"]


def test_envmanager_fail_is_idempotent_and_aborts():
    env = make_env("game", seed=1)
    proxy = _StubProxy()
    done = []
    em = EnvManager(env, proxy, tag="game", on_complete=done.append)
    em.start(version=0, seed=1)
    rid = em._active_req
    em.fail()
    em.fail()
    assert em.state.name == "FAILED"
    assert proxy.aborted == [rid]
    assert done == [em]


# ---------------------------------------------------------------------------
# rollout snapshot roundtrip: byte-identical KV slots + resume parity
# ---------------------------------------------------------------------------
def _empty_buffer_state():
    return {"items": [], "seq": 0, "version": 0, "consumed": set(),
            "total_put": 0, "total_evicted": 0, "total_consumed": 0,
            "total_deduped": 0}


def _roundtrip_stack(cfg, tmp_path, max_new=20):
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    prompt = [1, 5, 7, 9]

    ref_eng = InferenceEngine(model, params, max_slots=2, max_len=64,
                              seed=5)
    ref_eng.add_request(GenRequest("r", list(prompt),
                                   max_new_tokens=max_new,
                                   temperature=0.0))
    ref_eng.run_until_idle()
    ref = ref_eng.pop_result("r")

    eng = InferenceEngine(model, params, max_slots=2, max_len=64, seed=5)
    eng.add_request(GenRequest("r", list(prompt), max_new_tokens=max_new,
                               temperature=0.0))
    eng.step()                     # partial generation (one macro-step)
    [hf] = eng.snapshot_slots()
    rec = _handoff_record(hf)
    traj = _traj("byte-roundtrip")
    snap = RolloutSnapshot(
        step=0, version=0, runner_version=0, mode="sync",
        buffer=_empty_buffer_state(), in_hand=[traj], prev_fetched=-1,
        pending_rewards=[], ems=[],
        engines=[{"name": "e0", "role": "colocated",
                  "key": eng.snapshot_rng(), "weight_version": 0,
                  "slots": [rec], "queued": []}],
        sampler_rng=random.Random(0).getstate(), seed_counter=0,
        em_counter=0)
    snapper = RolloutSnapshotter(str(tmp_path), keep_last=2)
    snapper.save(snap)
    loaded = snapper.load()

    # byte-identical KV slot + trajectory across the disk roundtrip
    lrec = loaded.engines[0]["slots"][0]
    assert len(lrec["cache_leaves"]) == len(rec["cache_leaves"])
    for a, b in zip(rec["cache_leaves"], lrec["cache_leaves"]):
        a = np.asarray(a)
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)
    assert lrec["tokens"] == rec["tokens"]
    assert lrec["new_tokens"] == rec["new_tokens"]
    assert lrec["logprobs"] == rec["logprobs"]
    lt = loaded.in_hand[0]
    assert (lt.tokens, lt.loss_mask, lt.logprobs, lt.reward) == \
        (traj.tokens, traj.loss_mask, traj.logprobs, traj.reward)

    # resume on a fresh engine: the completed stream matches the
    # uninterrupted reference exactly (greedy)
    eng2 = InferenceEngine(model, params, max_slots=2, max_len=64, seed=5)
    tmpl_leaves, treedef = jax.tree.flatten(
        model.extract_cache_slot(eng2._cache, 0))
    out = []
    eng2.on_finish = out.append
    eng2.inject(snapper._rebuild_handoff(lrec, treedef, tmpl_leaves))
    eng2.run_until_idle()
    assert out[0].tokens == ref.tokens
    assert out[0].logprobs[len(lrec["new_tokens"]):] == \
        ref.logprobs[len(lrec["new_tokens"]):]


def test_snapshot_roundtrip_attention(tmp_path):
    _roundtrip_stack(get_config("tiny"), tmp_path)


@pytest.mark.slow
def test_snapshot_roundtrip_rwkv(tmp_path):
    _roundtrip_stack(get_config("rwkv6-7b").reduced(), tmp_path,
                     max_new=10)


@pytest.mark.slow
def test_snapshot_roundtrip_hybrid(tmp_path):
    _roundtrip_stack(get_config("jamba-v0.1-52b").reduced(), tmp_path,
                     max_new=10)


def test_rebuild_handoff_leaf_count_mismatch(tmp_path):
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, max_slots=2, max_len=64, seed=5)
    eng.add_request(GenRequest("r", [1, 5], max_new_tokens=20,
                               temperature=0.0))
    eng.step()                     # one macro-step: still in flight
    [hf] = eng.snapshot_slots()
    rec = _handoff_record(hf)
    rec["cache_leaves"] = rec["cache_leaves"][:-1]
    snapper = RolloutSnapshotter()
    tmpl_leaves, treedef = jax.tree.flatten(
        model.extract_cache_slot(eng._cache, 0))
    with pytest.raises(ValueError, match="leaf count mismatch"):
        snapper._rebuild_handoff(rec, treedef, tmpl_leaves)


# ---------------------------------------------------------------------------
# live supervisor: runner-scale scenarios
# ---------------------------------------------------------------------------
def _fresh_state():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    return init_train_state(model, jax.random.PRNGKey(0),
                            default_optimizer(1e-3))


def _make_runner_factory(mode="sync", tasks=("game",), max_new=16,
                         max_len=320):
    def make(state):
        cfg = get_config("tiny")
        model = Model(cfg, remat=False)
        opt = default_optimizer(1e-3)
        eng = InferenceEngine(model, state.params, max_slots=8,
                              max_len=max_len, seed=3)
        proxy = LLMProxy([EngineHandle(eng, "local")])
        return LiveRLRunner(
            RunnerConfig(batch_size=4, group_size=2, alpha=2, mode=mode,
                         tasks=tasks, max_new_tokens=max_new,
                         temperature=0.0),
            proxy, state, jax.jit(make_grpo_train_step(model, opt)),
            ServerlessPlatform(), REWARD_FNS["format_bonus"],
            seq_len=max_len)
    return make


@pytest.mark.slow
def test_trainer_restart_parity_and_dedup(tmp_path):
    """Kill-and-restore greedy parity: the restored run trains the same
    trajectory streams as an uninterrupted reference, and no traj_id
    trains twice across the surviving lineage."""
    make = _make_runner_factory("sync")
    S, KILL = 4, 2

    def tap(runner):
        runner._stream = []
        orig = runner._pack
        runner._pack = lambda t: (runner._stream.append(
            [(tuple(x.tokens), round(float(x.reward), 6)) for x in t])
            or orig(t))

    ref = make(_fresh_state())
    tap(ref)
    with ref:
        ref.run_steps(S)

    victim = make(_fresh_state())
    sup = FTSupervisor(victim, FTConfig(snapshot_every=1, keep_last=4),
                       ckpt_dir=str(tmp_path))
    sup.run_steps(KILL)
    sup.snapshotter.wait()
    victim.close()
    sup.close()

    restored, start = restore_latest(str(tmp_path), _fresh_state(), make)
    tap(restored)
    with restored:
        restored.run_steps(S - start)
    assert restored._stream == ref._stream[start:]
    lineage = [i for b in victim.trained_log[:start] for i in b] + \
        [i for b in restored.trained_log for i in b]
    assert len(lineage) == len(set(lineage))
    assert restored.buffer.total_deduped == 0     # cold restore: nothing
    #                                               replays past the frontier


@pytest.mark.slow
def test_restore_latest_corrupt_pair_falls_back(tmp_path):
    make = _make_runner_factory("sync")
    victim = make(_fresh_state())
    sup = FTSupervisor(victim, FTConfig(snapshot_every=1, keep_last=5),
                       ckpt_dir=str(tmp_path))
    sup.run_steps(3)
    sup.snapshotter.wait()
    victim.close()
    sup.close()
    latest = CK.latest_step(str(tmp_path))
    (tmp_path / f"step_{latest:08d}" / "arrays.npz").write_bytes(b"bad")
    log = []
    restored, step = restore_latest(str(tmp_path), _fresh_state(), make,
                                    log=log)
    assert step == latest - 1
    assert any("checkpoint corrupt, falling back" in line for line in log)
    restored.close()


@pytest.mark.slow
def test_engine_failure_supervised_recovery():
    make = _make_runner_factory("rollart", tasks=("math",), max_new=24,
                                max_len=512)
    runner = make(_fresh_state())
    sup = FTSupervisor(runner, FTConfig(snapshot_every=1),
                       injector=FailureInjector(schedule={1: "engine"},
                                                seed=3))
    with runner:
        sup.run_steps(3)
    sup.close()
    assert len(runner.history) == 3
    [ev] = sup.events
    assert ev.kind == "engine" and ev.recovered
    assert runner.proxy.handles[0].engine.crashes == 1
    ids = [i for b in runner.trained_log for i in b]
    assert len(ids) == len(set(ids))


@pytest.mark.slow
def test_engine_failure_reinjects_snapshot_kv():
    """Deterministic reinject-path coverage (regression: recovery once
    dropped the routes it had just re-registered, wedging every
    snapshot-covered request): capture a barrier snapshot while requests
    are mid-flight, advance, crash the engine, recover — the SAME request
    ids must re-home via KV reinjection and then run to completion."""
    make = _make_runner_factory("sync", tasks=("math",), max_new=64,
                                max_len=640)
    runner = make(_fresh_state())
    sup = FTSupervisor(runner, FTConfig(snapshot_every=1),
                       injector=FailureInjector(seed=3))
    try:
        runner._ensure_inflight()
        for _ in range(2):
            runner.proxy.pump()              # mid-generation (64-token
            #                                  actions, K=8 per pump)
        sup.last_snapshot = sup.snapshotter.capture(runner, 0)
        covered = {r["active_req"] for r in sup.last_snapshot.ems
                   if r["active_req"]}
        assert covered, "no request was in flight at the snapshot"
        for _ in range(2):
            runner.proxy.pump()              # work advances PAST it
        ev = sup.inject_and_recover("engine", 0)
        assert runner.proxy.recoveries >= 1, "reinject path not exercised"
        assert ev.recovered and ev.recovered_tokens > 0
        # the re-homed requests must still be routed AND complete
        for rid in ev.lost_rids:
            if rid in covered:
                assert runner.proxy.routed(rid)
        for _ in range(runner.cfg.max_pump_steps):
            if not any(em.state == EMState.GENERATING
                       for em in runner.active):
                break
            runner.proxy.pump()
            runner._drain_completions()
            runner._drain_rewards()
        assert not any(em.state == EMState.GENERATING
                       for em in runner.active), \
            "a recovered request never completed (lost route/callback)"
    finally:
        runner.close()
        sup.close()


@pytest.mark.slow
def test_rollout_plane_loss_recovery_dedups():
    make = _make_runner_factory("rollart", tasks=("math",), max_new=24,
                                max_len=512)
    runner = make(_fresh_state())
    sup = FTSupervisor(runner, FTConfig(snapshot_every=1),
                       injector=FailureInjector(schedule={1: "rollout"},
                                                seed=3))
    with runner:
        sup.run_steps(4)
    sup.close()
    [ev] = sup.events
    assert ev.kind == "rollout" and ev.recovered
    ids = [i for b in runner.trained_log for i in b]
    assert len(ids) == len(set(ids)), "a replayed trajectory trained twice"


@pytest.mark.slow
def test_reward_failure_retried_by_drain():
    make = _make_runner_factory("rollart", tasks=("math",), max_new=24,
                                max_len=512)
    runner = make(_fresh_state())
    sup = FTSupervisor(runner, FTConfig(snapshot_every=1),
                       injector=FailureInjector(schedule={0: "reward",
                                                          1: "reward"},
                                                seed=3))
    with runner:
        sup.run_steps(4)
    sup.close()
    assert len(runner.history) == 4
    assert runner.reward_retries >= 1
    assert all(e.recovered for e in sup.events)

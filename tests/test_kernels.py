"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all asserting allclose against the pure-jnp oracles in repro.kernels.ref,
with kernels executed in interpret mode (CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # CI image without hypothesis: run the property
    from _hyp_compat import given, settings, st   # tests on deterministic
    # fallback examples instead of skipping the whole module

from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.rwkv6_scan import rwkv6_scan

# kernel JIT dominates tier-1 wall time; the fast CI job skips these
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def k(i):
    return jax.random.fold_in(KEY, i)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,kvH,S,hd", [
    (1, 2, 2, 64, 32),      # MHA
    (2, 4, 2, 128, 64),     # GQA 2:1
    (1, 8, 2, 256, 32),     # GQA 4:1
    (2, 6, 1, 64, 128),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, kvH, S, hd, dtype):
    q = jax.random.normal(k(1), (B, H, S, hd), dtype)
    kk = jax.random.normal(k(2), (B, kvH, S, hd), dtype)
    v = jax.random.normal(k(3), (B, kvH, S, hd), dtype)
    o = flash_attention(q, kk, v, block_q=64, block_k=64)
    r = R.flash_ref(q, kk, v)
    tol = 5e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("block_q,block_k", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_blocks(block_q, block_k):
    B, H, kvH, S, hd = 1, 4, 4, 128, 64
    q = jax.random.normal(k(4), (B, H, S, hd))
    kk = jax.random.normal(k(5), (B, kvH, S, hd))
    v = jax.random.normal(k(6), (B, kvH, S, hd))
    o = flash_attention(q, kk, v, block_q=block_q, block_k=block_k)
    np.testing.assert_allclose(np.asarray(o), np.asarray(R.flash_ref(q, kk, v)),
                               atol=5e-6, rtol=5e-6)


def test_flash_non_causal():
    B, H, kvH, S, hd = 1, 2, 2, 64, 32
    q = jax.random.normal(k(7), (B, H, S, hd))
    kk = jax.random.normal(k(8), (B, kvH, S, hd))
    v = jax.random.normal(k(9), (B, kvH, S, hd))
    o = flash_attention(q, kk, v, causal=False, block_q=32, block_k=32)
    r = R.flash_ref(q, kk, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=5e-6,
                               rtol=5e-6)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,kvH,S,hd,bk", [
    (2, 4, 2, 512, 64, 128),
    (3, 8, 2, 256, 32, 64),
    (1, 2, 2, 128, 128, 128),
])
def test_decode_attention(B, H, kvH, S, hd, bk):
    q = jax.random.normal(k(10), (B, H, hd))
    kc = jax.random.normal(k(11), (B, kvH, S, hd))
    vc = jax.random.normal(k(12), (B, kvH, S, hd))
    lengths = jnp.asarray([(S // 2 + 7 * i) % S + 1 for i in range(B)])
    o = decode_attention(q, kc, vc, lengths, block_k=bk)
    r = R.decode_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=5e-6,
                               rtol=5e-6)


@given(length_frac=st.floats(0.05, 1.0), seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_decode_attention_lengths_property(length_frac, seed):
    """Property: masked cache positions never influence the output."""
    B, H, kvH, S, hd = 1, 2, 2, 128, 32
    kp = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(kp, 0), (B, H, hd))
    kc = jax.random.normal(jax.random.fold_in(kp, 1), (B, kvH, S, hd))
    vc = jax.random.normal(jax.random.fold_in(kp, 2), (B, kvH, S, hd))
    length = max(1, int(S * length_frac))
    lengths = jnp.asarray([length])
    o1 = decode_attention(q, kc, vc, lengths, block_k=32)
    # poison the masked region: output must not change
    poison = kc.at[:, :, length:, :].set(1e6)
    poison_v = vc.at[:, :, length:, :].set(-1e6)
    o2 = decode_attention(q, poison, poison_v, lengths, block_k=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 32, 2, 32, 32),
    (2, 96, 3, 32, 32),
    (1, 64, 1, 64, 16),
])
def test_rwkv6_scan(B, S, H, hd, chunk):
    r = jax.random.normal(k(20), (B, S, H, hd))
    kk = jax.random.normal(k(21), (B, S, H, hd))
    v = jax.random.normal(k(22), (B, S, H, hd))
    lw = jnp.clip(-jnp.exp(jax.random.normal(k(23), (B, S, H, hd))),
                  -2.5, -1e-4)
    u = jax.random.normal(k(24), (H, hd)) * 0.5
    y, S_out = rwkv6_scan(r, kk, v, lw, u, chunk=chunk)
    yr, S_ref = R.rwkv6_ref(r, kk, v, lw, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-4,
                               rtol=3e-4)
    np.testing.assert_allclose(np.asarray(S_out), np.asarray(S_ref),
                               atol=3e-4, rtol=3e-4)


@given(seed=st.integers(0, 2 ** 16), chunk=st.sampled_from([8, 16, 32]))
@settings(max_examples=8, deadline=None)
def test_rwkv6_chunk_invariance(seed, chunk):
    """Property: any chunk size within the stability bound (chunk*2.5 < 85)
    matches the sequential oracle. chunk=64 violates the bound and is
    rejected by the kernel's assertion (tested below)."""
    B, S, H, hd = 1, 64, 2, 16
    kp = jax.random.PRNGKey(seed)
    r = jax.random.normal(jax.random.fold_in(kp, 0), (B, S, H, hd))
    kk = jax.random.normal(jax.random.fold_in(kp, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(kp, 2), (B, S, H, hd))
    lw = jnp.clip(-jnp.exp(jax.random.normal(jax.random.fold_in(kp, 3),
                                             (B, S, H, hd))), -2.5, -1e-4)
    u = jnp.zeros((H, hd))
    y1, s1 = rwkv6_scan(r, kk, v, lw, u, chunk=chunk)
    y2, s2 = R.rwkv6_ref(r, kk, v, lw, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-4,
                               rtol=5e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=5e-4,
                               rtol=5e-4)


def test_rwkv6_rejects_unstable_chunk():
    B, S, H, hd = 1, 64, 1, 16
    z = jnp.zeros((B, S, H, hd))
    with pytest.raises(AssertionError):
        rwkv6_scan(z, z, z, z - 1.0, jnp.zeros((H, hd)), chunk=64)


# ---------------------------------------------------------------------------
# mamba
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,di,ds,chunk,bd", [
    (1, 64, 128, 16, 32, 128),
    (2, 128, 256, 16, 32, 64),
    (1, 32, 64, 8, 16, 32),
])
def test_mamba_scan(B, S, di, ds, chunk, bd):
    x = jax.random.normal(k(30), (B, S, di))
    delta = jax.nn.softplus(jax.random.normal(k(31), (B, S, di)) - 2)
    Bm = jax.random.normal(k(32), (B, S, ds))
    Cm = jax.random.normal(k(33), (B, S, ds))
    A_log = jax.random.normal(k(34), (di, ds)) * 0.5
    D = jax.random.normal(k(35), (di,))
    y, h = mamba_scan(x, delta, Bm, Cm, A_log, D, chunk=chunk, block_d=bd)
    yr, hr = R.mamba_ref(x, delta, Bm, Cm, A_log, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5,
                               rtol=1e-5)


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=6, deadline=None)
def test_mamba_state_continuation_property(seed):
    """Property: scanning [0:S] equals scanning [0:S/2] then [S/2:S] with the
    carried state (verified via the oracle's h0 support)."""
    B, S, di, ds = 1, 64, 32, 8
    kp = jax.random.PRNGKey(seed)
    x = jax.random.normal(jax.random.fold_in(kp, 0), (B, S, di))
    delta = jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(kp, 1), (B, S, di)) - 2)
    Bm = jax.random.normal(jax.random.fold_in(kp, 2), (B, S, ds))
    Cm = jax.random.normal(jax.random.fold_in(kp, 3), (B, S, ds))
    A_log = jax.random.normal(jax.random.fold_in(kp, 4), (di, ds)) * 0.3
    D = jnp.zeros((di,))
    y_full, h_full = mamba_scan(x, delta, Bm, Cm, A_log, D, chunk=16,
                                block_d=32)
    half = S // 2
    _, h1 = mamba_scan(x[:, :half], delta[:, :half], Bm[:, :half],
                       Cm[:, :half], A_log, D, chunk=16, block_d=32)
    y2, h2 = R.mamba_ref(x[:, half:], delta[:, half:], Bm[:, half:],
                         Cm[:, half:], A_log, D, h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, half:]), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2), atol=1e-4,
                               rtol=1e-4)

"""SampleBuffer staleness invariants (paper §6.2), incl. hypothesis
property tests:
- no returned trajectory violates start_version >= current - alpha;
- eager eviction bounds buffer growth to O(alpha * E);
- get_batch returns oldest-first and blocks until satisfied.
"""
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # CI image without hypothesis: run the property
    from _hyp_compat import given, settings, st   # tests on deterministic
    # fallback examples instead of skipping the whole module

from repro.core.buffer import SampleBuffer
from repro.data.pipeline import Trajectory


def _traj(i, sv):
    return Trajectory(traj_id=f"t{i}", task="math", tokens=[1, 2],
                      loss_mask=[0, 1], logprobs=[0.0, -1.0],
                      start_version=sv)


def test_basic_put_get():
    buf = SampleBuffer(alpha=1)
    for i in range(4):
        buf.put(_traj(i, 0))
    batch = buf.get_batch(4, timeout=1)
    assert len(batch) == 4
    assert buf.size() == 0


def test_stale_evicted_on_version_advance():
    buf = SampleBuffer(alpha=1)
    buf.put(_traj(0, 0))
    buf.put(_traj(1, 1))
    buf.set_version(2)          # bound: >= 1
    assert buf.size() == 1
    assert buf.total_evicted == 1
    batch = buf.get_batch(1, timeout=1)
    assert batch[0].start_version == 1


def test_stale_put_rejected():
    buf = SampleBuffer(alpha=1)
    buf.set_version(5)
    buf.put(_traj(0, 2))        # 2 < 5 - 1 -> evicted on arrival
    assert buf.size() == 0
    assert buf.total_evicted == 1


def test_oldest_first_ordering():
    buf = SampleBuffer(alpha=8)
    for i, sv in enumerate([3, 1, 2, 1]):
        buf.put(_traj(i, sv))
    batch = buf.get_batch(2, timeout=1)
    assert [t.start_version for t in batch] == [1, 1]


def test_get_batch_blocks_until_filled():
    buf = SampleBuffer(alpha=1)
    out = {}

    def consumer():
        out["batch"] = buf.get_batch(2, timeout=5)

    th = threading.Thread(target=consumer)
    th.start()
    time.sleep(0.05)
    buf.put(_traj(0, 0))
    buf.put(_traj(1, 0))
    th.join(timeout=5)
    assert len(out["batch"]) == 2


def test_get_batch_timeout():
    buf = SampleBuffer(alpha=1)
    with pytest.raises(TimeoutError):
        buf.get_batch(1, timeout=0.05)


@given(alpha=st.integers(0, 3),
       events=st.lists(st.tuples(st.sampled_from(["put", "bump"]),
                                 st.integers(0, 3)), min_size=1,
                       max_size=60))
@settings(max_examples=60, deadline=None)
def test_staleness_invariant_property(alpha, events):
    """After any interleaving of puts and version bumps, every buffered
    trajectory satisfies the alpha bound and nothing valid was dropped."""
    buf = SampleBuffer(alpha=alpha)
    version = 0
    i = 0
    for kind, arg in events:
        if kind == "put":
            sv = max(0, version - arg)
            buf.put(_traj(i, sv))
            i += 1
        else:
            version += arg
            buf.set_version(version)
        # invariant: everything in the buffer is within the bound
        with buf._lock:
            for t in buf._items:
                assert t.start_version >= version - alpha
    # bounded growth: O(alpha * E) with E = puts
    assert buf.size() <= i


@given(n_envs=st.integers(1, 16), alpha=st.integers(0, 2))
@settings(max_examples=25, deadline=None)
def test_buffer_bound_property(n_envs, alpha):
    """With E concurrent producers each holding at most one pending
    trajectory per version, the buffer never exceeds (alpha+1) * E."""
    buf = SampleBuffer(alpha=alpha)
    i = 0
    for version in range(6):
        buf.set_version(version)
        for e in range(n_envs):
            buf.put(_traj(i, version))
            i += 1
        assert buf.size() <= (alpha + 1) * n_envs

"""Substrate unit tests: losses, optimizer, tokenizer, data pipeline,
checkpointing, environments, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # CI image without hypothesis: run the property
    from _hyp_compat import given, settings, st   # tests on deterministic
    # fallback examples instead of skipping the whole module

from repro.checkpoint import checkpointer as CK
from repro.configs import get_config
from repro.data.pipeline import (Trajectory, group_advantages, lm_batches,
                                 pack_batch)
from repro.data.tokenizer import ByteTokenizer
from repro.distributed.sharding import (TRAIN_RULES, fit_spec,
                                        logical_axes_for_path, resolve_spec)
from repro.envs import ENV_CLASSES, make_env
from repro.models import Model
from repro.optim.adamw import AdamW, constant, warmup_cosine
from repro.rl import losses as LO


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def test_grpo_zero_advantage_zero_grad():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 16)
    mask = jnp.ones((2, 8))
    blp = LO.token_logprobs(logits, toks)

    def loss(lg):
        return LO.grpo_loss(lg, toks, mask, jnp.zeros((2,)), blp)[0]

    g = jax.grad(loss)(logits)
    np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-7)


def test_grpo_sign():
    """Positive advantage must push the sampled tokens' logprobs up."""
    logits = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 16))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, 16)
    mask = jnp.ones((1, 8))
    blp = LO.token_logprobs(logits, toks)

    def lp_sum(lg):
        return LO.token_logprobs(lg, toks).sum()

    def loss(lg, adv):
        return LO.grpo_loss(lg, toks, mask, adv, blp)[0]

    g = jax.grad(loss)(logits, jnp.asarray([1.0]))
    dlp = jax.grad(lp_sum)(logits)
    # gradient descent direction increases logprob of chosen tokens
    assert float(jnp.sum(-g * dlp)) > 0


def test_group_normalized_advantages():
    r = jnp.asarray([1.0, 0.0, 1.0, 0.0, 5.0, 5.0, 5.0, 5.0])
    a = LO.group_normalized_advantages(r, group_size=4)
    assert float(jnp.abs(a[:4].sum())) < 1e-5
    np.testing.assert_allclose(np.asarray(a[4:]), 0.0, atol=1e-4)


def test_lm_loss_decreases_with_training():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    opt = AdamW(lr=constant(5e-3))
    from repro.rl.trainer import init_train_state, make_lm_train_step
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_lm_train_step(model, opt))
    tok = ByteTokenizer()
    batch = next(lm_batches(tok, seq_len=64, batch=4, n_steps=1))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_converges_quadratic():
    opt = AdamW(lr=constant(0.1), weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), 0.0, atol=1e-2)


def test_grad_clip():
    opt = AdamW(lr=constant(0.0), clip_norm=1.0)
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.full((3,), 100.0)}, state, params)
    assert float(gnorm) > 100.0  # reported pre-clip norm


def test_warmup_cosine_schedule():
    f = warmup_cosine(1.0, warmup=10, total=100)
    assert float(f(jnp.asarray(0))) == 0.0
    assert float(f(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(f(jnp.asarray(100))) == pytest.approx(0.0, abs=0.01)


# ---------------------------------------------------------------------------
# tokenizer / data
# ---------------------------------------------------------------------------
@given(st.text(max_size=200))
@settings(max_examples=50, deadline=None)
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(text)) == text


def test_pack_batch_alignment():
    t = Trajectory(traj_id="t", task="math", tokens=[1, 2, 3, 4],
                   loss_mask=[0, 0, 1, 1], logprobs=[0, 0, -1.5, -2.5],
                   reward=1.0)
    b = pack_batch([t], seq_len=6)
    assert b["tokens"].tolist() == [[1, 2, 3, 4, 0, 0]]
    assert b["loss_mask"].tolist() == [[0, 0, 1, 1, 0, 0]]
    # behavior logprobs align with tokens[:,1:]
    assert b["behavior_logprobs"][0].tolist() == [0.0, -1.5, -2.5, 0.0, 0.0]


def test_group_advantages_numpy():
    trajs = [Trajectory(traj_id=str(i), task="m", tokens=[1],
                        loss_mask=[1], logprobs=[0.0], reward=float(i % 2))
             for i in range(4)]
    a = group_advantages(trajs, group_size=2)
    assert a.shape == (4,)
    assert abs(a[:2].sum()) < 1e-5


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    CK.save(str(tmp_path), tree, step=7)
    CK.save(str(tmp_path), tree, step=9)
    assert CK.latest_step(str(tmp_path)) == 9
    restored, step = CK.restore(str(tmp_path), tree)
    assert step == 9
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))


def test_checkpoint_shape_mismatch(tmp_path):
    CK.save(str(tmp_path), {"a": jnp.zeros((2,))}, step=0)
    with pytest.raises(ValueError):
        CK.restore(str(tmp_path), {"a": jnp.zeros((3,))})


# ---------------------------------------------------------------------------
# environments
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("task", sorted(ENV_CLASSES))
def test_env_episode_terminates(task):
    env = make_env(task, seed=0)
    obs = env.reset()
    assert isinstance(obs, str) and obs
    steps = 0
    done = False
    while not done and steps < env.MAX_TURNS + 2:
        obs, r, done, info = env.step("answer: 0")
        steps += 1
    assert done


def test_env_latency_profile_sampling():
    import random
    env = make_env("swe", 0)
    rng = random.Random(0)
    ts = [env.LATENCY.sample_reset(rng)[0] for _ in range(500)]
    assert min(ts) > 0
    assert max(ts) > 50          # heavy tail present


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
def test_param_rules_match_paths():
    axes = logical_axes_for_path(
        (jax.tree_util.DictKey("layers"), jax.tree_util.SequenceKey(0),
         jax.tree_util.DictKey("attn"), jax.tree_util.DictKey("wq")), 4)
    assert axes == (None, "qkv_in", "heads", None)


def test_fit_spec_drops_nondivisible():
    import numpy as _np

    class FakeMesh:
        axis_names = ("data", "model")
        devices = _np.empty((4, 8))

    spec = resolve_spec(("batch", "heads"),
                        {"batch": ("pod", "data"), "heads": "model"},
                        None)  # no mesh: all None
    assert spec == P()
    m = FakeMesh()
    fitted = fit_spec((6, 24), P("data", "model"), m)
    assert fitted == P(None, "model")          # 6 % 4 != 0 -> dropped
    fitted2 = fit_spec((8, 24), P("data", "model"), m)
    assert fitted2 == P("data", "model")

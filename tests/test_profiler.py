"""Online affinity profiler (paper §9 extension): classification,
hysteresis, live re-routing of an LLMProxy, and drift adaptation."""
import jax
import pytest

from repro.configs import get_config
from repro.core import EngineHandle, LLMProxy
from repro.core.profiler import AffinityProfiler
from repro.models import Model
from repro.rl.engine import InferenceEngine


def feed(prof, tag, prefill, decode, turns, n):
    for _ in range(n):
        prof.observe(tag, prefill, decode, turns)


def test_classification():
    prof = AffinityProfiler()
    feed(prof, "math", prefill=120, decode=6000, turns=3, n=12)
    feed(prof, "swe", prefill=20000, decode=3000, turns=40, n=12)
    assert prof.pool_for("math") == "H20"
    assert prof.pool_for("swe") == "H800"
    aff = prof.hw_affinity()
    assert aff["math"] == "H20" and aff["swe"] == "H800"


def test_min_samples_and_hysteresis():
    prof = AffinityProfiler(min_samples=8, stability_windows=2)
    feed(prof, "t", 100, 5000, 2, n=7)
    assert prof.pool_for("t") is None            # not enough samples
    feed(prof, "t", 100, 5000, 2, n=1)
    assert prof.pool_for("t") is None            # classified, not stable yet
    feed(prof, "t", 100, 5000, 2, n=3)
    assert prof.pool_for("t") == "H20"


def test_drift_reroutes_with_hysteresis():
    """A domain alternating between profiles (the §9 scenario) only
    re-routes after the new profile is stable."""
    prof = AffinityProfiler(ewma=0.5, stability_windows=2)
    feed(prof, "t", 100, 8000, 2, n=12)
    assert prof.pool_for("t") == "H20"
    # drift to prefill-heavy: EWMA shifts, class flips, stability resets
    feed(prof, "t", 20000, 500, 30, n=2)
    assert prof.pool_for("t") is None            # in flux: no routing claim
    feed(prof, "t", 20000, 500, 30, n=4)
    assert prof.pool_for("t") == "H800"


def test_apply_to_proxy_reroutes_live():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    e1 = InferenceEngine(model, params, max_slots=2, max_len=64, seed=1)
    e2 = InferenceEngine(model, params, max_slots=2, max_len=64, seed=2)
    proxy = LLMProxy([EngineHandle(e1, "H800"), EngineHandle(e2, "H20")],
                     hw_affinity={"default": "H800"})
    prof = AffinityProfiler()
    feed(prof, "chat", prefill=50, decode=4000, turns=1, n=12)
    mapping = prof.apply_to(proxy)
    assert mapping["chat"] == "H20"
    assert proxy.hw_affinity["chat"] == "H20"
    assert proxy._select("chat").pool == "H20"

"""Live prefill/decode disaggregation (§6.3) + engine correctness fixes:

- greedy parity: a request served colocated and through the
  prefill -> KV-handoff -> decode path emits identical tokens;
- per-pool counters: prefill tokens land only on the prefill pool,
  decode tokens only on the decode pool;
- suspend/update/resume and ABORT semantics survive the handoff;
- per-slot temperature in batched decode (mixed-temperature batches);
- ABORTs drain past a head-of-line-blocked ADD;
- SampleBuffer FIFO uses a numeric sequence, not lexicographic traj_id;
- redundancy cancellation aborts only the surplus beyond headroom.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import EMState, EngineHandle, LLMProxy, build_pd_proxy
from repro.core.buffer import SampleBuffer
from repro.core.scheduler import LiveRLRunner, RunnerConfig
from repro.data.pipeline import Trajectory
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_colocated(model, params, prompt, n, max_len=96):
    eng = InferenceEngine(model, params, max_slots=2, max_len=max_len)
    eng.add_request(GenRequest(request_id="ref", prompt=list(prompt),
                               max_new_tokens=n, temperature=0.0))
    eng.run_until_idle()
    return eng.pop_result("ref").tokens


def _serve(proxy, reqs, max_pumps=2000):
    out = {}
    for r in reqs:
        proxy.submit(r, callback=lambda res: out.__setitem__(
            res.request_id, res))
    pumps = 0
    while proxy.busy:
        proxy.pump()
        pumps += 1
        assert pumps < max_pumps, "proxy did not drain"
    return out


# ---------------------------------------------------------------------------
# tentpole: KV handoff parity + pool counters
# ---------------------------------------------------------------------------
def test_pd_greedy_parity_and_pool_counters(tiny_setup):
    cfg, model, params = tiny_setup
    prompts = [[1, 5, 7, 9], [1, 2, 3], [1, 9, 9, 4, 2]]
    proxy = build_pd_proxy(model, params, max_slots=4, max_len=96, seed=7)
    reqs = [GenRequest(request_id=f"r{i}", prompt=p, max_new_tokens=6,
                       temperature=0.0) for i, p in enumerate(prompts)]
    out = _serve(proxy, reqs)
    for i, p in enumerate(prompts):
        assert out[f"r{i}"].tokens == _greedy_colocated(model, params, p, 6)
        assert out[f"r{i}"].finish_reason in ("stop", "length")
    stats = proxy.stats()
    assert stats["handoffs"] == 3
    by_role = {e["role"]: e for e in stats["engines"]}
    assert by_role["prefill"]["prefill_tokens"] == sum(map(len, prompts))
    assert by_role["prefill"]["decode_tokens"] == 0
    assert by_role["decode"]["prefill_tokens"] == 0
    assert by_role["decode"]["decode_tokens"] > 0


def test_pd_suspend_update_resume_across_handoff(tiny_setup):
    """Weight-sync protocol on the disaggregated plane: suspending,
    re-publishing the same weights as v1 (cache recompute included), and
    resuming must not change the greedy token stream."""
    cfg, model, params = tiny_setup
    proxy = build_pd_proxy(model, params, max_slots=2, max_len=96, seed=11)
    out = {}
    # long enough that two macro-step pumps (default steps_per_dispatch=8)
    # leave the request mid-flight when the weight sync fires
    n_new = 24
    proxy.submit(GenRequest(request_id="x", prompt=[1, 4, 2],
                            max_new_tokens=n_new, temperature=0.0),
                 callback=lambda r: out.__setitem__(r.request_id, r))
    proxy.pump()           # prefill + handoff + first decode macro-step
    proxy.pump()
    proxy.suspend()
    proxy.update_all(params, version=1, recompute_caches=True)
    proxy.resume()
    pumps = 0
    while proxy.busy:
        proxy.pump()
        pumps += 1
        assert pumps < 200
    assert out["x"].tokens == _greedy_colocated(model, params, [1, 4, 2],
                                                n_new)
    assert out["x"].weight_version == 1


def test_pd_abort_midflight_and_during_migration(tiny_setup):
    cfg, model, params = tiny_setup
    proxy = build_pd_proxy(model, params, max_slots=2, max_len=96, seed=13)
    out = {}
    # abort while decoding on the decode engine
    proxy.submit(GenRequest(request_id="a", prompt=[1, 2],
                            max_new_tokens=40, temperature=1.0),
                 callback=lambda r: out.__setitem__(r.request_id, r))
    proxy.pump()
    proxy.pump()
    proxy.abort("a")
    while proxy.busy:
        proxy.pump()
    assert out["a"].finish_reason == "aborted"
    assert len(out["a"].tokens) < 40
    # abort before the first pump: resolved at/with the handoff, never
    # reaching the decode pool
    proxy.submit(GenRequest(request_id="b", prompt=[1, 3],
                            max_new_tokens=40, temperature=1.0),
                 callback=lambda r: out.__setitem__(r.request_id, r))
    proxy.abort("b")
    pumps = 0
    while proxy.busy:
        proxy.pump()
        pumps += 1
        assert pumps < 100
    assert out["b"].finish_reason == "aborted"


def test_stale_handoff_recomputed_on_inject(tiny_setup):
    """A KVHandoff that crosses a weight sync while queued (protocol step
    (5) only recomputes ACTIVE slots) must be re-prefilled under the new
    weights at injection, not decoded against its stale cache."""
    import jax.numpy as jnp
    cfg, model, params = tiny_setup
    params2 = model.init(jax.random.PRNGKey(42))
    handoffs = []
    prefill = InferenceEngine(model, params, max_slots=1, max_len=96,
                              role="prefill", on_handoff=handoffs.append)
    prefill.add_request(GenRequest(request_id="s", prompt=[1, 3, 5],
                                   max_new_tokens=6, temperature=0.0))
    prefill.step()
    (h,) = handoffs
    decode = InferenceEngine(model, params, max_slots=1, max_len=96,
                             role="decode")
    decode.update_params(params2, version=1)   # sync BEFORE the inject
    decode.inject(h)
    decode.run_until_idle()
    res = decode.pop_result("s")
    # expected: greedy continuation of (prompt + v0 first token) computed
    # entirely under params2
    prefix = list(h.tokens)
    cache = model.init_cache(1, 96)
    lg, cache = model.prefill(params2, jnp.asarray([prefix]), cache)
    expect = []
    pos = len(prefix)
    for _ in range(5):
        nt = int(jnp.argmax(lg[0]))
        expect.append(nt)
        lg, cache = model.decode_step(params2, jnp.asarray([[nt]]), cache,
                                      jnp.asarray([pos]))
        pos += 1
    assert res.tokens[1:] == expect
    assert res.weight_version == 1


def test_pd_finish_at_prefill(tiny_setup):
    """max_new_tokens=1 completes on the prefill engine — no handoff."""
    cfg, model, params = tiny_setup
    proxy = build_pd_proxy(model, params, max_slots=2, max_len=96, seed=17)
    out = _serve(proxy, [GenRequest(request_id="one", prompt=[1, 5, 7],
                                    max_new_tokens=1, temperature=0.0)])
    ref = _greedy_colocated(model, params, [1, 5, 7], 1)
    assert out["one"].tokens == ref
    assert proxy.stats()["handoffs"] == 0


def test_cache_slot_extract_inject_roundtrip(tiny_setup):
    cfg, model, params = tiny_setup
    cache = model.init_cache(4, 64)
    lg, cache = model.prefill(params, jax.numpy.asarray([[1, 5, 7, 9],
                                                         [2, 6, 8, 3],
                                                         [0, 0, 0, 0],
                                                         [0, 0, 0, 0]]),
                              cache)
    slot1 = model.extract_cache_slot(cache, 1)
    dst = model.init_cache(4, 64)
    dst = model.inject_cache_slot(dst, slot1, 3)
    for a, b in zip(jax.tree.leaves(slot1),
                    jax.tree.leaves(model.extract_cache_slot(dst, 3))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# satellite: per-slot temperature
# ---------------------------------------------------------------------------
def test_per_slot_temperature_in_batched_decode(tiny_setup):
    """A greedy (temperature=0) slot must stay greedy even when it shares
    the batched decode with a hot slot admitted later (previously the LAST
    active slot's temperature was applied to every slot)."""
    cfg, model, params = tiny_setup
    eng = InferenceEngine(model, params, max_slots=2, max_len=96, seed=5)
    # cold first (slot 0), hot second (slot 1): pre-fix the hot slot's
    # temperature would override the cold slot's greedy sampling
    eng.add_request(GenRequest(request_id="cold", prompt=[1, 5, 7, 9],
                               max_new_tokens=8, temperature=0.0))
    eng.add_request(GenRequest(request_id="hot", prompt=[1, 2, 3],
                               max_new_tokens=8, temperature=3.0))
    eng.run_until_idle()
    cold = eng.pop_result("cold")
    assert cold.tokens == _greedy_colocated(model, params, [1, 5, 7, 9], 8)


# ---------------------------------------------------------------------------
# satellite: ABORT drains past a blocked ADD
# ---------------------------------------------------------------------------
def test_abort_drains_behind_blocked_add(tiny_setup):
    cfg, model, params = tiny_setup
    eng = InferenceEngine(model, params, max_slots=1, max_len=96)
    eng.add_request(GenRequest(request_id="a", prompt=[1, 2],
                               max_new_tokens=40, temperature=1.0))
    eng.step()                 # admit "a": the only slot is now busy
    eng.add_request(GenRequest(request_id="b", prompt=[1, 3],
                               max_new_tokens=4, temperature=1.0))
    eng.abort("a")             # queued BEHIND the blocked ADD
    eng.step()                 # ADD "b" still blocked, ABORT must drain
    res = eng.pop_result("a")
    assert res is not None and res.finish_reason == "aborted"
    eng.run_until_idle()
    assert eng.pop_result("b").finish_reason in ("stop", "length")


def test_oversized_request_rejected_not_wedged(tiny_setup):
    """An ADD that can never fit (prompt + max_new_tokens > max_len) must
    unwind immediately instead of deferring forever and head-of-line
    blocking the engine."""
    cfg, model, params = tiny_setup
    eng = InferenceEngine(model, params, max_slots=2, max_len=32)
    eng.add_request(GenRequest(request_id="big", prompt=[1] * 20,
                               max_new_tokens=20, temperature=1.0))
    eng.add_request(GenRequest(request_id="ok", prompt=[1, 2],
                               max_new_tokens=4, temperature=1.0))
    eng.run_until_idle(max_steps=200)
    assert eng.pop_result("big").finish_reason == "aborted"
    assert eng.pop_result("ok").finish_reason in ("stop", "length")


def test_abort_of_pending_add_emits_result(tiny_setup):
    """Aborting a request that was never admitted still produces an
    'aborted' GenResult so the proxy/EnvManager callback chain unwinds."""
    cfg, model, params = tiny_setup
    eng = InferenceEngine(model, params, max_slots=1, max_len=96)
    eng.add_request(GenRequest(request_id="a", prompt=[1, 2],
                               max_new_tokens=30, temperature=1.0))
    eng.step()
    eng.add_request(GenRequest(request_id="b", prompt=[1, 3],
                               max_new_tokens=4, temperature=1.0))
    eng.abort("b")
    eng.step()
    res = eng.pop_result("b")
    assert res is not None
    assert res.finish_reason == "aborted" and res.tokens == []
    eng.abort("a")
    eng.run_until_idle()


# ---------------------------------------------------------------------------
# satellite: FIFO buffer ordering
# ---------------------------------------------------------------------------
def _traj(tid, sv=0):
    return Trajectory(traj_id=tid, task="math", tokens=[1, 2],
                      loss_mask=[0, 1], logprobs=[0.0, -1.0],
                      start_version=sv)


def test_buffer_fifo_is_numeric_not_lexicographic():
    buf = SampleBuffer(alpha=8)
    for tid in ["t2", "t10", "t1"]:     # lexicographic would give t1,t10,t2
        buf.put(_traj(tid))
    batch = buf.get_batch(3, timeout=1)
    assert [t.traj_id for t in batch] == ["t2", "t10", "t1"]


def test_buffer_fifo_within_version():
    buf = SampleBuffer(alpha=8)
    buf.put(_traj("t9", sv=1))
    buf.put(_traj("t10", sv=0))
    buf.put(_traj("t2", sv=0))
    batch = buf.try_get_batch(3)
    assert [t.traj_id for t in batch] == ["t10", "t2", "t9"]


# ---------------------------------------------------------------------------
# satellite: redundancy cancels only the surplus
# ---------------------------------------------------------------------------
class _FakeEM:
    def __init__(self, turns):
        self.state = EMState.GENERATING
        self.turns = turns
        self.aborted = False

    def abort(self):
        self.aborted = True


def test_cancel_surplus_keeps_headroom():
    runner = LiveRLRunner.__new__(LiveRLRunner)   # logic-only instance
    runner.cfg = RunnerConfig(batch_size=4, group_size=2, redundancy=1.5)
    ems = [_FakeEM(t) for t in [5, 0, 3, 1, 4, 2, 7, 6]]
    runner.active = list(ems)
    runner._cancel_surplus()
    aborted = [em for em in ems if em.aborted]
    # headroom = ceil(4 * 1.5) = 6 -> exactly 2 of 8 cancelled, slowest
    # (fewest turns) first
    assert len(aborted) == 2
    assert sorted(em.turns for em in aborted) == [0, 1]


def test_cancel_surplus_noop_within_headroom():
    runner = LiveRLRunner.__new__(LiveRLRunner)
    runner.cfg = RunnerConfig(batch_size=4, group_size=2, redundancy=2.0)
    ems = [_FakeEM(t) for t in range(5)]          # 5 <= ceil(4*2) = 8
    runner.active = list(ems)
    runner._cancel_surplus()
    assert not any(em.aborted for em in ems)

"""Paged KV decode plane: allocator/prefix-cache invariants (hypothesis),
greedy byte-parity paged-vs-dense on the live engine (single device and
TP groups 1/2/4), PD handoff across unequal sharded groups, FT
snapshot/restore, admission-time rejection, and the ragged paged decode
kernel against its gathered-dense oracle.

The TP tests need >= 8 host devices; run with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set below when
this module is the first jax importer, e.g. a standalone pytest run).
"""
import os
import sys

if "jax" not in sys.modules:      # must precede the first jax import
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # CI image without hypothesis: run the property
    from _hyp_compat import given, settings, st   # tests on deterministic
    # fallback examples instead of skipping the whole module

from repro.configs import get_config
from repro.core import build_pd_proxy
from repro.kernels import ref as R
from repro.kernels.decode_attention import ragged_paged_decode
from repro.launch.mesh import allocate_engine_devices, make_group_mesh
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine
from repro.rl.paged_kv import PagedKVAllocator, PageLeakError, PrefixCache

PAGE = 8

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


# ---------------------------------------------------------------------------
# allocator + prefix cache invariants
# ---------------------------------------------------------------------------
def test_alloc_is_all_or_nothing():
    a = PagedKVAllocator(4, PAGE)
    got = a.alloc(3)
    assert got is not None and len(got) == 3
    assert a.alloc(2) is None            # only 1 left: nothing handed out
    assert a.free_pages == 1
    a.decref(got)
    assert a.free_pages == 4
    a.check(external_refs={})


def test_cow_exclusive_shared_and_exhausted():
    a = PagedKVAllocator(2, PAGE)
    [p] = a.alloc(1)
    assert a.cow(p) == p                 # exclusive: same page back
    a.incref([p])                        # now shared (2 holders)
    q = a.cow(p)
    assert q is not None and q != p      # writer got a private copy
    assert a.refcount(p) == 1 and a.refcount(q) == 1
    a.incref([p])
    assert a.cow(p) is None              # shared + pool exhausted
    a.decref([p, p, q])
    a.check(external_refs={})


def test_refcount_misuse_raises():
    a = PagedKVAllocator(2, PAGE)
    [p] = a.alloc(1)
    a.decref([p])
    with pytest.raises(PageLeakError):
        a.decref([p])
    with pytest.raises(PageLeakError):
        a.incref([p])
    with pytest.raises(PageLeakError):
        a.cow(p)


def test_dirty_since_tracks_allocated_writes_only():
    a = PagedKVAllocator(4, PAGE)
    pids = a.alloc(2)
    base = a.clock()
    a.note_write(pids)
    assert sorted(a.dirty_since(base)) == sorted(pids)
    assert a.dirty_since(a.clock()) == []
    a.decref([pids[0]])                  # freed page: contents are dead
    assert a.dirty_since(base) == [pids[1]]
    a.decref([pids[1]])


@settings(deadline=None, max_examples=60)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=3),
                          st.integers(min_value=0, max_value=5)),
                min_size=1, max_size=40))
def test_allocator_invariants_under_random_ops(ops):
    """Random alloc/incref/decref/cow traffic against a shadow holder
    ledger: ``check(external_refs)`` must hold after every op."""
    a = PagedKVAllocator(6, PAGE)
    holders = {}                         # pid -> how many refs WE hold

    def live():
        return [p for p, n in holders.items() if n > 0]

    for op, arg in ops:
        if op == 0:                      # alloc(arg)
            pids = a.alloc(arg)
            if pids is not None:
                for p in pids:
                    holders[p] = holders.get(p, 0) + 1
        elif op == 1 and live():         # incref one live page
            p = live()[arg % len(live())]
            a.incref([p])
            holders[p] += 1
        elif op == 2 and live():         # decref one live page
            p = live()[arg % len(live())]
            a.decref([p])
            holders[p] -= 1
        elif op == 3 and live():         # cow one live page
            p = live()[arg % len(live())]
            q = a.cow(p)
            if q is not None and q != p:
                holders[p] -= 1
                holders[q] = holders.get(q, 0) + 1
        a.check(external_refs={p: n for p, n in holders.items() if n > 0})
    a.decref([p for p in holders for _ in range(holders[p])])
    a.check(external_refs={})


def test_prefix_cache_match_insert_evict():
    a = PagedKVAllocator(8, 2)
    c = PrefixCache(a, page_size=2)
    toks = [1, 2, 3, 4, 5]               # 2 full pages + 1-token tail
    pids = a.alloc(3)
    c.insert(toks, pids)
    assert c.cached_pages == 2           # tail page never cached
    assert c.match(toks) == pids[:2]
    assert c.match([1, 2, 9, 9]) == pids[:1]
    assert c.match([7, 7]) == []
    # cache + our table each hold a ref; dropping ours keeps pages alive
    a.decref(pids)
    a.check(external_refs={p: 1 for p in c.page_ids()})
    # LRU leaf eviction unwinds child-first and frees to the pool
    freed = c.evict(1)
    assert freed == 1 and c.cached_pages == 1
    c.clear()
    a.check(external_refs={})
    assert a.free_pages == a.num_pages


def test_prefix_cache_existing_nodes_win():
    a = PagedKVAllocator(8, 2)
    c = PrefixCache(a, page_size=2)
    first = a.alloc(2)
    c.insert([1, 2, 3, 4], first)
    second = a.alloc(2)
    c.insert([1, 2, 3, 4], second)       # same tokens, different pages
    assert c.match([1, 2, 3, 4]) == first
    a.decref(first)
    a.decref(second)
    c.clear()
    a.check(external_refs={})


# ---------------------------------------------------------------------------
# engine: paged vs dense byte-parity (single device)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    return model, model.init(jax.random.PRNGKey(0))


def _engine(model, params, paged, *, mesh=None, slots=4, max_len=64, k=4,
            seed=3, role="colocated"):
    return InferenceEngine(model, params, max_slots=slots, max_len=max_len,
                           seed=seed, steps_per_dispatch=k, role=role,
                           mesh=mesh, paged=paged, page_size=PAGE)


def _serve(eng, prompts, max_new=10, temperature=0.0):
    for j, p in enumerate(prompts):
        eng.add_request(GenRequest(request_id=f"r{j}", prompt=list(p),
                                   max_new_tokens=max_new,
                                   temperature=temperature))
    eng.run_until_idle()
    return [eng.pop_result(f"r{j}") for j in range(len(prompts))]


PROMPTS = [[1, 5, 7, 9], [2, 4, 6, 8, 10, 12, 3], [9, 9, 1], [3] * 17]


def test_greedy_parity_paged_vs_dense(tiny):
    model, params = tiny
    dense = _serve(_engine(model, params, False), PROMPTS)
    paged = _serve(_engine(model, params, True), PROMPTS)
    for d, p in zip(dense, paged):
        assert p.tokens == d.tokens
        assert p.logprobs == d.logprobs


def test_prefix_fork_parity_and_stats(tiny):
    model, params = tiny
    shared = list(range(1, 25))          # 3 full pages of 8 + 0-token tail
    eng = _engine(model, params, True)
    paged = _serve(eng, [shared, shared])
    dense = _serve(_engine(model, params, False), [shared, shared])
    assert [r.tokens for r in paged] == [r.tokens for r in dense]
    # the fork's TAIL prefill runs a different matmul shape (8 queries x
    # 24 keys) than the dense full prefill, so its logprob bits depend on
    # XLA:CPU reduction tiling (this module's 8-virtual-device flag
    # changes it); token streams must still match exactly
    for pr, dr in zip(paged, dense):
        np.testing.assert_allclose(pr.logprobs, dr.logprobs,
                                   rtol=1e-5, atol=1e-5)
    stt = eng.stats()
    assert stt["shared_prefix_tokens"] >= 2 * PAGE
    assert stt["prefix_hits"] >= 1
    # after drain only the prefix cache holds pages
    eng._alloc.check(external_refs={p: 1 for p in eng._prefix.page_ids()})


def test_too_long_rejected_at_submit(tiny):
    model, params = tiny
    eng = InferenceEngine(model, params, max_slots=2, max_len=32, seed=0,
                          paged=True, page_size=PAGE)
    eng.add_request(GenRequest(request_id="big", prompt=list(range(1, 31)),
                               max_new_tokens=20, temperature=0.0))
    r = eng.pop_result("big")
    assert r is not None and r.finish_reason == "aborted"
    assert eng.stats()["rejected_too_long"] == 1
    # an admissible request on the same engine still serves normally
    [ok] = _serve(eng, [[1, 2, 3]], max_new=4)
    assert len(ok.tokens) == 4


def test_dense_engine_also_rejects_too_long(tiny):
    model, params = tiny
    eng = InferenceEngine(model, params, max_slots=2, max_len=16, seed=0)
    eng.add_request(GenRequest(request_id="big", prompt=list(range(1, 15)),
                               max_new_tokens=10, temperature=0.0))
    r = eng.pop_result("big")
    assert r is not None and r.finish_reason == "aborted"
    assert eng.stats()["rejected_too_long"] == 1


def test_crash_resets_pool_bookkeeping(tiny):
    model, params = tiny
    eng = _engine(model, params, True)
    eng.add_request(GenRequest(request_id="c", prompt=[1, 2, 3, 4, 5],
                               max_new_tokens=20, temperature=0.0))
    eng.step()
    assert eng._alloc.used_pages > 0
    eng.crash()
    eng._alloc.check(external_refs={})
    assert eng.stats()["free_pages"] == eng.num_pages
    assert eng.stats()["prefix_cached_pages"] == 0


def test_midflight_weight_sync_parity(tiny):
    model, params = tiny

    def sync_run(paged):
        eng = _engine(model, params, paged, slots=2, seed=7, k=2)
        eng.add_request(GenRequest(request_id="r",
                                   prompt=list(range(1, 25)),
                                   max_new_tokens=16, temperature=0.0))
        for _ in range(3):
            eng.step()
        eng.update_params(jax.tree.map(lambda x: x * 1.01, params), 1)
        eng.run_until_idle()
        return eng.pop_result("r")

    d, p = sync_run(False), sync_run(True)
    assert p.tokens == d.tokens and p.logprobs == d.logprobs


def test_incremental_capture_shrinks_when_idle(tiny):
    model, params = tiny
    eng = _engine(model, params, True, slots=2, k=2, seed=11)
    eng.add_request(GenRequest(request_id="c", prompt=list(range(1, 10)),
                               max_new_tokens=40, temperature=0.0))
    eng.step()
    cap1 = eng.capture_kv_incremental()
    assert cap1["captured_bytes"] > 0 and cap1["slots"]
    eng.step()
    cap2 = eng.capture_kv_incremental()
    # a 2-token block dirties at most one fresh page per leaf: strictly
    # fewer bytes than the post-prefill capture
    assert 0 < cap2["captured_bytes"] < cap1["captured_bytes"]
    eng.run_until_idle()


def test_snapshot_restore_paged_engine(tiny):
    """Kill a paged engine mid-flight; the KVHandoff snapshot (dense
    portable format) re-injects into a fresh paged engine and finishes
    byte-identically."""
    model, params = tiny
    [ref] = _serve(_engine(model, params, False, slots=2, max_len=96,
                           seed=0), [PROMPTS[0]], max_new=24)
    eng = _engine(model, params, True, slots=2, max_len=96, seed=0, k=4)
    eng.add_request(GenRequest(request_id="r0", prompt=list(PROMPTS[0]),
                               max_new_tokens=24, temperature=0.0))
    eng.step()
    eng.step()                           # mid-flight
    [hf] = eng.snapshot_slots()
    assert isinstance(jax.tree.leaves(hf.cache)[0], np.ndarray)
    eng.crash()
    dst = _engine(model, params, True, slots=2, max_len=96, seed=0, k=4)
    dst.inject(hf)
    dst.run_until_idle()
    assert dst.pop_result("r0").tokens == ref.tokens


def test_pd_handoff_parity_single_device(tiny):
    model, params = tiny

    def pd(paged):
        pre = _engine(model, params, paged, slots=1, seed=3, role="prefill")
        dec = _engine(model, params, paged, slots=1, seed=4, k=2)
        pre.on_handoff = dec.inject
        pre.add_request(GenRequest(request_id="h", prompt=[4, 3, 2, 1, 5, 6],
                                   max_new_tokens=10, temperature=0.0))
        pre.run_until_idle()
        dec.run_until_idle()
        return dec.pop_result("h")

    d, p = pd(False), pd(True)
    assert p is not None and p.tokens == d.tokens
    assert p.logprobs == d.logprobs


# ---------------------------------------------------------------------------
# TP groups: paged parity at 1/2/4 and sharded PD handoff 2 -> 4
# ---------------------------------------------------------------------------
# tiny with num_kv_heads=4 so group 4 shards the KV heads too
TP_CFG = get_config("tiny").with_(name="tiny-paged-tp", num_kv_heads=4)


def _mesh(n):
    return make_group_mesh(allocate_engine_devices([n])[0])


@needs_8_devices
def test_paged_greedy_parity_across_group_sizes():
    model = Model(TP_CFG, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    prompt = [1, 5, 7, 9, 3]
    [ref] = _serve(_engine(model, params, False, slots=2, max_len=96),
                   [prompt], max_new=12)
    for n in (1, 2, 4):
        [got] = _serve(_engine(model, params, True, slots=2, max_len=96,
                               mesh=_mesh(n)), [prompt], max_new=12)
        assert got.tokens == ref.tokens, \
            f"paged group size {n} diverged from dense single-device"
        # sharded matmul reductions don't preserve logprob bits vs the
        # single-device ref (same contract as test_sharded_engine)
        np.testing.assert_allclose(got.logprobs, ref.logprobs,
                                   rtol=1e-5, atol=1e-5)


@needs_8_devices
def test_paged_pd_handoff_across_unequal_groups():
    model = Model(TP_CFG, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [[1, 5, 7, 9], [1, 2, 3]]
    refs = [_serve(_engine(model, params, False, slots=2, max_len=96),
                   [p], max_new=6)[0] for p in prompts]
    proxy = build_pd_proxy(model, params, max_slots=4, max_len=96, seed=7,
                           prefill_devices_per_engine=2,
                           decode_devices_per_engine=4,
                           paged=True, page_size=PAGE)
    assert all(h.engine.paged for h in proxy.handles)
    out = {}
    for i, p in enumerate(prompts):
        proxy.submit(GenRequest(request_id=f"r{i}", prompt=list(p),
                                max_new_tokens=6, temperature=0.0),
                     callback=lambda res: out.__setitem__(
                         res.request_id, res))
    pumps = 0
    while proxy.busy:
        proxy.pump()
        pumps += 1
        assert pumps < 2000, "proxy did not drain"
    for i, ref in enumerate(refs):
        assert out[f"r{i}"].tokens == ref.tokens
    assert proxy.stats()["handoffs"] == len(prompts)


# ---------------------------------------------------------------------------
# ragged paged decode kernel vs gathered-dense oracle
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=8)
@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=0, max_value=3))
def test_ragged_paged_decode_matches_ref(batch, zero_rows):
    page, P, kvH, H, hd = 8, 4, 2, 4, 16
    key = jax.random.PRNGKey(batch * 7 + zero_rows)
    kq, kk, kv, kl = jax.random.split(key, 4)
    q = jax.random.normal(kq, (batch, H, hd), jnp.float32)
    pool_k = jax.random.normal(kk, (batch * P + 1, kvH, page, hd))
    pool_v = jax.random.normal(kv, (batch * P + 1, kvH, page, hd))
    tables = jnp.arange(batch * P, dtype=jnp.int32).reshape(batch, P)
    lens = jax.random.randint(kl, (batch,), 1, P * page + 1)
    lens = lens.at[:min(zero_rows, batch)].set(0)    # inactive rows
    out = ragged_paged_decode(q, pool_k, pool_v, tables, lens)
    gk = jnp.moveaxis(pool_k[tables], 2, 1).reshape(batch, kvH, P * page, hd)
    gv = jnp.moveaxis(pool_v[tables], 2, 1).reshape(batch, kvH, P * page, hd)
    want = np.asarray(R.decode_ref(q, gk, gv, lens))
    got = np.asarray(out)
    for b in range(batch):
        if int(lens[b]) == 0:
            assert not got[b].any(), "inactive row must emit zeros"
        else:
            np.testing.assert_allclose(got[b], want[b], rtol=2e-5,
                                       atol=2e-5)

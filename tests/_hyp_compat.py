"""Deterministic fallback for the slice of the ``hypothesis`` API this test
suite uses (``given`` / ``settings`` / ``strategies``).

Tier-1 CI images may not ship hypothesis (see requirements-dev.txt). A
module-level ``pytest.importorskip("hypothesis")`` would skip the WHOLE
module — including the plain parametrized tests that live in the same
files — so instead the test modules import these shims on ImportError:
each property test then runs over a small fixed set of deterministic
examples (boundaries, midpoints, and cycled composites) rather than being
skipped. With hypothesis installed, the real library is used unchanged.
"""
from __future__ import annotations

import functools
import inspect
from typing import List


class _Strategy:
    def __init__(self, examples: List):
        self.examples = list(examples)


def _dedup(xs):
    out = []
    for x in xs:
        if x not in out:
            out.append(x)
    return out


class st:
    """Deterministic stand-ins for ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value=0, max_value=100):
        mid = (min_value + max_value) // 2
        return _Strategy(_dedup([min_value, min(min_value + 1, max_value),
                                 mid, max(max_value - 1, min_value),
                                 max_value]))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0):
        return _Strategy(_dedup([min_value, (min_value + max_value) / 2,
                                 max_value]))

    @staticmethod
    def booleans():
        return _Strategy([False, True])

    @staticmethod
    def text(max_size=80):
        cases = ["", "a", "hello world", "\n\t ", "π ∆ → 🦊",
                 ("the quick brown fox " * 12)]
        return _Strategy(_dedup([c[:max_size] for c in cases]))

    @staticmethod
    def sampled_from(xs):
        return _Strategy(list(xs))

    @staticmethod
    def tuples(*strategies):
        pools = [s.examples for s in strategies]
        n = max(len(p) for p in pools)
        return _Strategy([tuple(p[(i + j) % len(p)]
                                for j, p in enumerate(pools))
                          for i in range(n)])

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        ex = elements.examples

        def take(n, off=0):
            return [ex[(off + i) % len(ex)] for i in range(n)]

        sizes = sorted({min_size, max(min_size, 1),
                        (min_size + max_size) // 2, max_size})
        return _Strategy([take(n, off) for off, n in enumerate(sizes)
                          if n >= min_size])


def given(*arg_strategies, **kw_strategies):
    """Run the wrapped test once per deterministic example tuple, cycling
    shorter example pools (the fallback analogue of hypothesis' sampler)."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            pos_pools = [s.examples for s in arg_strategies]
            kw_pools = {k: s.examples for k, s in kw_strategies.items()}
            n = max([len(p) for p in pos_pools]
                    + [len(p) for p in kw_pools.values()] + [1])
            for i in range(n):
                pos = [p[i % len(p)] for p in pos_pools]
                kws = {k: p[i % len(p)] for k, p in kw_pools.items()}
                fn(*args, *pos, **kws, **kwargs)
        # hide the strategy-filled parameters from pytest's fixture
        # resolution (hypothesis' own @given does the same). Keyword
        # strategies remove their named parameter; positional strategies
        # fill the trailing parameters.
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items()
                if name not in kw_strategies]
        if arg_strategies:
            kept = kept[:-len(arg_strategies)]
        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__
        return wrapper
    return deco


def settings(**_kwargs):
    """No-op: example counts are fixed by the fallback strategies."""
    return lambda fn: fn

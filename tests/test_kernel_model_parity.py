"""Parity between the Pallas kernels and the MODEL's jnp implementations
(the kernels must be drop-in replacements for the layers they accelerate,
not just match the standalone oracles)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import rwkv6 as R

# kernel JIT dominates tier-1 wall time; the fast CI job skips these
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(7)


def k(i):
    return jax.random.fold_in(KEY, i)


def test_flash_matches_model_attention():
    cfg = get_config("llama3.2-3b").reduced()
    B, S, H, kvH, hd = 2, 128, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jax.random.normal(k(0), (B, H, S, hd))
    kk = jax.random.normal(k(1), (B, kvH, S, hd))
    v = jax.random.normal(k(2), (B, kvH, S, hd))
    model_out = L._attend_causal(q, kk, v, cfg, window=None, q_chunk=64)
    kern_out = flash_attention(q, kk, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(kern_out), np.asarray(model_out),
                               atol=2e-5, rtol=2e-5)


def test_decode_matches_model_attention_decode():
    """Kernel vs the model's cache-attention math for one decode step, with
    a dense cache of valid length L (new token already written)."""
    cfg = get_config("llama3.2-3b").reduced()
    B, S, kvH, hd = 2, 256, cfg.num_kv_heads, cfg.head_dim
    H = cfg.num_heads
    length = 100
    q = jax.random.normal(k(3), (B, H, hd))
    kc = jax.random.normal(k(4), (B, kvH, S, hd))
    vc = jax.random.normal(k(5), (B, kvH, S, hd))
    lengths = jnp.full((B,), length)
    out_k = decode_attention(q, kc, vc, lengths, block_k=64)
    # model-side reference: grouped scores + masked softmax (the math inside
    # L.attention_decode after the cache write)
    scores = L._grouped_scores(q[:, :, None, :], kc, cfg)
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, L.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_m = jnp.einsum("bkgst,bkth->bkgsh", probs, vc).reshape(B, H, hd)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_m),
                               atol=2e-5, rtol=2e-5)


def test_rwkv6_kernel_matches_model_chunked_wkv():
    cfg = get_config("rwkv6-7b").reduced()
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    B, S = 2, 96
    r = jax.random.normal(k(6), (B, S, H, hd))
    kk = jax.random.normal(k(7), (B, S, H, hd))
    v = jax.random.normal(k(8), (B, S, H, hd))
    lw = jnp.clip(-jnp.exp(jax.random.normal(k(9), (B, S, H, hd))),
                  R.LW_MIN, R.LW_MAX)
    u = jax.random.normal(k(10), (H, hd)) * 0.3
    S0 = jnp.zeros((B, H, hd, hd))
    y_model, S_model = R._chunked_wkv(r, kk, v, lw, u, S0)
    y_kern, S_kern = rwkv6_scan(r, kk, v, lw, u, chunk=R.RWKV_CHUNK)
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_model),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(S_kern), np.asarray(S_model),
                               atol=5e-4, rtol=5e-4)


def test_mamba_kernel_matches_model_scan():
    """The kernel consumes the same (delta, B, C) the model computes."""
    cfg = get_config("jamba-v0.1-52b").reduced()
    B, S = 1, 64
    di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
    p = M.init_mamba(k(11), cfg)
    xc = jax.nn.silu(jax.random.normal(k(12), (B, S, di)))
    a, b_, Cm = M._ssm_inputs(p, cfg, xc)
    # model path: associative scan of (a, b)
    _, h = jax.lax.associative_scan(M._scan_combine, (a, b_), axis=1)
    y_model = jnp.sum(h * Cm[:, :, None, :], axis=-1) \
        + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    # kernel path: recompute delta the same way the model does
    dr = M.dt_rank(cfg)
    xdbl = jnp.einsum("bsi,ir->bsr", xc, p["x_proj"].astype(xc.dtype))
    dt_r, Bm, Cm2 = jnp.split(xdbl, [dr, dr + ds], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"].astype(xc.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    y_kern, h_kern = mamba_scan(xc.astype(jnp.float32), delta,
                                Bm.astype(jnp.float32),
                                Cm2.astype(jnp.float32),
                                p["A_log"], p["D"].astype(jnp.float32),
                                chunk=32, block_d=64)
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_model),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h_kern), np.asarray(h[:, -1]),
                               atol=2e-4, rtol=2e-4)

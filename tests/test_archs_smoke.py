"""Per-architecture smoke tests (deliverable (f)): for every assigned arch,
instantiate the REDUCED variant (<=2 periods, d_model<=256, <=4 experts) and
run one forward + one GRPO train step + one decode step on CPU, asserting
output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)

# the per-arch JIT sweep (jamba alone is >1 min) dominates tier-1 wall
# time with the kernel suites; the fast CI job skips it
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg, remat=False)
    params = model.init(rng)
    B, S = 2, 64
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    cond = None
    lc = max(cfg.cond_len, cfg.vision_patches)
    if lc:
        cond = jnp.ones((B, lc, cfg.d_model), jnp.float32) * 0.01
    logits, aux = model.forward(params, tokens, cond=cond)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    if cfg.uses_moe:
        assert bool(jnp.isfinite(aux["lb_loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg, remat=False)
    opt = default_optimizer(1e-4)
    state = init_train_state(model, rng, opt)
    step = jax.jit(make_grpo_train_step(model, opt))
    B, S = 2, 64
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "advantages": jnp.asarray([1.0, -1.0]),
        "behavior_logprobs": jnp.full((B, S - 1), -2.0),
    }
    lc = max(cfg.cond_len, cfg.vision_patches)
    if lc:
        batch["cond"] = jnp.ones((B, lc, cfg.d_model), jnp.float32) * 0.01
    new_state, metrics = step(state, batch)
    assert int(new_state.version) == 1
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     state.params, new_state.params)
    assert max(jax.tree.leaves(d)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg, remat=False)
    params = model.init(rng)
    B = 2
    cache = model.init_cache(B, 128)
    logits, cache2 = model.decode_step(
        params, jnp.ones((B, 1), jnp.int32), cache,
        jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "rwkv6-7b",
                                  "jamba-v0.1-52b", "qwen3-moe-30b-a3b"])
def test_prefill_decode_consistency(arch, rng):
    """Prefill+decode must equal the full forward pass."""
    cfg = get_config(arch).reduced()
    if cfg.uses_moe:
        cfg = cfg.with_(capacity_factor=float(cfg.num_experts))
    model = Model(cfg, remat=False)
    params = model.init(rng)
    B, S = 2, 64
    tokens = jax.random.randint(rng, (B, S + 2), 0, cfg.vocab_size)
    logits_full, _ = model.forward(params, tokens)
    cache = model.init_cache(B, S + 8)
    lg, cache = model.prefill(params, tokens[:, :S], cache)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(logits_full[:, S - 1], np.float32),
                               atol=5e-4, rtol=5e-4)
    for t in range(2):
        pos = jnp.full((B,), S + t, jnp.int32)
        lg, cache = model.decode_step(params, tokens[:, S + t: S + t + 1],
                                      cache, pos)
        np.testing.assert_allclose(
            np.asarray(lg, np.float32),
            np.asarray(logits_full[:, S + t], np.float32),
            atol=5e-4, rtol=5e-4)


def test_sliding_window_variant():
    """The long_500k sub-quadratic variant: windowed == full attention when
    the window covers the sequence; differs (and stays finite) when not."""
    cfg = get_config("llama3.2-3b").reduced()
    model_full = Model(cfg, remat=False)
    model_w = Model(cfg, remat=False, window=16)
    params = model_full.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0,
                                cfg.vocab_size)
    lf, _ = model_full.forward(params, tokens)
    lw, _ = Model(cfg, remat=False, window=64).forward(params, tokens)
    np.testing.assert_allclose(np.asarray(lf, np.float32),
                               np.asarray(lw, np.float32), atol=1e-4)
    lsmall, _ = model_w.forward(params, tokens)
    assert bool(jnp.isfinite(lsmall.astype(jnp.float32)).all())
    assert float(jnp.abs(lsmall.astype(jnp.float32)
                         - lf.astype(jnp.float32)).max()) > 1e-3

"""The concurrency & donation static-analysis plane (repro.analysis).

Each rule family gets a seeded-violation fixture AND a clean twin, so a
rule that silently stops firing (or starts over-firing) fails here long
before it would rot in CI:

- lock discipline: `# guarded by:` attrs, `# requires:` caller-locked
  methods, the `__init__` exemption, Condition aliasing;
- lock order: inconsistent nesting cycles, re-acquisition self-deadlock,
  blocking calls under a lock (incl. foreign-lock regions);
- donation: use-after-donate through both jit registration forms,
  `params` in donate sets;
- plumbing: suppression comments (trailing + multi-line block),
  bad-annotation validation, parse errors, shrink-only baseline
  semantics, and the repo-clean end-to-end gate.

Plus targeted regression tests for the concurrency fixes the first
analyzer run motivated (engine RNG snapshot/update_params serialization,
proxy handoff counting + deadlock-free stats, serverless deploy race).
"""
import json
import os
import textwrap
import threading

import jax
import numpy as np
import pytest

from repro.analysis import analyze_source, main
from repro.analysis.baseline import compare, counts_of, load_baseline

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_rules(src):
    return analyze_source(textwrap.dedent(src), "fixture.py")


def rules_of(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------------------
# rule family 1: lock discipline
# ---------------------------------------------------------------------------
def test_guarded_attr_flags_unlocked_access():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0     # guarded by: _lock

            def bump(self):
                self.count += 1
    """)
    assert rules_of(findings) == ["guarded-attr"]
    assert findings[0].symbol == "count"
    assert "bump" in findings[0].context


def test_guarded_attr_clean_under_lock_and_init_exempt():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0     # guarded by: _lock
                self.count = 1     # __init__ is exempt: not shared yet

            def bump(self):
                with self._lock:
                    self.count += 1
    """)
    assert findings == []


def test_requires_marks_method_caller_locked():
    clean = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0     # guarded by: _lock

            def _bump_locked(self):    # requires: _lock
                self.count += 1

            def bump(self):
                with self._lock:
                    self._bump_locked()
    """)
    assert clean == []

    dirty = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def _bump_locked(self):    # requires: _lock
                pass

            def bump(self):
                self._bump_locked()
    """)
    assert rules_of(dirty) == ["caller-locked"]
    assert dirty[0].symbol == "_bump_locked"


def test_requires_on_multiline_signature():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []    # guarded by: _lock

            def _take(self, n,
                      default=None):    # requires: _lock
                return self.items[:n]
    """)
    assert findings == []


def test_condition_alias_satisfies_guard():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._cv = threading.Condition(self._lock)
                self.items = []    # guarded by: _lock

            def put(self, x):
                with self._cv:      # same underlying lock as _lock
                    self.items.append(x)
                    self._cv.notify()
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# rule family 2: lock order + blocking under lock
# ---------------------------------------------------------------------------
def test_lock_order_cycle_detected():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def fwd(self):
                with self._a:
                    with self._b:
                        pass

            def rev(self):
                with self._b:
                    with self._a:
                        pass
    """)
    assert "lock-order" in rules_of(findings)


def test_lock_order_consistent_nesting_clean():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def fwd(self):
                with self._a:
                    with self._b:
                        pass

            def also_fwd(self):
                with self._a:
                    with self._b:
                        pass
    """)
    assert findings == []


def test_lock_order_cycle_through_requires_edge():
    # the edge a->b comes from calling a `requires: _b` helper under _a;
    # the reverse nesting in rev() closes the cycle interprocedurally
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def _helper(self):    # requires: _b
                pass

            def fwd(self):
                with self._a:
                    with self._b:
                        self._helper()

            def rev(self):
                with self._b:
                    with self._a:
                        pass
    """)
    assert "lock-order" in rules_of(findings)


def test_reacquisition_self_deadlock():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def outer(self):
                with self._lock:
                    with self._lock:    # non-reentrant: deadlock
                        pass
    """)
    assert "lock-order" in rules_of(findings)


def test_blocking_under_lock():
    findings = run_rules("""
        import threading
        import time

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def slow(self):
                with self._lock:
                    time.sleep(0.5)
    """)
    assert rules_of(findings) == ["blocking-under-lock"]


def test_blocking_under_foreign_lock_region():
    findings = run_rules("""
        import numpy as np

        class C:
            def save(self, runner, path, arrays):
                with runner._completed_lock:
                    np.savez(path, **arrays)
    """)
    assert rules_of(findings) == ["blocking-under-lock"]


def test_blocking_outside_lock_clean():
    findings = run_rules("""
        import threading
        import time

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.done = 0      # guarded by: _lock

            def slow(self):
                time.sleep(0.1)
                with self._lock:
                    self.done += 1
    """)
    assert findings == []


def test_str_join_not_flagged_as_thread_join():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def render(self, parts, worker):
                with self._lock:
                    return ",".join(parts)

            def stop(self, worker):
                with self._lock:
                    worker.join()     # zero-arg join: Thread-like
    """)
    assert rules_of(findings) == ["blocking-under-lock"]
    assert findings[0].line > 0


# ---------------------------------------------------------------------------
# rule family 3: donation
# ---------------------------------------------------------------------------
def test_use_after_donate_partial_decorator():
    findings = run_rules("""
        import functools
        import jax

        class Engine:
            donate = True

            def __init__(self):
                donate_argnums = (1,) if self.donate else ()

                @functools.partial(jax.jit,
                                   donate_argnums=donate_argnums)
                def _step(params, cache, tok):
                    return cache, tok

                self._step_jit = _step

            def step(self, params, cache, tok):
                new_cache, tok = self._step_jit(params, cache, tok)
                return cache    # stale: buffer was donated to the jit
    """)
    assert rules_of(findings) == ["use-after-donate"]
    assert findings[0].symbol == "cache"


def test_use_after_donate_jit_call_form_and_rebind_clean():
    dirty = run_rules("""
        import jax

        def _decode(cache, tok):
            return cache, tok

        _decode_jit = jax.jit(_decode, donate_argnums=(0,))

        def loop(cache, tok):
            out_cache, tok = _decode_jit(cache, tok)
            return cache
    """)
    assert rules_of(dirty) == ["use-after-donate"]

    clean = run_rules("""
        import jax

        def _decode(cache, tok):
            return cache, tok

        _decode_jit = jax.jit(_decode, donate_argnums=(0,))

        def loop(cache, tok):
            cache, tok = _decode_jit(cache, tok)
            return cache    # rebound from the jit's return: fine
    """)
    assert clean == []


def test_donated_params_flagged():
    findings = run_rules("""
        import functools
        import jax

        @functools.partial(jax.jit, donate_argnums=(0, 2))
        def train_step(params, opt_state, batch):
            return params, opt_state
    """)
    assert rules_of(findings) == ["donated-params"]
    assert findings[0].symbol == "params"


def test_donation_write_before_read_clean():
    findings = run_rules("""
        import jax

        _f = jax.jit(lambda c: c, donate_argnums=(0,))

        def go(cache):
            out = _f(cache)
            cache = out      # overwritten before any read
            return cache
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# plumbing: suppressions, bad annotations, parse errors
# ---------------------------------------------------------------------------
def test_suppression_trailing_comment():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0     # guarded by: _lock

            def peek(self):
                return self.count  # analysis: ignore[guarded-attr] racy probe
    """)
    assert findings == []


def test_suppression_multiline_block_comment():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0     # guarded by: _lock

            def peek(self):
                # analysis: ignore[guarded-attr] advisory lock-free read;
                # taking the lock here would invert the canonical order
                # with the caller's lock (see module docstring)
                return self.count
    """)
    assert findings == []


def test_suppression_wrong_rule_does_not_mask():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0     # guarded by: _lock

            def peek(self):
                return self.count  # analysis: ignore[lock-order] mismatch
    """)
    assert rules_of(findings) == ["guarded-attr"]


def test_annotations_in_string_literals_are_inert():
    findings = run_rules('''
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.doc = """
                self.count = 0     # guarded by: _lock
                """
    ''')
    assert findings == []


def test_bad_annotation_unknown_lock_and_rule_id():
    findings = run_rules("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0     # guarded by: _mutex
                self.count += 1    # analysis: ignore[no-such-rule] why
    """)
    assert rules_of(findings) == ["bad-annotation", "bad-annotation"]


def test_parse_error_is_a_finding():
    findings = run_rules("def broken(:\n    pass\n")
    assert rules_of(findings) == ["parse-error"]


# ---------------------------------------------------------------------------
# baseline: shrink-only semantics through the CLI
# ---------------------------------------------------------------------------
DIRTY = textwrap.dedent("""
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0     # guarded by: _lock

        def bump(self):
            self.count += 1
""")


@pytest.fixture
def dirty_tree(tmp_path):
    mod = tmp_path / "mod.py"
    mod.write_text(DIRTY)
    return tmp_path, mod


def test_cli_no_baseline_fails_on_any_finding(dirty_tree, capsys):
    tmp_path, mod = dirty_tree
    assert main(["--no-baseline", str(mod)]) == 1
    assert "guarded-attr" in capsys.readouterr().out


def test_cli_new_finding_fails_without_baseline_entry(dirty_tree, capsys):
    tmp_path, mod = dirty_tree
    base = tmp_path / "base.json"
    assert main(["--baseline", str(base), str(mod)]) == 1
    assert "new finding" in capsys.readouterr().out


def test_cli_baseline_absorbs_then_growth_fails(dirty_tree, capsys):
    tmp_path, mod = dirty_tree
    base = tmp_path / "base.json"
    assert main(["--update-baseline", "--baseline", str(base),
                 str(mod)]) == 0
    assert main(["--baseline", str(base), str(mod)]) == 0
    capsys.readouterr()

    # the same debt gets worse: a second unguarded access of the same key
    mod.write_text(DIRTY + "\n    def bump2(self):\n"
                   "        self.count += 1\n")
    rc = main(["--baseline", str(base), str(mod)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "new finding" in out    # distinct context => distinct key


def test_cli_count_growth_within_one_key_fails(dirty_tree, capsys):
    tmp_path, mod = dirty_tree
    base = tmp_path / "base.json"
    assert main(["--update-baseline", "--baseline", str(base),
                 str(mod)]) == 0
    capsys.readouterr()
    # same (file, rule, context, symbol) key, higher count
    mod.write_text(DIRTY.replace(
        "        self.count += 1",
        "        self.count += 1\n        self.count += 1"))
    rc = main(["--baseline", str(base), str(mod)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "baseline growth" in out


def test_cli_resolved_entries_nag_but_pass(dirty_tree, capsys):
    tmp_path, mod = dirty_tree
    base = tmp_path / "base.json"
    assert main(["--update-baseline", "--baseline", str(base),
                 str(mod)]) == 0
    capsys.readouterr()
    mod.write_text(DIRTY.replace(
        "        self.count += 1",
        "        with self._lock:\n"
        "            self.count += 1"))
    rc = main(["--baseline", str(base), str(mod)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "resolved" in out


def test_cli_update_refuses_growth(dirty_tree, capsys):
    tmp_path, mod = dirty_tree
    base = tmp_path / "base.json"
    assert main(["--update-baseline", "--baseline", str(base),
                 str(mod)]) == 0
    capsys.readouterr()
    mod.write_text(DIRTY + "\n    def bump2(self):\n"
                   "        self.count += 1\n")
    rc = main(["--update-baseline", "--baseline", str(base), str(mod)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "refusing to grow" in out
    # the file was not rewritten
    assert len(json.load(open(base))["entries"]) == 1


def test_compare_is_line_insensitive():
    f = run_rules(DIRTY)[0]
    live = counts_of([f])
    # baseline built from a finding at a different line: same key
    shifted = counts_of([type(f)(**{**f.__dict__, "line": f.line + 40})])
    failures, resolved = compare(live, shifted)
    assert failures == [] and resolved == []


# ---------------------------------------------------------------------------
# end-to-end: the committed tree is clean under the committed baseline
# ---------------------------------------------------------------------------
def test_repo_tree_is_clean(capsys):
    old = os.getcwd()
    os.chdir(REPO)
    try:
        rc = main([os.path.join(REPO, "src"), os.path.join(REPO, "tests")])
    finally:
        os.chdir(old)
    out = capsys.readouterr().out
    assert rc == 0, f"analysis gate failed:\n{out}"


def test_committed_baseline_is_empty():
    base = load_baseline(os.path.join(REPO, "results",
                                      "analysis_baseline.json"))
    assert base == {}, "baseline debt crept in; pay it down instead"


def test_list_rules_exits_zero(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ("guarded-attr", "lock-order", "blocking-under-lock",
                 "use-after-donate", "caller-locked"):
        assert rule in out


# ---------------------------------------------------------------------------
# regression: the concurrency fixes the first analyzer run motivated
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_engine_setup():
    from repro.configs import get_config
    from repro.models import Model
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_rng_snapshot_is_serialized_and_roundtrips(
        tiny_engine_setup):
    from repro.rl.engine import GenRequest, InferenceEngine
    _, model, params = tiny_engine_setup
    eng = InferenceEngine(model, params, max_slots=1, max_len=64, seed=3)
    key = eng.snapshot_rng()
    assert isinstance(key, np.ndarray)

    def sample(e):
        e.add_request(GenRequest(request_id="r", prompt=[1, 5, 7],
                                 max_new_tokens=8, temperature=1.0))
        e.run_until_idle()
        return e.pop_result("r").tokens

    first = sample(eng)
    eng.restore_rng(key)
    assert sample(eng) == first, "restored RNG must replay the stream"


def test_update_params_same_version_is_noop(tiny_engine_setup):
    from repro.rl.engine import InferenceEngine
    _, model, params = tiny_engine_setup
    eng = InferenceEngine(model, params, max_slots=1, max_len=64)
    before = eng.params
    eng.update_params(jax.tree.map(lambda x: x * 0, params), version=0)
    assert eng.params is before      # same version: swap skipped
    eng.update_params(params, version=1)
    assert eng.weight_version == 1


def test_engine_stats_snapshot_keys(tiny_engine_setup):
    from repro.rl.engine import GenRequest, InferenceEngine
    _, model, params = tiny_engine_setup
    eng = InferenceEngine(model, params, max_slots=1, max_len=64)
    eng.add_request(GenRequest(request_id="r", prompt=[1, 2, 3],
                               max_new_tokens=4, temperature=0.0))
    eng.run_until_idle()
    s = eng.stats()
    for k in ("steps", "decode_tokens", "prefill_tokens",
              "weight_version", "handoffs_out", "crashes"):
        assert k in s
    assert s["decode_tokens"] >= 3
    assert s["prefill_tokens"] >= 3


def test_proxy_handoff_count_under_contention(tiny_engine_setup):
    """The handoff hook's `+= 1` runs under the proxy lock; hammering the
    hook from many threads must not lose counts (the pre-fix code read-
    modify-wrote outside the lock)."""
    from repro.core import build_pd_proxy
    _, model, params = tiny_engine_setup
    proxy = build_pd_proxy(model, params, n_prefill=1, n_decode=1,
                           max_slots=1, max_len=64)
    hook = proxy._make_handoff_hook(proxy.prefill_handles[0])
    proxy._route_handoff = lambda *a, **k: True
    threads = [threading.Thread(
        target=lambda: [hook(None) for _ in range(200)])
        for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert proxy.stats()["handoffs"] == 8 * 200


def test_proxy_stats_concurrent_with_serving(tiny_engine_setup):
    """proxy.stats() collects engine counters OUTSIDE the proxy lock —
    calling it repeatedly from another thread while the proxy serves must
    terminate (the naive all-under-lock version could deadlock against
    the engine's finish/handoff hooks)."""
    from repro.core import build_pd_proxy
    from repro.rl.engine import GenRequest
    _, model, params = tiny_engine_setup
    proxy = build_pd_proxy(model, params, n_prefill=1, n_decode=1,
                           max_slots=1, max_len=64)
    stop = threading.Event()

    def poll():
        while not stop.is_set():
            proxy.stats()

    t = threading.Thread(target=poll, daemon=True)
    t.start()
    try:
        done = {}
        for i in range(3):
            proxy.submit(
                GenRequest(request_id=f"r{i}", prompt=[1, 2 + i],
                           max_new_tokens=4, temperature=0.0),
                callback=lambda res: done.__setitem__(
                    res.request_id, res))
        pumps = 0
        while proxy.busy:
            proxy.pump()
            pumps += 1
            assert pumps < 2000, "proxy did not drain"
    finally:
        stop.set()
        t.join(timeout=10)
    assert not t.is_alive(), "stats() poller wedged against the proxy"
    assert len(done) == 3
    assert proxy.stats()["handoffs"] == 3


def test_serverless_deploy_races_invoke():
    """deploy() publishes and invoke() reads the registry under the
    platform lock; late deploys racing invocations must neither crash
    nor invoke a stale function."""
    from repro.core.serverless import ServerlessPlatform
    plat = ServerlessPlatform()
    plat.deploy("fc://echo0", lambda x: x)
    errs, stop = [], threading.Event()

    def caller():
        i = 0
        while not stop.is_set():
            try:
                assert plat.invoke(f"fc://echo{i % 4}", i) == i
            except KeyError:
                pass             # not deployed yet: the defined behavior
            except Exception as exc:  # noqa: BLE001
                errs.append(exc)
            i += 1

    threads = [threading.Thread(target=caller) for _ in range(4)]
    for t in threads:
        t.start()
    for i in range(1, 4):
        plat.deploy(f"fc://echo{i}", lambda x: x)
    stop.set()
    for t in threads:
        t.join(timeout=10)
    assert errs == []


def test_benchmark_registry_resolves_and_lists(capsys):
    import benchmarks.run as bench_run
    for name in bench_run.ALL:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        assert callable(mod.run), f"{name} has no run()"
    assert "async_overlap" in bench_run.ALL
    rc = bench_run.main(["--list"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "async_overlap" in out and "UNRESOLVED" not in out

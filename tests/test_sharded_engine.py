"""Sharded engine groups (TP execution through the live inference stack):

- byte-identical greedy decode at group sizes 1/2/4 on attention and MoE
  stacks (the mesh/axis_rules path changes placement, never tokens);
- sharded PD handoff across UNEQUAL group sizes (2-way prefill feeding
  4-way decode) with greedy parity vs a single-device engine;
- FT: kill a sharded engine mid-flight and restore its KV slot from a
  snapshot (host-numpy handoffs re-shard on inject);
- mid-flight sharded weight sync: per-shard chunks through the
  MooncakeStore -> update_from_chunks, with no device ever holding a
  full param copy (param_device_bytes accounting);
- fit_spec drop surfacing (one-shot warning + stats counter) and the
  validate_group raise that replaces the silent devices_per_engine no-op.

Needs >= 8 host devices; run with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set below when
this module is the first jax importer, e.g. a standalone pytest run).
"""
import os
import sys

if "jax" not in sys.modules:      # must precede the first jax import
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import EngineHandle, LLMProxy, build_pd_proxy
from repro.core.weightstore import (MooncakeStore, pull_param_chunks,
                                    push_params_sharded)
from repro.distributed.sharding import (model_axis_dims, reset_drop_state,
                                        validate_group)
from repro.launch.mesh import allocate_engine_devices, make_group_mesh
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


# tiny with num_kv_heads=4 so group 4 shards the KV heads too (tiny's
# stock kv_heads=2 is the fit-drop case, covered separately below)
ATTN_CFG = get_config("tiny").with_(name="tiny-tp", num_kv_heads=4)
MOE_CFG = get_config("tiny").with_(
    name="tiny-tp-moe", family="moe", num_kv_heads=4,
    block_pattern=(("attn", "moe"),), num_experts=4, top_k=2, moe_d_ff=128)


def _setup(cfg):
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _mesh(n):
    return make_group_mesh(allocate_engine_devices([n])[0])


def _greedy(model, params, prompt, n, *, mesh=None, max_len=96):
    eng = InferenceEngine(model, params, max_slots=2, max_len=max_len,
                          mesh=mesh)
    eng.add_request(GenRequest(request_id="g", prompt=list(prompt),
                               max_new_tokens=n, temperature=0.0))
    eng.run_until_idle()
    return eng.pop_result("g").tokens


def _serve(proxy, reqs, max_pumps=2000):
    out = {}
    for r in reqs:
        proxy.submit(r, callback=lambda res: out.__setitem__(
            res.request_id, res))
    pumps = 0
    while proxy.busy:
        proxy.pump()
        pumps += 1
        assert pumps < max_pumps, "proxy did not drain"
    return out


# ---------------------------------------------------------------------------
# tentpole: greedy parity across group sizes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [ATTN_CFG, MOE_CFG],
                         ids=["attn", "moe"])
def test_greedy_parity_across_group_sizes(cfg):
    model, params = _setup(cfg)
    prompt = [1, 5, 7, 9, 3]
    ref = _greedy(model, params, prompt, 12)
    assert len(ref) == 12
    for n in (2, 4):
        got = _greedy(model, params, prompt, 12, mesh=_mesh(n))
        assert got == ref, f"group size {n} diverged from single-device"


def test_sharded_engine_places_params_and_cache():
    model, params = _setup(ATTN_CFG)
    eng = InferenceEngine(model, params, max_slots=2, max_len=64,
                          mesh=_mesh(4))
    assert eng.stats()["tp_group"] == 4
    # a sharded leaf spreads across the group: the per-device param
    # footprint must be strictly below the full footprint
    full = sum(int(np.asarray(x).nbytes) for x in jax.tree.leaves(params))
    per_dev = eng.param_device_bytes()
    assert len(per_dev) == 4
    assert all(b < full for b in per_dev.values())
    # caller's pytree stays host/single-device; the engine placed a copy
    assert eng.params is not params


# ---------------------------------------------------------------------------
# sharded PD handoff across unequal group sizes
# ---------------------------------------------------------------------------
def test_pd_handoff_across_unequal_groups():
    model, params = _setup(ATTN_CFG)
    prompts = [[1, 5, 7, 9], [1, 2, 3], [1, 9, 9, 4, 2]]
    refs = [_greedy(model, params, p, 6) for p in prompts]
    proxy = build_pd_proxy(model, params, max_slots=4, max_len=96, seed=7,
                           prefill_devices_per_engine=2,
                           decode_devices_per_engine=4)
    by_role = {h.engine.role: h.engine for h in proxy.handles}
    assert by_role["prefill"].tp_group == 2
    assert by_role["decode"].tp_group == 4
    reqs = [GenRequest(request_id=f"r{i}", prompt=p, max_new_tokens=6,
                       temperature=0.0) for i, p in enumerate(prompts)]
    out = _serve(proxy, reqs)
    for i, ref in enumerate(refs):
        assert out[f"r{i}"].tokens == ref
    assert proxy.stats()["handoffs"] == 3


def test_engine_groups_are_disjoint():
    model, params = _setup(ATTN_CFG)
    proxy = build_pd_proxy(model, params, max_slots=2, max_len=64,
                           prefill_devices_per_engine=2,
                           decode_devices_per_engine=4)
    seen = set()
    for h in proxy.handles:
        devs = {d.id for d in h.engine.mesh.devices.flat}
        assert not (seen & devs), "engines share a device"
        seen |= devs


# ---------------------------------------------------------------------------
# FT: kill a sharded engine, restore its KV slot from a snapshot
# ---------------------------------------------------------------------------
def test_sharded_engine_kill_and_snapshot_restore():
    model, params = _setup(ATTN_CFG)
    prompt = [1, 5, 7, 9, 3]
    ref = _greedy(model, params, prompt, 48, max_len=128)
    eng = InferenceEngine(model, params, max_slots=2, max_len=128,
                          seed=0, mesh=_mesh(4))
    proxy = LLMProxy([EngineHandle(eng, "local")])
    out = {}
    proxy.submit(GenRequest(request_id="g", prompt=list(prompt),
                            max_new_tokens=48, temperature=0.0),
                 callback=lambda r: out.__setitem__(r.request_id, r))
    for _ in range(2):
        proxy.pump()
    [hf] = eng.snapshot_slots()
    assert isinstance(jax.tree.leaves(hf.cache)[0], np.ndarray), \
        "snapshot cache must be host numpy (portable across group sizes)"
    proxy.pump()                       # work advances past the snapshot
    eng.crash()
    assert eng.stats()["crashes"] == 1
    proxy.reinject(hf)                 # re-shards the slot on inject
    pumps = 0
    while proxy.busy:
        proxy.pump()
        pumps += 1
        assert pumps < 2000
    assert out["g"].tokens == ref


def test_handoff_injects_across_group_sizes():
    """A slot snapshotted on a 2-way engine restores onto a 4-way engine
    (the FT re-homing case when the replacement pool is sized
    differently)."""
    model, params = _setup(ATTN_CFG)
    prompt = [1, 5, 7, 9, 3]
    ref = _greedy(model, params, prompt, 32, max_len=128)
    src = InferenceEngine(model, params, max_slots=2, max_len=128,
                          mesh=_mesh(2))
    src.add_request(GenRequest(request_id="g", prompt=list(prompt),
                               max_new_tokens=32, temperature=0.0))
    src.step()
    src.step()                # ~17 of 32 tokens: genuinely mid-flight
    [hf] = src.snapshot_slots()
    dst = InferenceEngine(model, params, max_slots=2, max_len=128,
                          mesh=_mesh(4))
    dst.inject(hf)
    dst.run_until_idle()
    assert dst.pop_result("g").tokens == ref


# ---------------------------------------------------------------------------
# mid-flight sharded weight sync
# ---------------------------------------------------------------------------
def test_midflight_sharded_weight_sync():
    model, params = _setup(ATTN_CFG)
    params_v1 = model.init(jax.random.PRNGKey(1))
    prompt = [1, 5, 7, 9, 3]

    def run(eng):
        eng.add_request(GenRequest(request_id="g", prompt=list(prompt),
                                   max_new_tokens=24, temperature=0.0))
        eng.step()                     # mid-flight under v0 weights
        return eng

    # reference: single-device engine swapped to v1 the monolithic way
    ref_eng = run(InferenceEngine(model, params, max_slots=2, max_len=128))
    ref_eng.update_params(params_v1, 1)
    ref_eng.run_until_idle()
    ref = ref_eng.pop_result("g").tokens

    # sharded engine pulls v1 as per-shard chunks through the store
    eng = run(InferenceEngine(model, params, max_slots=2, max_len=128,
                              mesh=_mesh(4)))
    store = MooncakeStore(bucket_mb=1)
    dims = model_axis_dims(params_v1, 4)
    pushed = push_params_sharded(store, params_v1, 1, 4, dims)
    assert pushed > 0
    chunks, version = pull_param_chunks(store, params_v1)
    eng.update_from_chunks(chunks, version)
    eng.run_until_idle()
    assert eng.pop_result("g").tokens == ref
    st = eng.stats()
    assert st["weight_version"] == 1
    assert st["sync_bytes"] > 0
    # no device assembled a full copy of the params
    full = sum(int(np.asarray(x).nbytes)
               for x in jax.tree.leaves(params_v1))
    assert all(b < full for b in eng.param_device_bytes().values())


def test_chunked_pull_assembles_on_single_device_engine():
    """A dense (mesh=None) engine consumes the same chunked store format
    — the mixed-plane path (e.g. an unsharded colocated engine pulling a
    version the trainer chunked for its sharded peers)."""
    model, params = _setup(ATTN_CFG)
    params_v1 = model.init(jax.random.PRNGKey(1))
    store = MooncakeStore(bucket_mb=1)
    push_params_sharded(store, params_v1, 1, 4, model_axis_dims(params_v1, 4))
    chunks, version = pull_param_chunks(store, params_v1)
    eng = InferenceEngine(model, params, max_slots=2, max_len=64)
    eng.update_from_chunks(chunks, version)
    want = jax.tree.leaves(params_v1)
    got = jax.tree.leaves(eng.params)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


# ---------------------------------------------------------------------------
# fit_spec drop surfacing + validate_group
# ---------------------------------------------------------------------------
def test_fit_drop_warns_once_and_counts_in_stats():
    # stock tiny has num_kv_heads=2: a 4-way group cannot shard the KV
    # head dim, so fit_spec drops it — surfaced, never silent
    from repro.distributed.sharding import ShardingDropWarning
    model, params = _setup(get_config("tiny"))
    reset_drop_state()
    with pytest.warns(ShardingDropWarning, match="dropped sharding"):
        eng = InferenceEngine(model, params, max_slots=2, max_len=64,
                              mesh=_mesh(4))
    assert eng.stats()["sharding_drops"] > 0
    # one-shot: the same structural drop does not warn again
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", ShardingDropWarning)
        InferenceEngine(model, params, max_slots=2, max_len=64,
                        mesh=_mesh(4))


def test_unusable_group_raises_not_noop():
    # tiny shards nothing 7 ways (no param dim divisible by 7): the old
    # silent devices_per_engine no-op must raise instead
    model, params = _setup(get_config("tiny"))
    with pytest.raises(ValueError, match="shards nothing"):
        InferenceEngine(model, params, max_slots=2, max_len=64,
                        mesh=_mesh(7))
    with pytest.raises(ValueError, match="shards nothing"):
        validate_group(params, 7, model_name="tiny")


def test_placement_report_prices_the_group():
    model, params = _setup(ATTN_CFG)
    proxy = build_pd_proxy(model, params, max_slots=2, max_len=64,
                           prefill_devices_per_engine=2,
                           decode_devices_per_engine=4)
    rows = {r["role"]: r for r in proxy.placement_report()}
    assert rows["prefill"]["tp_group"] == 2
    assert rows["decode"]["tp_group"] == 4
    assert rows["prefill"]["devices"] == 2
    assert rows["decode"]["devices"] == 4

"""repro: RollArt — disaggregated multi-task agentic RL training — in JAX.

Layers: repro.core (the paper's resource/data/control planes + the
calibrated cluster simulation), repro.models (10 assigned architectures),
repro.rl (GRPO trainer + continuous-batching engine), repro.kernels
(Pallas TPU kernels + oracles), repro.envs / repro.rewards,
repro.launch (mesh, multi-pod dry-run, train/serve CLIs).
"""
__version__ = "1.0.0"

"""Unified decoder-only model builder covering all assigned families.

Depth is organized as ``num_periods`` repetitions of ``cfg.block_pattern``
(a tuple of (mixer, ffn) pairs). Parameters for each pattern position are
stacked over a leading ``num_periods`` axis and the forward pass scans over
periods (``scan_layers=True``, depth-independent HLO — required for the
80 dry-run compiles on one CPU) or unrolls them (``scan_layers=False``, used
by the roofline harness: XLA cost analysis counts a scan body only once, so
costs are extracted from unrolled depth-1/-2 builds and extrapolated).

Public entry points:
    init(key)                                   -> params
    forward(params, tokens, cond=None)          -> (logits, aux)   # train
    init_cache(batch, cache_len)                -> cache
    prefill(params, tokens, cache)              -> (logits, cache)
    decode_step(params, tokens, cache, positions) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shd
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import rwkv6 as R


class Model:
    def __init__(self, cfg: ModelConfig, *, scan_layers: bool = True,
                 remat: bool = True, window: Optional[int] = None):
        self.cfg = cfg
        self.scan_layers = scan_layers
        self.remat = remat
        # in unrolled (roofline cost) mode avoid inner scans: XLA cost
        # analysis counts while-loop bodies once (see hlo_costs.py)
        self.q_chunk = 512 if scan_layers else (1 << 30)
        self.mamba_chunk = 64 if scan_layers else (1 << 30)
        # attention window: explicit arg overrides config (long-context mode)
        self.window = window if window is not None else cfg.sliding_window

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def _init_block(self, key, mixer: str, ffn: str) -> Dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        if mixer == "attn":
            mix = L.init_attention(k1, cfg)
        elif mixer == "mamba":
            mix = M.init_mamba(k1, cfg)
        elif mixer == "rwkv":
            mix = R.init_rwkv(k1, cfg)
        else:
            raise ValueError(mixer)
        ff = MOE.init_moe(k2, cfg) if ffn == "moe" else L.init_mlp(k2, cfg)
        return {
            "norm1": L.init_rmsnorm(cfg.d_model, L.pdt(cfg)),
            "norm2": L.init_rmsnorm(cfg.d_model, L.pdt(cfg)),
            mixer: mix,
            ("moe" if ffn == "moe" else "mlp"): ff,
        }

    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(key, 3 + len(cfg.block_pattern))
        layers = []
        for p_idx, (mixer, ffn) in enumerate(cfg.block_pattern):
            per_period = [
                self._init_block(jax.random.fold_in(keys[3 + p_idx], i),
                                 mixer, ffn)
                for i in range(cfg.num_periods)
            ]
            layers.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_period))
        params = {
            "embed": {"tokens": L.dense_init(
                keys[0], (cfg.vocab_size, cfg.d_model), L.pdt(cfg),
                scale=0.02)},
            "layers": tuple(layers),
            "final_norm": L.init_rmsnorm(cfg.d_model, L.pdt(cfg)),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {"w": L.dense_init(
                keys[1], (cfg.d_model, cfg.vocab_size), L.pdt(cfg))}
        return params

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))

    # ------------------------------------------------------------------
    # full-sequence forward (train / scoring)
    # ------------------------------------------------------------------
    def _block_fwd(self, bp: Dict, pattern: Tuple[str, str], x, positions,
                   aux_acc):
        cfg = self.cfg
        mixer, ffn = pattern
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
        if mixer == "attn":
            h = L.attention_fwd(bp["attn"], cfg, h, positions,
                                window=self.window, q_chunk=self.q_chunk)
        elif mixer == "mamba":
            h = M.mamba_fwd(bp["mamba"], cfg, h, chunk=self.mamba_chunk)
        else:
            h = R.rwkv_fwd(bp["rwkv"], cfg, h)
        x = x + h
        h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            h, aux = MOE.moe_fwd(bp["moe"], cfg, h)
            aux_acc = {
                "lb_loss": aux_acc["lb_loss"] + aux["lb_loss"],
                "z_loss": aux_acc["z_loss"] + aux["z_loss"],
            }
        else:
            h = L.mlp_fwd(bp["mlp"], cfg, h)
        x = x + h
        x = shd(x, "batch", "seq", "act_embed")
        return x, aux_acc

    def _period_fwd(self, period_params, x, positions, aux_acc):
        # NOTE(hillclimb): nested per-block remat was tried for multi-block
        # patterns (jamba) and regressed temp memory 52->64 GiB on XLA:CPU
        # (the extra checkpoint boundaries defeat buffer reuse); disabled.
        nested = False
        for p_idx, pattern in enumerate(self.cfg.block_pattern):
            fwd = functools.partial(self._block_fwd, pattern=pattern)
            if nested:
                fwd = jax.checkpoint(fwd, static_argnums=())
            x, aux_acc = fwd(period_params[p_idx], x=x, positions=positions,
                             aux_acc=aux_acc)
        return x, aux_acc

    def forward(self, params, tokens, cond=None, positions=None):
        """tokens: [B,S] int32; cond: [B,Lc,d_model] early-fusion embeddings.

        Returns (logits [B,S,V] fp32, aux dict with MoE losses).
        """
        cfg = self.cfg
        x, aux = self._backbone(params, tokens, cond, positions)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        w_out = (params["embed"]["tokens"].T if cfg.tie_embeddings
                 else params["lm_head"]["w"])
        w_out = shd(w_out.astype(L.dt(cfg)), None, "vocab")  # PERF(iter 1)
        logits = jnp.einsum("bsd,dv->bsv", x, w_out)
        logits = shd(logits, "batch", "seq", "vocab")
        return logits, aux

    def forward_logprobs(self, params, tokens, cond=None, chunk: int = 512):
        """Fused, seq-chunked head: returns (logprobs [B,S-1] fp32, aux)
        without ever materializing [B,S,V] logits — the head matmul, the
        logsumexp, and the label pick run per sequence chunk under remat.
        This is what the GRPO train_step uses; ``forward`` keeps the plain
        logits path for sampling/scoring of short sequences.
        """
        cfg = self.cfg
        B, S = tokens.shape
        x, aux = self._backbone(params, tokens, cond)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        x = shd(x, "batch", "seq", "act_embed")
        w_out = (params["embed"]["tokens"].T if cfg.tie_embeddings
                 else params["lm_head"]["w"]).astype(L.dt(cfg))
        # PERF(iter 1): contract an UNSHARDED d — gather the (data,model)-
        # sharded head weight over "data" (tens of MB) rather than letting
        # GSPMD all-reduce [B,chunk,V] partial sums per chunk (GBs); see
        # EXPERIMENTS.md §Perf.
        w_out = shd(w_out, None, "vocab")
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)

        if not self.scan_layers:       # roofline cost mode: no inner scans
            chunk = S
        c = min(chunk, S)
        while S % c:
            c -= 1
        nb = S // c

        def body(carry, xs):
            xc, labc = xs                                  # [B,c,d], [B,c]
            logits = jnp.einsum("bcd,dv->bcv", xc, w_out)  # bf16 [B,c,V]
            m = jax.lax.stop_gradient(jnp.max(logits, -1, keepdims=True))
            shifted = (logits - m).astype(jnp.float32)
            lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
            iota = jax.lax.broadcasted_iota(labc.dtype,
                                            (1, 1, logits.shape[-1]), 2)
            lab = jnp.sum(jnp.where(labc[..., None] == iota, shifted, 0.0),
                          axis=-1)
            return carry, lab - lse

        if nb == 1:
            _, lp = body(None, (x, labels))
        else:
            xs = (jnp.moveaxis(x.reshape(B, nb, c, -1), 1, 0),
                  jnp.moveaxis(labels.reshape(B, nb, c), 1, 0))
            _, lp = jax.lax.scan(jax.checkpoint(body), None, xs)
            lp = jnp.moveaxis(lp, 0, 1).reshape(B, S)
        return lp[:, :-1], aux

    def _embed(self, params, tokens):
        """Token embedding. Under SPMD, a one-hot matmul (MaxText-style): the
        gather's backward is a scatter-add into the full [V,d] table that
        GSPMD cannot shard (measured 2 GiB/device f32 replicated on
        chameleon-34b); as a matmul, dW shards like the table itself."""
        from repro.distributed.sharding import sharding_active
        cfg = self.cfg
        table = params["embed"]["tokens"].astype(L.dt(cfg))
        if not sharding_active():
            return jnp.take(table, tokens, axis=0)
        onehot = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=L.dt(cfg))
        onehot = shd(onehot, "batch", "seq", "vocab")
        table = shd(table, "vocab", None)                   # PERF(iter 1)
        return jnp.einsum("bsv,vd->bsd", onehot, table)

    def _backbone(self, params, tokens, cond=None, positions=None):
        """Shared embed + layer stack; returns (x [B,S,d] pre-final-norm
        residual output, aux)."""
        cfg = self.cfg
        B, S = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x = self._embed(params, tokens)
        if cond is not None:
            lc = cond.shape[1]
            x = jnp.concatenate([cond.astype(x.dtype), x[:, lc:, :]], axis=1)
        x = shd(x, "batch", "seq", "act_embed")
        aux = {"lb_loss": jnp.zeros((), jnp.float32),
               "z_loss": jnp.zeros((), jnp.float32)}
        if self.scan_layers:
            def body(carry, period_params):
                x, aux = carry
                x, aux = self._period_fwd(period_params, x, positions, aux)
                return (x, aux), ()
            if self.remat:
                body = jax.checkpoint(body)
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["layers"])
        else:
            for i in range(cfg.num_periods):
                pp = jax.tree.map(lambda a: a[i], params["layers"])
                fwd = self._period_fwd
                if self.remat:
                    fwd = jax.checkpoint(fwd)
                x, aux = fwd(pp, x, positions, aux)
        return x, aux

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def _block_cache_spec(self, pattern, batch: int, cache_len: int):
        cfg = self.cfg
        mixer, _ = pattern
        np_ = cfg.num_periods
        if mixer == "attn":
            clen = min(cache_len, self.window) if self.window else cache_len
            shape = (np_, batch, cfg.num_kv_heads, clen, cfg.head_dim)
            return {"k": jnp.zeros(shape, L.dt(cfg)),
                    "v": jnp.zeros(shape, L.dt(cfg))}
        if mixer == "mamba":
            return {"h": jnp.zeros((np_, batch, cfg.mamba_d_inner,
                                    cfg.mamba_d_state), jnp.float32),
                    "conv": jnp.zeros((np_, batch, cfg.mamba_d_conv - 1,
                                       cfg.mamba_d_inner), L.dt(cfg))}
        if mixer == "rwkv":
            return {"prev_x": jnp.zeros((np_, batch, cfg.d_model), L.dt(cfg)),
                    "S": jnp.zeros((np_, batch, cfg.num_rwkv_heads,
                                    cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                                   jnp.float32)}
        raise ValueError(mixer)

    def init_cache(self, batch: int, cache_len: int):
        return tuple(self._block_cache_spec(pat, batch, cache_len)
                     for pat in self.cfg.block_pattern)

    # ------------------------------------------------------------------
    # paged KV pool (attention stacks only; see rl/paged_kv.py)
    # ------------------------------------------------------------------
    def supports_paged(self) -> bool:
        """Paged KV needs position-addressable per-token state: every
        mixer must be attention (a recurrent mamba/rwkv state has no
        page structure) and no ring-buffered sliding window (a page
        holds absolute positions, a ring holds positions mod window)."""
        return (self.window is None
                and all(m == "attn" for m, _ in self.cfg.block_pattern))

    def init_paged_pool(self, num_rows: int, page_size: int):
        """Zeroed page pool, one leaf pair per block-pattern position:
        ``[num_periods, num_rows, kvH, page_size, hd]``. ``num_rows``
        includes the engine's trash row (id ``num_rows-1``), which
        absorbs padded-table writes and gathers."""
        if not self.supports_paged():
            raise ValueError(
                f"{self.cfg.name}: paged KV requires an attention-only "
                "stack with no sliding window")
        cfg = self.cfg
        shape = (cfg.num_periods, num_rows, cfg.num_kv_heads, page_size,
                 cfg.head_dim)
        return tuple({"k": jnp.zeros(shape, L.dt(cfg)),
                      "v": jnp.zeros(shape, L.dt(cfg))}
                     for _ in cfg.block_pattern)

    # logical axes per cache leaf, aligned with _block_cache_spec shapes.
    # Under SERVE_RULES the attention cache shards its sequence dim over
    # the group's "model" axis ("cache_seq" rule) — the layout the §6.3
    # decode path wants, since each decode step touches one position of
    # every head but streams the whole context.
    _CACHE_AXES = {
        "attn": {"k": (None, None, "cache_kv_heads", "cache_seq", None),
                 "v": (None, None, "cache_kv_heads", "cache_seq", None)},
        "mamba": {"h": (None, None, "mamba_inner", "ssm_state"),
                  "conv": (None, None, None, "mamba_inner")},
        "rwkv": {"prev_x": (None, None, None),
                 "S": (None, None, "rwkv_heads", None, None)},
    }

    # paged pool leaves are [num_periods, num_rows, kvH, page, hd]: the
    # within-page position dim shards over the group ("cache_page_seq"),
    # the page-granular analogue of the dense "cache_seq" layout
    _PAGED_CACHE_AXES = {
        "attn": {"k": (None, None, "cache_kv_heads", "cache_page_seq", None),
                 "v": (None, None, "cache_kv_heads", "cache_page_seq", None)},
    }

    def cache_logical_axes(self):
        """Pytree matching ``init_cache`` structure whose leaves are the
        logical-axis tuples of each cache leaf."""
        return tuple(dict(self._CACHE_AXES[mixer])
                     for mixer, _ in self.cfg.block_pattern)

    def paged_cache_logical_axes(self):
        return tuple(dict(self._PAGED_CACHE_AXES[mixer])
                     for mixer, _ in self.cfg.block_pattern)

    def cache_sharding(self, cache, mesh, rules, axes=None):
        """NamedSharding pytree for an engine cache on ``mesh`` under a
        logical rule set (divisibility handled exactly like params, via
        ``fit_spec``). ``axes`` selects the layout — dense
        (``cache_logical_axes``, default) or paged
        (``paged_cache_logical_axes``)."""
        from jax.sharding import NamedSharding
        from repro.distributed.sharding import fit_spec, resolve_spec

        def one(leaf, leaf_axes):
            spec = fit_spec(leaf.shape,
                            resolve_spec(leaf_axes, rules, mesh), mesh)
            return NamedSharding(mesh, spec)
        # tree.map flattens up to the CACHE's leaves (arrays), so the
        # logical-axis tuples sitting at those positions pass through
        # whole instead of being descended into
        return jax.tree.map(one, cache,
                            axes if axes is not None
                            else self.cache_logical_axes())

    # ------------------------------------------------------------------
    # KV-cache slot migration (live prefill/decode disaggregation)
    # ------------------------------------------------------------------
    # Every cache leaf is laid out (num_periods, batch, ...), so one
    # trajectory's state is the batch-axis slice at its slot index. These
    # two helpers are the data-plane handoff used by the PD-disaggregated
    # engines: the prefill engine extracts a freshly filled slot and the
    # decode engine injects it into one of its free slots.

    def extract_cache_slot(self, cache, slot: int):
        """Slice one slot (batch axis == 1) out of an engine cache pytree.

        Returns a cache pytree with batch dimension 1, suitable for
        ``inject_cache_slot`` on another engine built from the same model
        with the same ``cache_len``.
        """
        return jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
            cache)

    def inject_cache_slot(self, cache, slot_cache, slot: int):
        """Write a batch-1 cache pytree into ``slot`` of a full cache."""
        return jax.tree.map(
            lambda big, little: jax.lax.dynamic_update_slice_in_dim(
                big, little.astype(big.dtype), slot, axis=1),
            cache, slot_cache)

    def paged_to_dense_slot(self, pool, table):
        """Gather one slot's pages into the batch-1 DENSE cache layout
        (``init_cache(1, P*page)`` shapes) — the portable KVHandoff
        format. ``table``: [P] int32 page ids, padded with the trash row
        past the slot's allocation (those positions carry junk the
        consumer masks by position, exactly like a dense engine's stale
        rows). Eager ops, like ``extract_cache_slot``."""
        table = jnp.asarray(table, jnp.int32)

        def one(leaf):
            g = jnp.swapaxes(leaf[:, table], 1, 2)   # [np,kvH,P,page,hd]
            np_, kvh, P, page, hd = g.shape
            return g.reshape(np_, kvh, P * page, hd)[:, None]
        return jax.tree.map(one, pool)

    def dense_slot_to_pages(self, pool, slot_cache, table):
        """Scatter a batch-1 dense cache pytree into a slot's pages (the
        inject half of a PD handoff / FT restore into a paged engine).
        Positions past the allocation land in the trash row."""
        table = jnp.asarray(table, jnp.int32)

        def one(leaf, dense):
            np_, _, kvh, length, hd = dense.shape
            P = table.shape[0]
            pages = dense[:, 0].reshape(np_, kvh, P, length // P, hd)
            pages = jnp.swapaxes(pages, 1, 2)        # [np,P,kvH,page,hd]
            return leaf.at[:, table].set(pages.astype(leaf.dtype))
        return jax.tree.map(one, pool, slot_cache)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _block_decode(self, bp, pattern, x, cache, positions):
        cfg = self.cfg
        mixer, ffn = pattern
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
        if mixer == "attn":
            h, k_c, v_c = L.attention_decode(
                bp["attn"], cfg, h, cache["k"], cache["v"], positions,
                lengths=positions, window=self.window)
            new_cache = {"k": k_c, "v": v_c}
        elif mixer == "mamba":
            h, h_state, conv = M.mamba_decode(bp["mamba"], cfg, h,
                                              cache["h"], cache["conv"])
            new_cache = {"h": h_state, "conv": conv}
        else:
            h, prev_x, S = R.rwkv_decode(bp["rwkv"], cfg, h,
                                         cache["prev_x"], cache["S"])
            new_cache = {"prev_x": prev_x, "S": S}
        x = x + h
        h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            h, _ = MOE.moe_fwd(bp["moe"], cfg, h)
        else:
            h = L.mlp_fwd(bp["mlp"], cfg, h)
        return x + h, new_cache

    def _block_decode_paged(self, bp, pattern, x, pool_leaf, tables,
                            positions, page_size):
        cfg = self.cfg
        mixer, ffn = pattern
        if mixer != "attn":
            raise ValueError(f"paged decode: unsupported mixer {mixer!r}")
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
        h, k_p, v_p = L.attention_decode_paged(
            bp["attn"], cfg, h, pool_leaf["k"], pool_leaf["v"], tables,
            positions, page_size)
        x = x + h
        h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            h, _ = MOE.moe_fwd(bp["moe"], cfg, h)
        else:
            h = L.mlp_fwd(bp["mlp"], cfg, h)
        return x + h, {"k": k_p, "v": v_p}

    def decode_step_paged(self, params, tokens, pool, tables, positions,
                          page_size: int):
        """Paged analogue of :meth:`decode_step`. ``pool`` leaves are
        ``[num_periods, num_rows, kvH, page, hd]``; ``tables``: [B,P]
        page ids (trash-padded); B is the COMPACTED active batch, not
        max_slots. Per-row math is bit-identical to the dense step (see
        ``attention_decode_paged``)."""
        cfg = self.cfg
        x = jnp.take(params["embed"]["tokens"], tokens, axis=0)
        x = x.astype(L.dt(cfg))
        x = shd(x, "batch", "seq", "act_embed")

        if self.scan_layers:
            def body(x, xs):
                period_params, period_pool = xs
                new_pool = []
                for p_idx, pat in enumerate(self.cfg.block_pattern):
                    x, nc = self._block_decode_paged(
                        period_params[p_idx], pat, x, period_pool[p_idx],
                        tables, positions, page_size)
                    new_pool.append(nc)
                return x, tuple(new_pool)
            x, new_pool = jax.lax.scan(body, x, (params["layers"], pool))
        else:
            outs = []
            for i in range(cfg.num_periods):
                pp = jax.tree.map(lambda a: a[i], params["layers"])
                pc = jax.tree.map(lambda a: a[i], pool)
                ncs = []
                for p_idx, pat in enumerate(cfg.block_pattern):
                    x, nc = self._block_decode_paged(
                        pp[p_idx], pat, x, pc[p_idx], tables, positions,
                        page_size)
                    ncs.append(nc)
                outs.append(tuple(ncs))
            new_pool = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        w_out = (params["embed"]["tokens"].T if cfg.tie_embeddings
                 else params["lm_head"]["w"])
        logits = jnp.einsum("bsd,dv->bsv", x, w_out.astype(L.dt(cfg)))
        return logits[:, 0].astype(jnp.float32), new_pool

    def gather_paged_cache(self, pool, tables):
        """Gather each compacted row's page table out of the pool into
        the dense block-cache layout (``[np, B, kvH, P*page, hd]`` per
        leaf, i.e. ``init_cache(B, P*page)`` shapes). ``tables``: [B,P]
        int32, trash-padded — padded positions carry junk that downstream
        attention masks by position, exactly like a dense engine's stale
        rows."""
        def one(leaf):
            g = jnp.swapaxes(leaf[:, tables], 2, 3)  # [np,B,kvH,P,page,hd]
            np_, b, kvh, p, page, hd = g.shape
            return g.reshape(np_, b, kvh, p * page, hd)
        return jax.tree.map(one, pool)

    def scatter_block_writes(self, pool, cache, tables, positions,
                             k_steps: int, page_size: int):
        """Write the pages a K-step decode block can have touched back
        into the pool. A block starting at ``positions[b]`` writes the
        span ``[pos, pos+K)``, which lands on at most
        ``(K-1)//page + 2`` pages starting at ``pos // page``; everything
        else in the gathered view is byte-identical to the pool already,
        so rewriting a partially-touched page is idempotent. Page-id
        clamping to the last table column mirrors ``dynamic_slice``'s
        automatic start clamping, so an overshooting candidate rewrites
        the final page (or the trash row) with its own bytes."""
        n_rows, n_pages = tables.shape
        n_cand = (k_steps - 1) // page_size + 2

        def one(leaf, dense):
            np_, _, kvh, _, hd = dense.shape
            for b in range(n_rows):
                first = positions[b] // page_size
                for t in range(n_cand):
                    j = first + t
                    pid = tables[b, jnp.minimum(j, n_pages - 1)]
                    piece = jax.lax.dynamic_slice(
                        dense, (0, b, 0, j * page_size, 0),
                        (np_, 1, kvh, page_size, hd))
                    leaf = jax.lax.dynamic_update_slice(
                        leaf, piece.astype(leaf.dtype), (0, pid, 0, 0, 0))
            return leaf
        return jax.tree.map(one, pool, cache)

    def decode_block_paged(self, params, tokens, pool, tables, positions,
                           keys, temperatures, stop_ids, budgets, sample_fn,
                           page_size: int):
        """K paged decode steps in one compiled call. Rather than carry
        the pool through the scan (a per-step pool scatter is ~100x the
        cost of the gather on XLA:CPU), the block gathers each row's
        pages into a dense cache ONCE, runs the unmodified dense
        :meth:`decode_block` on it — bit-identical per-row math, which is
        what keeps paged greedy output byte-equal to the dense engine —
        and writes only the touched pages back at the end. ``tables`` is
        loop-invariant: every page a slot can touch is allocated at
        admission."""
        cache = self.gather_paged_cache(pool, tables)
        toks, lps, emitted, cache = self.decode_block(
            params, tokens, cache, positions, keys, temperatures,
            stop_ids, budgets, sample_fn)
        pool = self.scatter_block_writes(pool, cache, tables, positions,
                                         keys.shape[0], page_size)
        return toks, lps, emitted, pool

    def decode_block(self, params, tokens, cache, positions, keys,
                     temperatures, stop_ids, budgets, sample_fn,
                     step_fn=None):
        """K decode steps in one compiled call (``jax.lax.scan`` over the
        stacked ``keys``): the device-resident decode loop. Host dispatch,
        per-step Python overhead, and the token round-trip are amortized
        K-fold; stop-token / length / already-finished masking happens on
        device so a slot that finishes mid-block freezes (its token and
        position stop advancing, making the remaining cache writes
        idempotent re-writes of the same entry) without a host round-trip.

        tokens: [B,1] last emitted token per slot; positions: [B] absolute
        position of that token; keys: [K, ...] stacked PRNG keys, one per
        inner step (same one-key-per-decode-step schedule as K single-step
        dispatches, so sampled streams are reproducible across block
        sizes); temperatures: [B]; stop_ids: [B,W] per-slot stop tokens
        padded with -1; budgets: [B] int32 tokens each slot may still emit
        (0 = frozen — inactive slots ride along exactly like the
        single-step path's zero rows). ``sample_fn(key, logits,
        temperatures) -> (toks [B], lps [B])`` runs inside the scanned
        body (see ``repro.rl.sampling.sample_mixed``).

        Returns (toks [K,B], lps [K,B], emitted [K,B] bool, cache). Each
        slot's emitted column is a True-prefix: host code appends exactly
        the emitted tokens and re-derives stop/length finishing from them.
        """
        step = step_fn if step_fn is not None else self.decode_step

        def body(carry, key):
            tok, pos, rem, done, cache = carry
            logits, cache = step(params, tok, cache, pos)
            t, lp = sample_fn(key, logits, temperatures)
            emit = ~done
            # frozen rows re-feed their previous token at the same
            # position: the attention cache write is idempotent and a
            # recurrent state only advances in a slot that is finished
            # (and therefore re-prefilled before reuse)
            t = jnp.where(emit, t, tok[:, 0])
            lp = jnp.where(emit, lp, 0.0)
            rem = rem - emit.astype(rem.dtype)
            hit_stop = jnp.any(t[:, None] == stop_ids, axis=1)
            done = done | (emit & hit_stop) | (rem <= 0)
            pos = pos + emit.astype(pos.dtype)
            return (t[:, None], pos, rem, done, cache), (t, lp, emit)

        carry0 = (tokens, positions, budgets, budgets <= 0, cache)
        (_, _, _, _, cache), (toks, lps, emitted) = jax.lax.scan(
            body, carry0, keys)
        return toks, lps, emitted, cache

    def decode_step(self, params, tokens, cache, positions):
        """tokens: [B,1] int32; positions: [B] int32 (absolute positions).

        Returns (logits [B,V] fp32, new cache).
        """
        cfg = self.cfg
        x = jnp.take(params["embed"]["tokens"], tokens, axis=0)
        x = x.astype(L.dt(cfg))
        x = shd(x, "batch", "seq", "act_embed")

        if self.scan_layers:
            def body(x, xs):
                period_params, period_cache = xs
                new_caches = []
                for p_idx, pat in enumerate(self.cfg.block_pattern):
                    x, nc = self._block_decode(period_params[p_idx], pat, x,
                                               period_cache[p_idx], positions)
                    new_caches.append(nc)
                return x, tuple(new_caches)
            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        else:
            new_caches = []
            for i in range(cfg.num_periods):
                pp = jax.tree.map(lambda a: a[i], params["layers"])
                pc = jax.tree.map(lambda a: a[i], cache)
                ncs = []
                for p_idx, pat in enumerate(cfg.block_pattern):
                    x, nc = self._block_decode(pp[p_idx], pat, x,
                                               pc[p_idx], positions)
                    ncs.append(nc)
                new_caches.append(tuple(ncs))
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        w_out = (params["embed"]["tokens"].T if cfg.tie_embeddings
                 else params["lm_head"]["w"])
        logits = jnp.einsum("bsd,dv->bsv", x, w_out.astype(L.dt(cfg)))
        return logits[:, 0].astype(jnp.float32), new_cache

    # ------------------------------------------------------------------
    # prefill (fills KV/state caches, returns last-token logits)
    # ------------------------------------------------------------------
    def prefill(self, params, tokens, cache, cond=None, last_pos=None,
                slot=None):
        """tokens: [B,S]. Fills cache positions [0,S) and returns
        (logits [B,V] at position ``last_pos`` (default S-1), cache).

        With ``slot`` given (int or traced scalar), ``tokens`` is batch-1
        and ``cache`` is a FULL engine cache (leaves laid out
        ``[num_periods, max_slots, ...]``): the prompt's cache entries are
        written directly into that slot's batch row via
        ``dynamic_update_slice``, so admission prefill needs no transient
        batch-1 cache and — with the cache argument donated at the jit
        boundary — no full-cache copy either. Without ``slot`` the batch
        rows of ``tokens`` and ``cache`` correspond 1:1 (legacy mode,
        requires ``B == cache batch``).
        """
        cfg = self.cfg
        B, S = tokens.shape
        slot0 = 0 if slot is None else slot
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x = jnp.take(params["embed"]["tokens"], tokens, axis=0)
        x = x.astype(L.dt(cfg))
        if cond is not None:
            lc = cond.shape[1]
            x = jnp.concatenate([cond.astype(x.dtype), x[:, lc:, :]], axis=1)
        x = shd(x, "batch", "seq", "act_embed")

        def period_prefill(period_params, period_cache, x):
            new_caches = []
            for p_idx, pat in enumerate(cfg.block_pattern):
                bp = period_params[p_idx]
                mixer, ffn = pat
                h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
                if mixer == "attn":
                    cdt = L.dt(cfg)
                    q, k, v = L._qkv(bp["attn"], cfg, h, positions)
                    ccache = period_cache[p_idx]
                    clen = ccache["k"].shape[2]
                    kw = k[:, :, -clen:, :] if clen < S else k
                    vw = v[:, :, -clen:, :] if clen < S else v
                    if self.window is not None and clen == self.window:
                        # ring layout: token t lives in slot t % window
                        sl = (jnp.arange(max(S - clen, 0), S) % clen)
                        if slot is None:
                            k_c = ccache["k"].at[:, :, sl, :].set(
                                kw.astype(cdt))
                            v_c = ccache["v"].at[:, :, sl, :].set(
                                vw.astype(cdt))
                        else:
                            # slice the slot's batch row out first: mixing
                            # the scalar `slot` with the advanced index
                            # `sl` in one .at[] would move the advanced
                            # dims to the front (transposed write)
                            def ring_write(big, little):
                                row = jax.lax.dynamic_slice_in_dim(
                                    big, slot, 1, axis=0)
                                row = row.at[:, :, sl, :].set(
                                    little.astype(cdt))
                                return jax.lax.dynamic_update_slice_in_dim(
                                    big, row, slot, axis=0)
                            k_c = ring_write(ccache["k"], kw)
                            v_c = ring_write(ccache["v"], vw)
                    else:
                        k_c = jax.lax.dynamic_update_slice(
                            ccache["k"], kw.astype(cdt), (slot0, 0, 0, 0))
                        v_c = jax.lax.dynamic_update_slice(
                            ccache["v"], vw.astype(cdt), (slot0, 0, 0, 0))
                    out = L._attend_causal(q, k, v, cfg, self.window,
                                           q_chunk=self.q_chunk)
                    h = jnp.einsum("bnsh,nhd->bsd", out,
                                   bp["attn"]["wo"].astype(cdt))
                    nc = {"k": k_c, "v": v_c}
                elif mixer == "mamba":
                    h, h_state, conv = M.mamba_fwd(
                        bp["mamba"], cfg, h, return_state=True,
                        chunk=self.mamba_chunk)
                    nc = {"h": h_state, "conv": conv}
                    if slot is not None:
                        nc = jax.tree.map(
                            lambda big, little:
                            jax.lax.dynamic_update_slice_in_dim(
                                big, little.astype(big.dtype), slot, axis=0),
                            period_cache[p_idx], nc)
                else:
                    h, prev_x, S_out = R.rwkv_fwd(bp["rwkv"], cfg, h,
                                                  return_state=True)
                    nc = {"prev_x": prev_x, "S": S_out}
                    if slot is not None:
                        nc = jax.tree.map(
                            lambda big, little:
                            jax.lax.dynamic_update_slice_in_dim(
                                big, little.astype(big.dtype), slot, axis=0),
                            period_cache[p_idx], nc)
                x = x + h
                h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
                if ffn == "moe":
                    h, _ = MOE.moe_fwd(bp["moe"], cfg, h)
                else:
                    h = L.mlp_fwd(bp["mlp"], cfg, h)
                x = x + h
                new_caches.append(nc)
            return x, tuple(new_caches)

        if self.scan_layers:
            def body(x, xs):
                period_params, period_cache = xs
                x, ncs = period_prefill(period_params, period_cache, x)
                return x, ncs
            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        else:
            outs = []
            for i in range(cfg.num_periods):
                pp = jax.tree.map(lambda a: a[i], params["layers"])
                pc = jax.tree.map(lambda a: a[i], cache)
                x, ncs = period_prefill(pp, pc, x)
                outs.append(ncs)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if last_pos is None:
            x_last = x[:, -1, :]
        else:
            x_last = jnp.take_along_axis(
                x, last_pos[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        w_out = (params["embed"]["tokens"].T if cfg.tie_embeddings
                 else params["lm_head"]["w"])
        logits = jnp.einsum("bd,dv->bv", x_last, w_out.astype(L.dt(cfg)))
        return logits.astype(jnp.float32), new_cache

    def prefill_paged(self, params, tokens, pool, table, page_size: int,
                      last_pos=None, ctx_len=None):
        """Prefill a (tail of a) prompt into a slot's KV pages.

        tokens: [1, S] with S a page multiple (engine pads); table: [P]
        int32 page ids for the WHOLE slot, trash-padded past the
        allocation; last_pos: [1] index of the last real prompt token
        WITHIN ``tokens``.

        Two modes, selected statically so each gets its own compile:

        - ``ctx_len=None`` (fresh prompt, no prefix hit): positions start
          at 0 and attention runs ``_attend_causal`` over the tail alone —
          the exact op sequence of the dense :meth:`prefill`, so the tail
          logits (and the K/V bytes written to the pages) are bitwise
          identical to the dense engine's.
        - ``ctx_len`` a traced int32 scalar (prefix fork): ``ctx_len``
          cached prefix tokens (a page multiple) already sit in the
          slot's leading pages; the tail is written at positions
          ``ctx_len + [0, S)`` and attends over the full gathered table
          (cached prefix + its own causal tail, everything else masked
          to exact zeros).

        Returns (logits [1,V] fp32 at ``last_pos``, pool).
        """
        cfg = self.cfg
        if not self.supports_paged():
            raise ValueError(f"{cfg.name}: paged prefill needs an "
                             "attention-only, non-windowed stack")
        B, S = tokens.shape
        n_tail_pages = S // page_size
        P = table.shape[0]
        base = jnp.arange(S)[None, :]
        positions = base if ctx_len is None else base + ctx_len
        start_page = (jnp.int32(0) if ctx_len is None
                      else ctx_len // page_size)
        x = jnp.take(params["embed"]["tokens"], tokens, axis=0)
        x = x.astype(L.dt(cfg))
        x = shd(x, "batch", "seq", "act_embed")

        def write_pages(leaf, kv):
            # kv: [1, kvH, S, hd] tail K or V -> page-aligned scatter;
            # static page count, traced page ids (trash absorbs writes
            # past the allocation when the tail bucket overshoots)
            for j in range(n_tail_pages):
                piece = kv[:, :, j * page_size:(j + 1) * page_size, :]
                pid = table[start_page + j]
                leaf = jax.lax.dynamic_update_slice(
                    leaf, piece.astype(leaf.dtype), (pid, 0, 0, 0))
            return leaf

        def period_prefill(period_params, period_pool, x):
            new_pool = []
            for p_idx, pat in enumerate(cfg.block_pattern):
                bp = period_params[p_idx]
                cdt = L.dt(cfg)
                h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
                q, k, v = L._qkv(bp["attn"], cfg, h, positions)
                pl = period_pool[p_idx]
                k_p = write_pages(pl["k"], k)
                v_p = write_pages(pl["v"], v)
                if ctx_len is None:
                    out = L._attend_causal(q, k, v, cfg, None,
                                           q_chunk=self.q_chunk)
                else:
                    # gather the full table (cached prefix + the tail
                    # pages just written); mask mirrors _attend_causal:
                    # row i sees absolute positions <= ctx_len + i, the
                    # rest contribute exact zeros
                    kvh, hd = k_p.shape[1], k_p.shape[3]
                    kg = jnp.swapaxes(k_p[table], 0, 1)
                    kg = kg.reshape(1, kvh, P * page_size, hd)
                    vg = jnp.swapaxes(v_p[table], 0, 1)
                    vg = vg.reshape(1, kvh, P * page_size, hd)
                    scores = L._grouped_scores(q, kg, cfg)
                    t_idx = jnp.arange(P * page_size)[None, None, :]
                    mask = t_idx <= positions[0][:, None]
                    scores = jnp.where(mask[None, None], scores, L.NEG_INF)
                    probs = jax.nn.softmax(scores, axis=-1).astype(vg.dtype)
                    out = jnp.einsum("bkgst,bkth->bkgsh", probs, vg)
                    out = out.reshape(1, cfg.num_heads, S, cfg.head_dim)
                h = jnp.einsum("bnsh,nhd->bsd", out,
                               bp["attn"]["wo"].astype(cdt))
                x = x + h
                h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
                if pat[1] == "moe":
                    h, _ = MOE.moe_fwd(bp["moe"], cfg, h)
                else:
                    h = L.mlp_fwd(bp["mlp"], cfg, h)
                x = x + h
                new_pool.append({"k": k_p, "v": v_p})
            return x, tuple(new_pool)

        if self.scan_layers:
            def body(x, xs):
                period_params, period_pool = xs
                x, ncs = period_prefill(period_params, period_pool, x)
                return x, ncs
            x, new_pool = jax.lax.scan(body, x, (params["layers"], pool))
        else:
            outs = []
            for i in range(cfg.num_periods):
                pp = jax.tree.map(lambda a: a[i], params["layers"])
                pc = jax.tree.map(lambda a: a[i], pool)
                x, ncs = period_prefill(pp, pc, x)
                outs.append(ncs)
            new_pool = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if last_pos is None:
            x_last = x[:, -1, :]
        else:
            x_last = jnp.take_along_axis(
                x, last_pos[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        w_out = (params["embed"]["tokens"].T if cfg.tie_embeddings
                 else params["lm_head"]["w"])
        logits = jnp.einsum("bd,dv->bv", x_last, w_out.astype(L.dt(cfg)))
        return logits.astype(jnp.float32), new_pool


@functools.lru_cache(maxsize=64)
def build_model(cfg: ModelConfig, scan_layers: bool = True,
                remat: bool = True, window: Optional[int] = None) -> Model:
    return Model(cfg, scan_layers=scan_layers, remat=remat, window=window)

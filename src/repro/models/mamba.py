"""Mamba (S6) selective-scan mixer — used by the Jamba hybrid architecture.

Train/prefill uses a loop-free associative scan over the sequence (the
h_t = a_t*h_{t-1} + b_t recurrence), so XLA cost analysis sees the true
FLOPs and GSPMD shards d_inner over the "model" axis. The memory-efficient
blocked variant for TPU lives in ``repro.kernels.mamba_scan`` (Pallas).
Decode is the O(1) single-step state update.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shd
from repro.models.layers import dense_init, dt, pdt


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def init_mamba(key, cfg: ModelConfig) -> dict:
    d, di, ds = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dr, dc = dt_rank(cfg), cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), pdt(cfg)),
        "conv_w": dense_init(ks[1], (dc, di), pdt(cfg), scale=0.5),
        "conv_b": jnp.zeros((di,), pdt(cfg)),
        "x_proj": dense_init(ks[2], (di, dr + 2 * ds), pdt(cfg)),
        "dt_proj": dense_init(ks[3], (dr, di), pdt(cfg)),
        "dt_bias": jnp.full((di,), -4.6, pdt(cfg)),  # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(jnp.float32),
        "D": jnp.ones((di,), pdt(cfg)),
        "out_proj": dense_init(ks[4], (di, d), pdt(cfg)),
    }


def _causal_conv(p, cfg: ModelConfig, x, conv_state=None):
    """Depthwise causal conv over seq. x: [B,S,di]. conv_state: [B,dc-1,di]."""
    dc = cfg.mamba_d_conv
    w = p["conv_w"].astype(x.dtype)
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # [B, S+dc-1, di]
    out = sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(dc))
    out = out + p["conv_b"].astype(x.dtype)
    new_state = xp[:, -(dc - 1):, :] if dc > 1 else pad
    return out, new_state


def _ssm_inputs(p, cfg: ModelConfig, xc):
    """xc: [B,S,di] post-conv+silu. Returns (a, b, C) for the recurrence."""
    dr, ds = dt_rank(cfg), cfg.mamba_d_state
    xdbl = jnp.einsum("bsi,ir->bsr", xc, p["x_proj"].astype(xc.dtype))
    dt_r, Bm, Cm = jnp.split(xdbl, [dr, dr + ds], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"].astype(xc.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,S,di]
    A = -jnp.exp(p["A_log"])                             # [di,ds] fp32
    a = jnp.exp(delta[..., None] * A[None, None])        # [B,S,di,ds]
    b = (delta * xc.astype(jnp.float32))[..., None] * \
        Bm.astype(jnp.float32)[:, :, None, :]            # [B,S,di,ds]
    return a, b, Cm.astype(jnp.float32)


def _scan_combine(l, r):
    a1, b1 = l
    a2, b2 = r
    return a2 * a1, a2 * b1 + b2


def mamba_fwd(p, cfg: ModelConfig, x,
              h0=None, conv_state=None,
              return_state: bool = False,
              chunk: int = 256):
    """Full-sequence selective scan, chunked. x: [B,S,d].

    The [B,S,di,ds] discretized (a,b) tensors are only ever materialized one
    chunk at a time inside a checkpointed lax.scan (full-sequence
    materialization measured 225 GiB/device on jamba train_4k); within a
    chunk the recurrence is a loop-free associative scan. ``chunk >= S``
    degenerates to a single associative scan with no loop (used by the
    roofline cost mode, which must avoid while-ops).
    """
    cdt = dt(cfg)
    B, S, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cdt))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shd(xin, "batch", None, "mamba_inner")
    xc, new_conv = _causal_conv(p, cfg, xin, conv_state)
    xc = jax.nn.silu(xc)
    if h0 is None:
        h0 = jnp.zeros((B, cfg.mamba_d_inner, cfg.mamba_d_state), jnp.float32)

    def chunk_fwd(h_in, xc_c):
        a, b, Cm = _ssm_inputs(p, cfg, xc_c)             # [B,C,di,ds]
        a = shd(a, "batch", None, "mamba_inner", None)
        b = shd(b, "batch", None, "mamba_inner", None)
        a_cum, h_intra = jax.lax.associative_scan(_scan_combine, (a, b),
                                                  axis=1)
        h = h_intra + a_cum * h_in[:, None]              # fold carry state
        y = jnp.sum(h * Cm[:, :, None, :], axis=-1)      # [B,C,di]
        return h[:, -1], y

    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    if nc == 1:
        h_last, y = chunk_fwd(h0, xc)
    else:
        xs = jnp.moveaxis(xc.reshape(B, nc, c, -1), 1, 0)
        h_last, y = jax.lax.scan(jax.checkpoint(chunk_fwd), h0, xs)
        y = jnp.moveaxis(y, 0, 1).reshape(B, S, -1)
    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(cdt)
    y = shd(y, "batch", None, "mamba_inner")
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cdt))
    if return_state:
        return out, h_last, new_conv
    return out


def mamba_decode(p, cfg: ModelConfig, x, h, conv_state):
    """Single-token step. x: [B,1,d]; h: [B,di,ds]; conv_state: [B,dc-1,di]."""
    cdt = dt(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cdt))
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = _causal_conv(p, cfg, xin, conv_state)
    xc = jax.nn.silu(xc)
    a, b, Cm = _ssm_inputs(p, cfg, xc)                   # S == 1
    h_new = a[:, 0] * h + b[:, 0]                        # [B,di,ds]
    y = jnp.sum(h_new * Cm[:, 0, None, :], axis=-1)      # [B,di]
    y = y + p["D"].astype(jnp.float32) * xc[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(cdt)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"].astype(cdt))[:, None, :]
    return out, h_new, new_conv


def init_mamba_state(cfg: ModelConfig, batch: int) -> Tuple:
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    h = jnp.zeros((batch, di, ds), jnp.float32)
    conv = jnp.zeros((batch, dc - 1, di), dt(cfg))
    return h, conv

"""RWKV6 ("Finch") time-mix: linear attention with data-dependent per-channel
decay, computed in the chunked formulation (intra-chunk matmuls + inter-chunk
associative scan over boundary states). Loop-free, MXU-friendly, and the same
algorithm the Pallas kernel (repro.kernels.rwkv6_scan) implements with VMEM
tiles.

Recurrence (per head, state S in R^{hd x hd}):
    y_t = r_t @ (S_{t-1} + (u * k_t)^T v_t)
    S_t = diag(w_t) @ S_{t-1} + k_t^T v_t
with w_t = exp(-exp(decay(x_t))) in (0,1), per channel.

Numerical note: log-decay is clamped to [LW_MIN, LW_MAX] so that within a
chunk of RWKV_CHUNK tokens every intermediate exponent stays < 88 (fp32 exp
overflow); the clamp is inherited by the Pallas kernel and documented in
DESIGN.md.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shd
from repro.models.layers import dense_init, dt, pdt

RWKV_CHUNK = 32
LW_MIN = -2.5        # per-token log-decay floor: 32 * 2.5 = 80 < 88
LW_MAX = -1e-4
DECAY_LORA = 64


def init_rwkv(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "wr": dense_init(ks[0], (d, d), pdt(cfg)),
        "wk": dense_init(ks[1], (d, d), pdt(cfg)),
        "wv": dense_init(ks[2], (d, d), pdt(cfg)),
        "wg": dense_init(ks[3], (d, d), pdt(cfg)),
        "wo": dense_init(ks[4], (d, d), pdt(cfg)),
        "decay_w1": dense_init(ks[5], (d, DECAY_LORA), pdt(cfg)),
        "decay_w2": dense_init(ks[6], (DECAY_LORA, d), pdt(cfg)),
        "decay_bias": jnp.full((d,), 0.0, jnp.float32),
        "bonus": dense_init(ks[7], (d,), jnp.float32, scale=0.5),
        # token-shift lerp coefficients for r/k/v/g/w
        "mu": jnp.full((5, d), 0.5, pdt(cfg)),
    }


def _projections(p, cfg: ModelConfig, x, prev_x):
    """Token-shifted projections. x: [B,S,d]; prev_x: [B,d] (state)."""
    cdt = dt(cfg)
    xs = jnp.concatenate([prev_x[:, None, :], x[:, :-1, :]], axis=1)
    mu = p["mu"].astype(cdt)

    def mix(i):
        return x * mu[i] + xs * (1.0 - mu[i])

    r = jnp.einsum("bsd,de->bse", mix(0), p["wr"].astype(cdt))
    k = jnp.einsum("bsd,de->bse", mix(1), p["wk"].astype(cdt))
    v = jnp.einsum("bsd,de->bse", mix(2), p["wv"].astype(cdt))
    g = jnp.einsum("bsd,de->bse", mix(3), p["wg"].astype(cdt))
    dec = jnp.einsum("bsd,dl->bsl", mix(4), p["decay_w1"].astype(cdt))
    dec = jnp.einsum("bsl,ld->bsd", jnp.tanh(dec), p["decay_w2"].astype(cdt))
    lw = -jnp.exp(dec.astype(jnp.float32) + p["decay_bias"])
    lw = jnp.clip(lw, LW_MIN, LW_MAX)                    # log-decay [B,S,d]
    return r, k, v, g, lw


def _heads(x, H: int):
    B, S, d = x.shape
    return x.reshape(B, S, H, d // H)


def _chunked_wkv(r, k, v, lw, u, S0):
    """Chunked WKV6. r/k/v/lw: [B,S,H,hd] (lw fp32); u: [H,hd];
    S0: [B,H,hd,hd] initial state. Returns (y [B,S,H,hd], S_out)."""
    B, S, H, hd = r.shape
    C = min(RWKV_CHUNK, S)
    while S % C:   # largest chunk size <= RWKV_CHUNK dividing S
        C -= 1
    nc = S // C
    rt = r.reshape(B, nc, C, H, hd).astype(jnp.float32)
    kt = k.reshape(B, nc, C, H, hd).astype(jnp.float32)
    vt = v.reshape(B, nc, C, H, hd).astype(jnp.float32)
    lwt = lw.reshape(B, nc, C, H, hd)

    cs = jnp.cumsum(lwt, axis=2)                         # [B,nc,C,H,hd]
    total = cs[:, :, -1]                                 # [B,nc,H,hd]

    # intra-chunk: scores[i,j] = sum_hd r_i k_j exp(cs_{i-1} - cs_j), j < i
    q_in = rt * jnp.exp(cs - lwt)                        # exp(cs_{i-1})
    k_in = kt * jnp.exp(-cs)
    scores = jnp.einsum("bnihe,bnjhe->bnhij", q_in, k_in)
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    # u-bonus diagonal
    diag = jnp.einsum("bnihe,he,bnihe->bnih", rt, u.astype(jnp.float32), kt)
    y_intra = jnp.einsum("bnhij,bnjhe->bnihe", scores, vt) \
        + diag[..., None] * vt

    # inter-chunk boundary states: S_c = diag(exp(total_c)) S_{c-1} + T_c
    # T_c = sum_j exp(total_c - cs_j) k_j (x) v_j
    k_tail = kt * jnp.exp(total[:, :, None] - cs)        # [B,nc,C,H,hd]
    T = jnp.einsum("bnjhe,bnjhf->bnhef", k_tail, vt)     # [B,nc,H,hd,hd]
    decay = jnp.exp(total)                               # [B,nc,H,hd]

    def combine(left, right):
        a1, s1 = left
        a2, s2 = right
        return a1 * a2, a2[..., None] * s1 + s2

    a_cum, S_cum = jax.lax.associative_scan(combine, (decay, T), axis=1)
    # state entering chunk c is S_{c-1} (with S0 folded in)
    S_in = jnp.concatenate(
        [S0[:, None], S_cum[:, :-1]
         + (a_cum[:, :-1, ..., None] * S0[:, None])], axis=1)
    y_inter = jnp.einsum("bnihe,bnhef->bnihf", q_in, S_in)
    S_out = S_cum[:, -1] + a_cum[:, -1, ..., None] * S0

    y = (y_intra + y_inter).reshape(B, S, H, hd)
    return y, S_out


def _groupnorm_heads(y, eps: float):
    """Per-head layernorm on the wkv output (RWKV's GroupNorm)."""
    yf = y.astype(jnp.float32)
    mean = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    return (yf - mean) * jax.lax.rsqrt(var + eps)


def rwkv_fwd(p, cfg: ModelConfig, x, prev_x=None, S0=None,
             return_state: bool = False):
    """Full-sequence RWKV6 time-mix. x: [B,S,d]."""
    B, S, d = x.shape
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    cdt = dt(cfg)
    if prev_x is None:
        prev_x = jnp.zeros((B, d), cdt)
    if S0 is None:
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    r, k, v, g, lw = _projections(p, cfg, x, prev_x)
    u = p["bonus"].reshape(H, hd)
    y, S_out = _chunked_wkv(_heads(r, H), _heads(k, H), _heads(v, H),
                            _heads(lw, H), u, S0)
    y = _groupnorm_heads(y, cfg.norm_eps).reshape(B, S, d)
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(cdt)
    y = shd(y, "batch", "seq", "rwkv_out")
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(cdt))
    if return_state:
        return out, x[:, -1, :], S_out
    return out


def rwkv_decode(p, cfg: ModelConfig, x, prev_x, S0):
    """Single-token step. x: [B,1,d]; prev_x: [B,d]; S0: [B,H,hd,hd]."""
    B, _, d = x.shape
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    cdt = dt(cfg)
    r, k, v, g, lw = _projections(p, cfg, x, prev_x)
    rh, kh, vh = (_heads(t, H)[:, 0].astype(jnp.float32) for t in (r, k, v))
    lwh = _heads(lw, H)[:, 0]                            # [B,H,hd]
    u = p["bonus"].reshape(H, hd)
    kv = kh[..., :, None] * vh[..., None, :]             # [B,H,hd,hd]
    y = jnp.einsum("bhe,bhef->bhf", rh, S0 + u[None, :, :, None] * kv)
    S_out = jnp.exp(lwh)[..., None] * S0 + kv
    y = _groupnorm_heads(y, cfg.norm_eps).reshape(B, 1, d)
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(cdt)
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(cdt))
    return out, x[:, 0, :], S_out


def init_rwkv_state(cfg: ModelConfig, batch: int) -> Tuple:
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    prev_x = jnp.zeros((batch, cfg.d_model), dt(cfg))
    S = jnp.zeros((batch, H, hd, hd), jnp.float32)
    return prev_x, S

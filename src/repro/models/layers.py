"""Core transformer layers: RMSNorm, RoPE, GQA attention (qk-norm, sliding
window, KV-cache decode), gated MLP. Pure-function style: ``init_*`` builds a
param dict, ``*_fwd`` applies it. All matmuls run in ``cfg.dtype`` with
fp32 softmax/norm accumulation.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shd

NEG_INF = -1e30


def dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rmsnorm_head(scale, x, eps: float):
    """qk-norm over the head dim; scale shape [head_dim]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.num_heads, hd), pdt(cfg)),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads, hd), pdt(cfg)),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads, hd), pdt(cfg)),
        "wo": dense_init(ks[3], (cfg.num_heads, hd, d), pdt(cfg),
                         scale=1.0 / math.sqrt(cfg.num_heads * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), pdt(cfg))
        p["k_norm"] = jnp.ones((hd,), pdt(cfg))
    return p


def _qkv(p, cfg: ModelConfig, x, positions):
    cdt = dt(cfg)
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(cdt))
    if cfg.qk_norm:
        q = rmsnorm_head(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_head(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta)
    k = apply_rope(k.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta)
    return q, k, v.swapaxes(1, 2)  # [B, H, S, hd] / [B, kvH, S, hd]


def _grouped_scores(q, k, cfg: ModelConfig):
    """q: [B,H,S,hd], k: [B,kvH,T,hd] -> scores [B,kvH,G,S,T] (fp32)."""
    B, H, S, hd = q.shape
    G = H // cfg.num_kv_heads
    qg = q.reshape(B, cfg.num_kv_heads, G, S, hd)
    scores = jnp.einsum("bkgsh,bkth->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    return scores / math.sqrt(hd)


def _attend_causal(q, k, v, cfg: ModelConfig, window: Optional[int],
                   q_chunk: int = 1024):
    """Causal attention over full K/V, blocked over the query dim so the
    [S,S] score matrix is never materialized (the XLA-path analogue of the
    Pallas flash-attention kernel). q: [B,H,S,hd]; k/v: [B,kvH,S,hd]."""
    B, H, S, hd = q.shape
    G = H // cfg.num_kv_heads
    cq = min(q_chunk, S)
    while S % cq:
        cq -= 1
    nb = S // cq
    qg = q.reshape(B, cfg.num_kv_heads, G, nb, cq, hd)
    j = jnp.arange(S)[None, :]

    def block(carry, xs):
        qb, blk = xs                                     # [B,kvH,G,cq,hd]
        i = blk * cq + jnp.arange(cq)[:, None]
        scores = jnp.einsum("bkgsh,bkth->bkgst", qb, k,
                            preferred_element_type=jnp.float32)
        scores = scores / math.sqrt(hd)
        mask = j <= i
        if window is not None:
            mask &= (i - j) < window
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        ob = jnp.einsum("bkgst,bkth->bkgsh", probs, v)
        return carry, ob

    if nb == 1:
        _, out = block(None, (qg[:, :, :, 0], jnp.int32(0)))
        out = out[:, :, :, None]
    else:
        _, out = jax.lax.scan(jax.checkpoint(block), None,
                              (jnp.moveaxis(qg, 3, 0), jnp.arange(nb)))
        out = jnp.moveaxis(out, 0, 3)                    # [B,kvH,G,nb,cq,hd]
    return out.reshape(B, H, S, hd)


def attention_fwd(p, cfg: ModelConfig, x, positions,
                  window: Optional[int] = None, q_chunk: int = 1024):
    """Full-sequence causal attention. x: [B,S,d], positions: [B,S]."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    q = shd(q, "batch", "act_heads", "seq", None)
    out = _attend_causal(q, k, v, cfg, window, q_chunk=q_chunk)
    out = shd(out, "batch", "act_heads", "seq", None)
    return jnp.einsum("bnsh,nhd->bsd", out, p["wo"].astype(dt(cfg)))


def attention_decode(p, cfg: ModelConfig, x, k_cache, v_cache, positions,
                     lengths, window: Optional[int] = None):
    """One-token decode against a KV cache.

    x: [B,1,d]; k_cache/v_cache: [B,kvH,S_cache,hd]; positions: [B] absolute
    position of the new token; lengths: [B] valid cache length (== positions
    for dense cache). With ``window`` the cache is a ring buffer of size
    S_cache==window and slots are addressed mod window.

    Returns (out [B,1,d], k_cache, v_cache) with the new K/V written in.
    """
    B = x.shape[0]
    S_cache = k_cache.shape[2]
    cdt = dt(cfg)
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(cdt))
    if cfg.qk_norm:
        q = rmsnorm_head(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_head(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q.swapaxes(1, 2), positions[:, None, None], cfg.rope_theta)
    k_new = apply_rope(k.swapaxes(1, 2), positions[:, None, None],
                       cfg.rope_theta)                    # [B,kvH,1,hd]
    v_new = v.swapaxes(1, 2)

    slot = positions % S_cache if window is not None else positions
    onehot = jax.nn.one_hot(slot, S_cache, dtype=cdt)     # [B,S_cache]
    # PERF(iter 2b, decode): keep the write mask sharded like the cache seq
    # axis, otherwise GSPMD materializes a fully-gathered cache around the
    # elementwise update
    onehot = shd(onehot, "batch", "cache_seq")
    k_cache = k_cache * (1 - onehot[:, None, :, None]) + \
        onehot[:, None, :, None] * k_new
    v_cache = v_cache * (1 - onehot[:, None, :, None]) + \
        onehot[:, None, :, None] * v_new
    k_cache = shd(k_cache, "batch", "kv_heads", "cache_seq", None)
    v_cache = shd(v_cache, "batch", "kv_heads", "cache_seq", None)

    scores = _grouped_scores(q, k_cache, cfg)             # [B,kvH,G,1,S_cache]
    # PERF(iter 2, decode): keep scores sharded over the cache-seq axis so
    # softmax stats + PV partials all-reduce ~100 KB/layer instead of
    # all-gathering the multi-GB KV cache (EXPERIMENTS.md §Perf)
    scores = shd(scores, "batch", None, None, None, "cache_seq")
    idx = jnp.arange(S_cache)[None, :]                    # [1,S_cache]
    if window is not None:
        age = positions[:, None] - \
            (idx + ((positions[:, None] - idx) // S_cache) * S_cache)
        valid = (age >= 0) & (age < jnp.minimum(lengths + 1, S_cache)[:, None])
    else:
        valid = idx <= positions[:, None]
        valid &= idx < jnp.maximum(lengths + 1, 1)[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
    out = jnp.einsum("bkgst,bkth->bkgsh", probs, v_cache)
    out = out.reshape(B, cfg.num_heads, 1, cfg.head_dim)
    y = jnp.einsum("bnsh,nhd->bsd", out, p["wo"].astype(cdt))
    return y, k_cache, v_cache


def attention_decode_paged(p, cfg: ModelConfig, x, k_pool, v_pool, tables,
                           positions, page_size: int):
    """One-token decode against a PAGED KV pool.

    x: [B,1,d]; k_pool/v_pool: [N+1, kvH, page, hd] (row N is the trash
    page absorbing padded rows' writes); tables: [B,P] int32 page ids
    (padded with the trash id); positions: [B] absolute position of the
    new token. ``P * page_size`` must equal the dense engine's
    ``max_len``: the gather below always materializes the FULL table
    width, so the attention reduction runs over exactly the same axis
    length — and therefore exactly the same partial-sum grouping — as
    ``attention_decode``. Masked positions contribute exact zeros either
    way, which is what makes paged-vs-dense greedy decode byte-identical
    (the perf win is batch compaction: B is the POW2-bucketed ACTIVE
    slot count, not max_slots). The Pallas counterpart that also skips
    empty pages is ``kernels.decode_attention.ragged_paged_decode``.

    Returns (out [B,1,d], k_pool, v_pool) with the new K/V scattered
    into each row's current page.
    """
    B = x.shape[0]
    P = tables.shape[1]
    kvH, hd = k_pool.shape[1], k_pool.shape[3]
    cdt = dt(cfg)
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(cdt))
    if cfg.qk_norm:
        q = rmsnorm_head(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_head(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q.swapaxes(1, 2), positions[:, None, None], cfg.rope_theta)
    k_new = apply_rope(k.swapaxes(1, 2), positions[:, None, None],
                       cfg.rope_theta)                    # [B,kvH,1,hd]
    v_new = v.swapaxes(1, 2)

    # scatter the new K/V at (page_table[b, pos//page], pos%page): the
    # write VALUE is the same bits attention_decode's one-hot update
    # produces (0*old + 1*new == new)
    pid = jnp.take_along_axis(tables, (positions // page_size)[:, None],
                              axis=1)[:, 0]               # [B]
    off = positions % page_size
    k_pool = k_pool.at[pid, :, off, :].set(k_new[:, :, 0, :])
    v_pool = v_pool.at[pid, :, off, :].set(v_new[:, :, 0, :])
    k_pool = shd(k_pool, None, "cache_kv_heads", "cache_page_seq", None)
    v_pool = shd(v_pool, None, "cache_kv_heads", "cache_page_seq", None)

    # gather each row's pages to a dense [B,kvH,P*page,hd] view
    kg = jnp.moveaxis(k_pool[tables], 2, 1).reshape(B, kvH, P * page_size, hd)
    vg = jnp.moveaxis(v_pool[tables], 2, 1).reshape(B, kvH, P * page_size, hd)

    scores = _grouped_scores(q, kg, cfg)                  # [B,kvH,G,1,T]
    idx = jnp.arange(P * page_size)[None, :]
    valid = idx <= positions[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
    out = jnp.einsum("bkgst,bkth->bkgsh", probs, vg)
    out = out.reshape(B, cfg.num_heads, 1, cfg.head_dim)
    y = jnp.einsum("bnsh,nhd->bsd", out, p["wo"].astype(cdt))
    return y, k_pool, v_pool


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), pdt(cfg)),
        "w_up": dense_init(ks[1], (d, f), pdt(cfg)),
        "w_down": dense_init(ks[2], (f, d), pdt(cfg)),
    }


def mlp_fwd(p, cfg: ModelConfig, x):
    cdt = dt(cfg)
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cdt))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cdt))
    h = jax.nn.silu(g) * u
    h = shd(h, "batch", "seq", "act_mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cdt))

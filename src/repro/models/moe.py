"""Mixture-of-Experts layer: top-k routing with grouped, capacity-based
token dispatch (MaxText-style).

Tokens are dispatched *per group* (group = batch row), so the routing
cumsum/scatter stay sharded over the "data" mesh axis instead of forcing a
replicated prefix-sum over all 1M batch-tokens (which cost ~37 GiB/device in
the flat formulation — see EXPERIMENTS.md §Perf). Expert weights are sharded
on the expert dim over the "model" axis (expert parallelism); GSPMD lowers
the dispatch/combine gathers into the all-to-all traffic the paper's MoE
workloads exercise. Tokens above a group's per-expert capacity are dropped
(standard capacity semantics).

Aux losses: load-balance (Switch-style) + router z-loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shd
from repro.models.layers import dense_init, dt, pdt


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, f), pdt(cfg)),
        "w_up": dense_init(ks[2], (e, d, f), pdt(cfg)),
        "w_down": dense_init(ks[3], (e, f, d), pdt(cfg)),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared_gate"] = dense_init(ks[4], (d, fs), pdt(cfg))
        p["shared_up"] = dense_init(ks[5], (d, fs), pdt(cfg))
        p["shared_down"] = dense_init(ks[6], (fs, d), pdt(cfg))
    return p


def group_capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor
                      / cfg.num_experts))
    return max(1, min(c, tokens_per_group))


TOKENS_PER_GROUP = 256


def moe_fwd(p, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, dict]:
    """x: [B,S,d] -> (y [B,S,d], aux {lb_loss, z_loss, expert_load}).

    Tokens are split into routing groups of ~TOKENS_PER_GROUP tokens,
    aligned with the (batch x sequence-shard) layout, so routing/cumsum/
    scatter are fully sharded over BOTH mesh axes and never force a
    sequence all-gather; the expert einsum's resharding (groups:
    data x model -> experts: model) is the dispatch all-to-all, exactly as
    in expert-parallel production systems. Per-group capacity
    C = tokens_per_group * top_k * cf / E.
    """
    B, S_full, d = x.shape
    cdt = dt(cfg)
    E, K = cfg.num_experts, cfg.top_k
    # NOTE(hillclimb): sub-grouping groups to (batch x seq-shard) granularity
    # and sharding G over (data, model) was tried and REGRESSED badly under
    # GSPMD (temp 20->135 GiB, collectives 56->289 GiB on qwen3-moe train_4k:
    # the merged-dim reshape forces resharding of every routing tensor).
    # Batch-row groups keep routing data-sharded and are the measured best.
    nsub = 1
    S = S_full // nsub                                      # tokens per group
    x = x.reshape(B * nsub, S, d)
    C = group_capacity(S, cfg)

    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))    # [G,S,E]
    G = x.shape[0]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, K)           # [G,S,K]
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- aux losses (global over all tokens) ------------------------------
    me = probs.mean(axis=(0, 1))                            # [E]
    assign = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32).sum(2)  # [G,S,E]
    ce = assign.mean(axis=(0, 1)) / K
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- per-group dispatch indices ---------------------------------------
    # ranks: position of each (token, k) assignment within its expert's
    # buffer, counted over the flattened (token-major, k-minor) order.
    flat_e = topk_idx.reshape(G, S * K)                     # [G,S*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # [G,S*K,E]
    ranks = jnp.cumsum(onehot, axis=1) - onehot             # rank within group
    pos = jnp.take_along_axis(ranks, flat_e[..., None],
                              axis=2)[..., 0]               # [G,S*K]
    keep = pos < C
    buf_idx = jnp.where(keep, flat_e * C + pos,
                        E * C).reshape(G, S, K)             # OOB -> dropped

    # --- scatter into per-group expert buffers ----------------------------
    # one scatter per k (K small): avoids materializing the [G, S*K, d]
    # gathered-token tensor that dominated memory in the flat formulation
    xc = x.astype(cdt)

    def scatter_group(xg, idxg):                            # [S,d], [S,K]
        buf = jnp.zeros((E * C + 1, d), cdt)
        for k in range(K):
            buf = buf.at[idxg[:, k]].add(xg)
        return buf
    buffers = jax.vmap(scatter_group)(xc, buf_idx)
    buffers = buffers[:, : E * C].reshape(G, E, C, d)
    # groups: (data x model) -> (pod, data); experts -> model. This
    # resharding is the dispatch all-to-all.
    buffers = shd(buffers, "batch", "act_experts", None, None)

    # --- expert compute ----------------------------------------------------
    # PERF(iter 4, REFUTED): merging the group dim into each expert's token
    # dim (one [d,f] dW matmul per expert) was predicted to collapse the
    # per-group dW partials; measured temp 52.6 -> 194 GiB and flops x2.5 on
    # jamba — the [G(data),E(model)] swap/merge forces GSPMD to replicate
    # the dispatch tensor. THIRD refutation of the merge-the-sharded-dims
    # family (with P9 and iter 2A): on a (data, model) mesh, keep dispatch
    # tensors in [G, E, C, d] layout and let the per-group batched matmul
    # stand. See EXPERIMENTS.md §Perf.
    g = jnp.einsum("gecd,edf->gecf", buffers, p["w_gate"].astype(cdt))
    u = jnp.einsum("gecd,edf->gecf", buffers, p["w_up"].astype(cdt))
    h = jax.nn.silu(g) * u
    y_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(cdt))
    y_e = shd(y_e, "batch", "act_experts", None, None)
    y_flat = y_e.reshape(G, E * C, d)
    y_flat = jnp.concatenate(
        [y_flat, jnp.zeros((G, 1, d), cdt)], axis=1)        # OOB row
    # PERF(iter 2, REFUTED twice): forcing y_flat to group(data)-sharded
    # before the combine was predicted to replace a ~4 GiB gather-reduce
    # with a ~170 MB all-gather, but GSPMD instead replicated the routing
    # tensors (coll 43 -> 110 GiB/dev, temp 22 -> 87 GiB). Left unconstrained.

    # --- combine ------------------------------------------------------------
    w = (gate_vals * keep.reshape(G, S, K)).astype(cdt)     # [G,S,K]

    def combine_group(yg, idxg, wg):                        # [EC+1,d],[S,K],[S,K]
        y = jnp.zeros((S, d), cdt)
        for k in range(K):
            y = y + yg[idxg[:, k]] * wg[:, k, None]
        return y
    y = jax.vmap(combine_group)(y_flat, buf_idx, w)         # [G,S,d]
    y = y.reshape(B, S_full, d)

    if cfg.num_shared_experts:
        xf = x.reshape(B, S_full, d)
        gs = jnp.einsum("bsd,df->bsf", xf, p["shared_gate"].astype(cdt))
        us = jnp.einsum("bsd,df->bsf", xf, p["shared_up"].astype(cdt))
        hs = jax.nn.silu(gs) * us
        hs = shd(hs, "batch", "seq", "act_mlp")
        y = y + jnp.einsum("bsf,fd->bsd", hs, p["shared_down"].astype(cdt))

    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "expert_load": ce}
    return y, aux


def moe_aux_loss(aux: dict, cfg: ModelConfig):
    return (cfg.router_aux_coef * aux["lb_loss"]
            + cfg.router_z_coef * aux["z_loss"])

"""Rollout-as-a-Service: the multi-tenant streaming serving tier over the
disaggregated data plane (service loop, job/ticket request boundary,
per-tenant weighted QoS, incremental token streams)."""
from repro.serve.service import (JobState, JobTicket, RolloutJob,
                                 RolloutService, Tenant)
from repro.serve.stream import StreamChunk, TokenStream

__all__ = ["JobState", "JobTicket", "RolloutJob", "RolloutService",
           "Tenant", "StreamChunk", "TokenStream"]

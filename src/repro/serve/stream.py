"""Incremental token streams (StreamRL-style disaggregated stream
generation): tokens are delivered to the consumer as the engines emit
them, not at trajectory end, so time-to-first-token and per-token tail
latency become measurable quantities instead of being hidden inside a
blocking generate() call.

A :class:`TokenStream` is the consumer half of one rollout job. Producers
(the engine progress hooks routed through ``LLMProxy`` plus the service's
final-result callback) push CUMULATIVE per-request token lists; the stream
keeps a per-request delivered offset and appends only the unseen suffix,
which makes delivery idempotent — replays after an engine handoff, a
weight-sync re-prefill, or a fault-tolerance re-injection collapse into
no-ops instead of duplicating tokens. Per request id the delivered stream
is therefore monotonic and gap-free by construction (chunk ``k`` starts
exactly where chunk ``k-1`` ended).

Locking: ``TokenStream._cv`` is a LEAF lock — push/close never call out
while holding it, so producers may push from under the engine's
``_step_lock`` (via the proxy progress hook) without joining any
cross-class lock cycle.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Iterator, List, Optional


@dataclasses.dataclass
class StreamChunk:
    """One incremental delivery: ``tokens`` are the request's new tokens
    ``[start, start + len(tokens))`` — consecutive chunks of the same
    ``request_id`` tile the stream with no gaps or overlaps."""
    request_id: str
    start: int                    # offset into the request's new tokens
    tokens: List[int]
    logprobs: List[float]
    t: float = 0.0                # arrival time (time.monotonic())

    @property
    def end(self) -> int:
        return self.start + len(self.tokens)


class TokenStream:
    """Thread-safe incremental token stream for one rollout job.

    Producers call :meth:`push` with the CUMULATIVE new-token list of a
    request (what ``_Slot.new_tokens`` / ``GenResult.tokens`` hold);
    consumers iterate chunks (:meth:`get`, :meth:`__iter__`) or wait for
    completion (:meth:`result_tokens`). One stream can multiplex several
    request ids (a multi-turn env job issues one request per turn).
    """

    def __init__(self, job_id: str = ""):
        self.job_id = job_id
        self._cv = threading.Condition()
        self._chunks: List[StreamChunk] = []       # guarded by: _cv
        self._cursor = 0                           # guarded by: _cv
        self._delivered: Dict[str, int] = {}       # guarded by: _cv
        self.closed = False                        # guarded by: _cv
        self.finish_reason: Optional[str] = None   # guarded by: _cv
        self.created_t = time.monotonic()
        self.first_token_t: Optional[float] = None  # guarded by: _cv

    # -- producer side --------------------------------------------------
    def push(self, request_id: str, cum_tokens: List[int],
             cum_logprobs: List[float]) -> int:
        """Deliver the unseen suffix of ``cum_tokens`` (idempotent: a
        replayed or shorter cumulative list is a no-op). Returns the
        number of newly delivered tokens."""
        with self._cv:
            if self.closed:
                return 0
            seen = self._delivered.get(request_id, 0)
            if len(cum_tokens) <= seen:
                return 0
            now = time.monotonic()
            chunk = StreamChunk(
                request_id=request_id, start=seen,
                tokens=list(cum_tokens[seen:]),
                logprobs=list(cum_logprobs[seen:len(cum_tokens)]),
                t=now)
            self._delivered[request_id] = len(cum_tokens)
            self._chunks.append(chunk)
            if self.first_token_t is None:
                self.first_token_t = now
            self._cv.notify_all()
            return len(chunk.tokens)

    def close(self, finish_reason: str = "stop"):
        """Idempotent: the first close wins the finish reason."""
        with self._cv:
            if not self.closed:
                self.closed = True
                self.finish_reason = finish_reason
            self._cv.notify_all()

    # -- consumer side --------------------------------------------------
    def get(self, timeout: Optional[float] = None) -> Optional[StreamChunk]:
        """Next undelivered chunk; None once the stream is closed and
        drained. Raises TimeoutError if nothing arrives in time."""
        with self._cv:
            def ready():
                return self._cursor < len(self._chunks) or self.closed
            if not self._cv.wait_for(ready, timeout=timeout):
                raise TimeoutError(
                    f"stream {self.job_id!r}: no chunk within {timeout}s")
            if self._cursor < len(self._chunks):
                chunk = self._chunks[self._cursor]
                self._cursor += 1
                return chunk
            return None

    def __iter__(self) -> Iterator[StreamChunk]:
        while True:
            chunk = self.get()
            if chunk is None:
                return
            yield chunk

    # -- inspection ------------------------------------------------------
    def chunks(self) -> List[StreamChunk]:
        """Every chunk delivered so far (the consumer cursor is not
        advanced — latency analysis reads this after the fact)."""
        with self._cv:
            return list(self._chunks)

    def token_count(self) -> int:
        with self._cv:
            return sum(self._delivered.values())

    def tokens_for(self, request_id: str) -> List[int]:
        """The request's delivered tokens, reassembled from its chunks."""
        with self._cv:
            out: List[int] = []
            for c in self._chunks:
                if c.request_id == request_id:
                    assert c.start == len(out), \
                        f"stream gap: chunk starts at {c.start}, " \
                        f"delivered {len(out)}"
                    out.extend(c.tokens)
            return out

    def result_tokens(self, timeout: Optional[float] = None) -> List[int]:
        """Block until the stream closes; all delivered tokens in chunk
        order (single-request jobs: the full generation)."""
        with self._cv:
            if not self._cv.wait_for(lambda: self.closed, timeout=timeout):
                raise TimeoutError(
                    f"stream {self.job_id!r} not closed within {timeout}s")
            return [t for c in self._chunks for t in c.tokens]

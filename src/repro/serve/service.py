"""Rollout-as-a-Service: the multi-tenant serving tier that owns the
data-plane dispatch loop (ProRL-Agent-style rollout jobs as a service
boundary; ROADMAP item 1).

Before this tier existed, ``LiveRLRunner`` drove ``LLMProxy.pump()``
directly from a private worker loop and was therefore the only possible
client of the disaggregated data plane. :class:`RolloutService` lifts that
loop out: tenants register with a weight and optional in-flight cap,
submit :class:`RolloutJob`\\ s (prompt completions or full env-group
rollouts) and get back a :class:`JobTicket` whose
:class:`~repro.serve.stream.TokenStream` delivers tokens incrementally as
the engines emit them. The trainer is tenant #0 — it reaches the engines
through exactly the same admission path an external client uses.

Scheduling is stride-based weighted fair queueing: each tenant carries a
virtual time that advances by ``1 / weight`` per admitted job, and
admission always picks the eligible tenant with the smallest virtual
time — so under overload the measured share of admitted work tracks the
configured weights (benchmarks/traffic_gen.py measures this). Eligibility
= queued work (or a pull ``source`` that yields a job), in-flight below
the tenant's ``max_inflight``; the service-wide ``max_inflight`` bounds
the total admission window so overload queues at the service, where the
stride scheduler arbitrates, instead of draining unchecked into the
engine FIFO. A full per-tenant queue rejects at submit time
(backpressure, ``JobState.REJECTED``).

Locking (machine-checked by ``python -m repro.analysis``):

- ``_lock`` (RLock) is the SERVICE lock — the role the runner's old pump
  lock played. The service worker holds it for each tick; the trainer
  holds it across the suspend -> update -> resume weight-sync barrier
  (:meth:`barrier`); every public entry point takes it. It is reentrant
  so barrier-context callers (the FT snapshot hook) can re-enter drain
  methods.
- ``_completed_lock`` guards every tenant's ``completed`` list — the one
  structure written from engine callback context (EnvManager
  ``on_complete`` fires under an engine's ``_step_lock`` during pump).
- **Acquisition order: ``_lock`` -> engine ``_step_lock`` -> proxy
  ``_lock`` -> ``TokenStream._cv`` / ``_completed_lock`` (leaves).**
  The service lock is strictly the outermost lock of the data plane:
  pump() is only ever called with ``_lock`` held, and nothing called
  from under an engine or proxy lock ever takes ``_lock`` (the stream
  push and completion hooks touch only leaf locks). This extends the
  engine/proxy order documented in ``repro.rl.engine`` without creating
  a cycle.
- Tenant bookkeeping (queues, in-flight counts, stride clocks, stats)
  belongs to the service-lock domain: it is only mutated from under
  ``_lock`` or from engine hooks that run inside a pump — which itself
  runs under ``_lock`` — so a single lock covers both paths. The
  ``FailureInjector`` mutates tenant state lock-free from its documented
  quiescent barrier (see ``repro.ft.failure``), exactly as it did against
  the runner's pump-lock domain.
"""
from __future__ import annotations

import collections
import contextlib
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.envmanager import EMState, EnvManager, RolloutPolicy
from repro.core.proxy import LLMProxy
from repro.rl.engine import GenRequest, GenResult
from repro.serve.stream import TokenStream


class JobState:
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    ABORTED = "aborted"
    REJECTED = "rejected"


@dataclass
class RolloutJob:
    """One unit of serving work.

    ``kind="prompt"``: a single completion — ``prompt`` tokens in,
    streamed tokens out (the external-client fast path).
    ``kind="env"``: a GRPO env group — ``envs`` (pre-built environment
    instances) each driven by an EnvManager under ``policy``; the job is
    done when every manager completes. ``seeds`` (parallel to ``envs``)
    seeds each manager's reset.
    """
    kind: str = "prompt"
    tag: str = "default"               # task/domain tag (affinity routing)
    # prompt jobs
    prompt: Optional[List[int]] = None
    max_new_tokens: int = 32
    temperature: float = 1.0
    stop_tokens: tuple = (2,)
    # env jobs
    envs: List = field(default_factory=list)
    seeds: List[Optional[int]] = field(default_factory=list)
    group_id: str = ""
    policy: Optional[RolloutPolicy] = None
    version: int = 0                   # start weight version (env jobs)
    stream: bool = True                # attach a TokenStream


class JobTicket:
    """Handle returned by :meth:`RolloutService.submit`: job state, the
    incremental token stream, and the final :class:`GenResult` list
    (prompt jobs). Env-job trajectories flow through the tenant's reward
    pipeline into its ``sink`` — the ticket tracks completion only."""

    def __init__(self, job_id: str, tenant: str, job: RolloutJob):
        self.job_id = job_id
        self.tenant = tenant
        self.job = job
        self.state = JobState.QUEUED
        self.stream: Optional[TokenStream] = \
            TokenStream(job_id) if job.stream else None
        self.results: List[GenResult] = []
        self.t_submit = time.monotonic()
        self.t_admit: Optional[float] = None
        self.t_done: Optional[float] = None
        self._remaining = 0            # env jobs: managers still running
        self._done_evt = threading.Event()

    @property
    def done(self) -> bool:
        return self._done_evt.is_set()

    def wait(self, timeout: Optional[float] = None) -> str:
        """Block until the job reaches a terminal state; returns it."""
        if not self._done_evt.wait(timeout=timeout):
            raise TimeoutError(f"job {self.job_id} not done in {timeout}s")
        return self.state

    def _finish(self, state: str):
        self.state = state
        self.t_done = time.monotonic()
        if self.stream is not None:
            self.stream.close("stop" if state == JobState.DONE else state)
        self._done_evt.set()


@dataclass
class Tenant:
    """Per-tenant serving state. All fields except ``completed`` belong
    to the service-lock domain (see module docstring); ``completed`` is
    guarded by the service's ``_completed_lock``."""
    name: str
    weight: float = 1.0
    max_inflight: Optional[int] = None   # None = uncapped (the trainer)
    max_queue: Optional[int] = None      # None = unbounded queue
    tokenizer: object = None             # env jobs: obs/action codec
    sink: Optional[Callable] = None      # scored Trajectory consumer
    source: Optional[Callable] = None    # pull-based job generator
    pre_tick: Optional[Callable] = None  # before admission (staleness)
    post_tick: Optional[Callable] = None  # after drain (surplus cancel)
    observe: Optional[Callable] = None   # affinity profiler hook
    version_fn: Optional[Callable[[], int]] = None
    # reward pipeline (env jobs; None = sink directly, e.g. load tests)
    reward_url: Optional[str] = None
    serverless: object = None
    use_async_reward: bool = True
    reward_retry_limit: int = 2
    # runtime state
    queue: collections.deque = field(default_factory=collections.deque)
    active: List[EnvManager] = field(default_factory=list)
    completed: List[EnvManager] = field(default_factory=list)
    pending_rewards: collections.deque = field(
        default_factory=collections.deque)
    jobs: Dict[str, JobTicket] = field(default_factory=dict)
    inflight: int = 0
    vtime: float = 0.0                   # stride-scheduler virtual time
    stats: Dict[str, int] = field(default_factory=lambda: collections.Counter(
        submitted=0, rejected=0, admitted=0, completed=0, aborted=0,
        failed=0, scored=0, stream_tokens=0, tokens_out=0,
        reward_retries=0))


class RolloutService:
    """The serving tier: owns ``LLMProxy.pump()``, the EnvManager
    completion cascade, and the serverless reward drain for every tenant.

    Lifecycle mirrors the runner's old worker: :meth:`start` spins up (or
    resumes) the background service thread, :meth:`pause` parks it and
    returns only once no tick is in flight, :meth:`close` is idempotent
    and exception-safe (double-close and close-after-crash both return
    promptly). Synchronous callers can drive :meth:`tick` cooperatively
    instead of starting the thread.
    """

    def __init__(self, proxy: LLMProxy, idle_sleep: float = 0.002,
                 max_pump_steps: int = 200000,
                 max_inflight: Optional[int] = None):
        self.proxy = proxy
        self.idle_sleep = idle_sleep
        self.max_pump_steps = max_pump_steps
        # global admission window (jobs in flight across ALL tenants).
        # Weighted fairness needs contention at the admission point: with
        # an unbounded window every arrival is admitted straight into the
        # engine FIFO and the stride scheduler never arbitrates. Size it
        # to engine capacity (~sum of slots) for serving deployments;
        # None (the trainer default) keeps the old unbounded behavior.
        self.max_inflight = max_inflight
        # service lock: see module docstring. RLock so barrier-context
        # callers (FT snapshot hook) may re-enter drain entry points.
        self._lock = threading.RLock()
        self._completed_lock = threading.Lock()
        self._tenants: Dict[str, Tenant] = {}      # guarded by: _lock
        self._job_counter = itertools.count()      # guarded by: _lock
        self._run = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # liveness beat for the observability watchdog: bumped after
        # every tick OUTSIDE the service lock (bare counter, atomic
        # under the GIL) — readable while a wedged tick holds _lock
        self.beats = 0
        # set by the service thread on crash; surfaced by clients
        # (Runner._await_batch) — written without _lock by design, like
        # the runner's old _rollout_error
        self.error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    # tenancy
    # ------------------------------------------------------------------
    def register_tenant(self, name: str, **kw) -> Tenant:
        with self._lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            t = Tenant(name=name, **kw)
            if t.weight <= 0:
                raise ValueError(f"tenant weight must be > 0: {t.weight}")
            # join at the max of live virtual times so a newcomer gets its
            # fair share going forward, not a retroactive burst
            if self._tenants:
                t.vtime = max(x.vtime for x in self._tenants.values())
            self._tenants[name] = t
            return t

    def tenant(self, name: str) -> Tenant:
        with self._lock:
            return self._tenants[name]

    def tenants(self) -> List[Tenant]:
        with self._lock:
            return list(self._tenants.values())

    # ------------------------------------------------------------------
    # the request boundary
    # ------------------------------------------------------------------
    def submit(self, tenant: str, job: RolloutJob) -> JobTicket:
        """Enqueue a job; admission happens on a later tick in stride
        order. A full tenant queue rejects immediately (backpressure) —
        the ticket comes back ``REJECTED`` with a closed stream."""
        with self._lock:
            t = self._tenants[tenant]
            t.stats["submitted"] += 1
            ticket = JobTicket(f"{t.name}-j{next(self._job_counter)}",
                               t.name, job)
            if t.max_queue is not None and len(t.queue) >= t.max_queue:
                t.stats["rejected"] += 1
                ticket._finish(JobState.REJECTED)
                return ticket
            t.queue.append(ticket)
            return ticket

    def abort_job(self, ticket: JobTicket):
        """Cancel a job: queued jobs finish ``ABORTED`` immediately;
        running env jobs abort their managers (the abort drains through
        subsequent ticks); running prompt jobs abort their request."""
        with self._lock:
            t = self._tenants[ticket.tenant]
            if ticket.state == JobState.QUEUED:
                if ticket in t.queue:
                    t.queue.remove(ticket)
                t.stats["aborted"] += 1
                ticket._finish(JobState.ABORTED)
                return
            if ticket.state != JobState.RUNNING:
                return
            if ticket.job.kind == "env":
                for em in t.active:
                    if getattr(em, "job_id", None) == ticket.job_id:
                        em.abort()
            else:
                self.proxy.abort(f"{ticket.job_id}.r0")

    # ------------------------------------------------------------------
    # admission (stride-scheduled weighted fair queueing)
    # ------------------------------------------------------------------
    def _eligible(self, t: Tenant, dry: set) -> bool:   # requires: _lock
        if t.name in dry:
            return False
        if t.max_inflight is not None and t.inflight >= t.max_inflight:
            return False
        if t.queue:
            return True
        if t.source is None:
            return False
        job = t.source()
        if job is None:
            dry.add(t.name)
            return False
        t.queue.append(JobTicket(
            f"{t.name}-j{next(self._job_counter)}", t.name, job))
        return True

    def _admit_locked(self, only: Optional[str] = None) -> int:   # requires: _lock
        """Admit queued/pulled jobs in stride order until no tenant is
        eligible. Each admission advances the tenant's virtual time by
        ``1 / weight`` — over any congested interval tenants therefore
        receive admissions proportional to their weights."""
        admitted = 0
        dry: set = set()
        while True:
            if self.max_inflight is not None and \
                    sum(t.inflight for t in self._tenants.values()) \
                    >= self.max_inflight:
                return admitted
            cands = [t for t in self._tenants.values()
                     if (only is None or t.name == only)
                     and self._eligible(t, dry)]
            if not cands:
                return admitted
            t = min(cands, key=lambda x: (x.vtime, x.name))
            self._launch_locked(t, t.queue.popleft())
            t.vtime += 1.0 / t.weight
            admitted += 1

    def _launch_locked(self, t: Tenant, ticket: JobTicket):   # requires: _lock
        job = ticket.job
        ticket.state = JobState.RUNNING
        ticket.t_admit = time.monotonic()
        t.jobs[ticket.job_id] = ticket
        t.inflight += 1
        t.stats["admitted"] += 1
        on_tokens = None
        if ticket.stream is not None:
            on_tokens = self._make_stream_hook(t, ticket)
        if job.kind == "prompt":
            rid = f"{ticket.job_id}.r0"
            self.proxy.submit(
                GenRequest(request_id=rid, prompt=list(job.prompt or []),
                           max_new_tokens=job.max_new_tokens,
                           temperature=job.temperature,
                           stop_tokens=job.stop_tokens, tag=job.tag),
                callback=self._make_prompt_cb(t, ticket, rid),
                on_tokens=on_tokens)
            return
        version = t.version_fn() if t.version_fn is not None else job.version
        ticket._remaining = len(job.envs)
        seeds = job.seeds or [None] * len(job.envs)
        for env, seed in zip(job.envs, seeds):
            em = EnvManager(
                env, self.proxy, tokenizer=t.tokenizer, policy=job.policy,
                tag=job.tag, group_id=job.group_id or ticket.job_id,
                on_complete=self._make_on_complete(t),
                on_tokens=on_tokens)
            em.job_id = ticket.job_id
            t.active.append(em)
            em.start(version=version, seed=seed)
        if not job.envs:
            self._finish_ticket(t, ticket, JobState.DONE)

    def _make_stream_hook(self, t: Tenant, ticket: JobTicket):
        def on_tokens(rid: str, cum_tokens, cum_logprobs,
                      _t=t, _tk=ticket):
            n = _tk.stream.push(rid, cum_tokens, cum_logprobs)
            if n:
                _t.stats["stream_tokens"] += n
        return on_tokens

    def _make_prompt_cb(self, t: Tenant, ticket: JobTicket, rid: str):
        # runs from engine finish-hook context (under that engine's
        # _step_lock, inside a pump — i.e. inside the service lock)
        def cb(res: GenResult, _t=t, _tk=ticket, _rid=rid):
            _tk.results.append(res)
            if _tk.stream is not None and res.finish_reason != "aborted":
                # completeness: the final cumulative push is a no-op when
                # streaming already delivered everything
                _tk.stream.push(_rid, res.tokens, res.logprobs)
            _t.stats["tokens_out"] += len(res.tokens)
            done = res.finish_reason != "aborted"
            _t.stats["completed" if done else "aborted"] += 1
            self._finish_ticket(
                _t, _tk, JobState.DONE if done else JobState.ABORTED)
        return cb

    def _make_on_complete(self, t: Tenant):
        def on_complete(em: EnvManager, _t=t):
            with self._completed_lock:
                _t.completed.append(em)
        return on_complete

    def _finish_ticket(self, t: Tenant, ticket: JobTicket, state: str):
        t.jobs.pop(ticket.job_id, None)
        t.inflight -= 1
        ticket._finish(state)

    # ------------------------------------------------------------------
    # the service tick
    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One serving iteration: per-tenant pre-tick policy (staleness),
        stride admission, ONE proxy pump, completion cascade, reward
        drain, post-tick policy (surplus cancellation). Returns an
        activity count (0 == idle)."""
        with self._lock:
            n = self._tick_locked()
        self.beats += 1
        return n

    def _tick_locked(self) -> int:   # requires: _lock
        for t in self._tenants.values():
            if t.pre_tick is not None:
                t.pre_tick()
        n = self._admit_locked()
        n += self.proxy.pump()
        n += self._drain_completions_locked()
        for t in self._tenants.values():
            n += self._drain_rewards_locked(t)
            if t.post_tick is not None:
                t.post_tick()
        return n

    def admit(self, only: Optional[str] = None) -> int:
        with self._lock:
            return self._admit_locked(only)

    def drain_completions(self) -> int:
        with self._lock:
            return self._drain_completions_locked()

    def drain_rewards(self, block: bool = False) -> int:
        with self._lock:
            return sum(self._drain_rewards_locked(t, block=block)
                       for t in self._tenants.values())

    def _drain_completions_locked(self) -> int:   # requires: _lock
        n = 0
        for t in self._tenants.values():
            with self._completed_lock:
                done = list(t.completed)
                t.completed.clear()
            for em in done:
                self._score_locked(t, em)
                if em in t.active:
                    t.active.remove(em)
                ticket = t.jobs.get(getattr(em, "job_id", None))
                if ticket is not None:
                    ticket._remaining -= 1
                    if ticket._remaining <= 0:
                        t.stats["completed"] += 1
                        self._finish_ticket(t, ticket, JobState.DONE)
            n += len(done)
        return n

    def _score_locked(self, t: Tenant, em: EnvManager):   # requires: _lock
        """Reward stage (was LiveRLRunner._score_and_buffer). Async
        tenants submit the serverless call and return immediately — the
        trajectory reaches the sink when its future resolves
        (:meth:`_drain_rewards_locked`)."""
        traj = em.trajectory()
        if t.observe is not None and em.turns:
            t.observe(em)
        if em.state in (EMState.FAILED, EMState.ABORTED):
            t.stats["failed" if em.state == EMState.FAILED
                    else "aborted"] += 1
            return   # redundancy / staleness control absorb these
        if t.reward_url is None:
            t.stats["scored"] += 1
            t.stats["tokens_out"] += sum(traj.loss_mask)
            if t.sink is not None:
                t.sink(traj)
            return
        payload = {
            "env_return": em.env_return,
            "tokens": traj.tokens,
            "loss_mask": traj.loss_mask,
            "num_tokens": len(traj.tokens),
            "text": t.tokenizer.decode(traj.tokens),
        }
        if t.use_async_reward:
            # analysis: ignore[blocking-under-lock] pool.submit only: the
            # call executes on the serverless pool thread, not here
            fut = t.serverless.invoke_async(t.reward_url, payload)
            t.pending_rewards.append([traj, payload, fut, 0])
        else:
            # analysis: ignore[blocking-under-lock] sync baseline BY
            # DESIGN: "sync" mode scores rewards inline in the tick (no
            # service thread exists in sync modes, so nothing is
            # serialized behind the lock)
            traj.reward = float(t.serverless.invoke(t.reward_url, payload))
            t.stats["scored"] += 1
            t.stats["tokens_out"] += sum(traj.loss_mask)
            if t.sink is not None:
                t.sink(traj)

    def _drain_rewards_locked(self, t: Tenant,
                              block: bool = False) -> int:   # requires: _lock
        """Completed-PREFIX drain in reward SUBMISSION order (batch
        composition must not depend on serverless timing). Lost
        invocations re-submit from the retained payload up to the
        tenant's retry limit (was LiveRLRunner._drain_rewards)."""
        n = 0
        while t.pending_rewards:
            entry = t.pending_rewards[0]
            traj, payload, fut, attempts = entry
            if not block and not fut.done():
                break
            try:
                traj.reward = float(fut.result())
            except Exception:
                if attempts >= t.reward_retry_limit:
                    raise
                # analysis: ignore[blocking-under-lock] pool.submit only
                entry[2] = t.serverless.invoke_async(t.reward_url, payload)
                entry[3] = attempts + 1
                t.stats["reward_retries"] += 1
                if not block:
                    break
                continue
            t.pending_rewards.popleft()
            t.stats["scored"] += 1
            t.stats["tokens_out"] += sum(traj.loss_mask)
            if t.sink is not None:
                t.sink(traj)
            n += 1
        return n

    def drain_tenant(self, name: str, abort: bool = True):
        """Synchronously drain one tenant's in-flight work (the sync
        baselines' between-iteration barrier: abort leftovers, pump until
        the proxy is idle, block on pending rewards)."""
        with self._lock:
            t = self._tenants[name]
            if abort:
                for em in list(t.active):
                    em.abort()
            pumps = 0
            while self.proxy.busy:
                self.proxy.pump()
                self._drain_completions_locked()
                self._drain_rewards_locked(t)
                pumps += 1
                if pumps > self.max_pump_steps:
                    raise RuntimeError("rollout did not drain")
            self._drain_completions_locked()
            self._drain_rewards_locked(t, block=True)

    # ------------------------------------------------------------------
    # weight-sync barrier
    # ------------------------------------------------------------------
    @contextlib.contextmanager
    def barrier(self):
        """The suspend -> update -> resume critical section: holding it
        excludes the service tick, so a weight swap never races a decode
        step (the runner's old pump-lock contract, now a service API)."""
        with self._lock:
            yield self

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def _loop(self):
        try:
            while not self._stop.is_set():
                if not self._run.wait(timeout=0.05):
                    continue
                with self._lock:
                    if not self._run.is_set():
                        continue
                    n = self._tick_locked()
                self.beats += 1
                if n == 0:
                    time.sleep(self.idle_sleep)   # idle: yield the GIL
        except BaseException as e:   # surfaced by clients via self.error
            self.error = e
            self._run.clear()

    def start(self):
        """Start (or resume) the background service thread."""
        if self._stop.is_set():
            raise RuntimeError("service is closed; build a new one")
        # a crashed thread is NOT restarted: self.error stays set and
        # clients surface it (LiveRLRunner._await_batch)
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name="rollout-service", daemon=True)
            self._thread.start()
        self._run.set()

    def loop_expected_alive(self) -> bool:
        """Watchdog probe (lock-free bare reads): True while the service
        thread is supposed to be ticking — started, running, not closed,
        and not already crashed loudly (``self.error`` is the loud
        failure path; the watchdog exists for the SILENT one, where the
        thread is wedged inside a tick and beats stop advancing)."""
        return (self._thread is not None and self._run.is_set()
                and not self._stop.is_set() and self.error is None)

    def pause(self):
        """Park the service thread; returns only once no tick is in
        flight (a tick past the flag check finishes first)."""
        self._run.clear()
        with self._lock:
            pass

    def close(self, timeout: float = 10.0):
        """Idempotent, exception-safe shutdown: double-close is a no-op
        and close-after-crash returns promptly (a dead thread joins
        immediately; a wedged one is abandoned after ``timeout`` — it is
        a daemon — instead of hanging the caller)."""
        self._run.clear()
        self._stop.set()
        th, self._thread = self._thread, None
        if th is not None and th.is_alive():
            th.join(timeout=timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        with self._lock:
            return {t.name: {
                "weight": t.weight, "vtime": round(t.vtime, 3),
                "inflight": t.inflight, "queued": len(t.queue),
                "active_ems": len(t.active),
                "pending_rewards": len(t.pending_rewards),
                **dict(t.stats),
            } for t in self._tenants.values()}

"""Worker abstraction + decorator-based declarations (paper §5.2, Listing 1).

Three decorators configure the data/resource planes:

- ``@register(mode="execute_all")``     — single-controller collective call
- ``@hw_mapping(hw_affinity={...})``    — task-domain -> hardware routing (R1)
- ``@register_serverless(attribute=, serverless_url=)`` — offload to the
  serverless platform (R3)

Decorators only attach metadata; ``Cluster`` (cluster.py) interprets it,
mirroring the paper's Listing 2.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

REG_ATTR = "_rollart_register"
HW_ATTR = "_rollart_hw_mapping"
SLS_ATTR = "_rollart_serverless"


def register(mode: str = "execute_all"):
    """Single-controller collective invocation across the Worker group."""
    assert mode in ("execute_all", "execute_rank0")

    def deco(fn: Callable) -> Callable:
        setattr(fn, REG_ATTR, {"mode": mode})
        return fn
    return deco


def hw_mapping(hw_affinity: Dict[str, str]):
    """Route calls to workers on the hardware preferred for the request's
    ``tag_name`` (task domain). Requires a "default" key."""
    assert "default" in hw_affinity, "hw_affinity needs a 'default' entry"

    def deco(fn: Callable) -> Callable:
        setattr(fn, HW_ATTR, {"hw_affinity": dict(hw_affinity)})
        return fn
    return deco


def register_serverless(attribute: str, serverless_url: str):
    """Replace ``self.<attribute>`` with a callable that invokes the
    registered serverless endpoint (scale-to-zero, no dedicated GPUs)."""
    def deco(fn: Callable) -> Callable:
        setattr(fn, SLS_ATTR, {"attribute": attribute,
                               "serverless_url": serverless_url})
        return fn
    return deco


@dataclasses.dataclass
class WorkerInfo:
    worker_id: str
    role: str
    resource_type: str = ""     # pool name after binding
    device_ids: tuple = ()


class Worker:
    """Basic execution unit spanning the resource and data planes."""

    ROLE = "generic"
    DEFAULT_HW = "CPU"
    DEVICES_PER_WORKER = 1

    def __init__(self, info: WorkerInfo, **kwargs):
        self.info = info

    @property
    def resource_type(self) -> str:
        return self.info.resource_type

    def setup(self):
        """Called once after resource binding (load model etc.)."""

    def teardown(self):
        """Called on release/failure."""


class ActorTrainCls(Worker):
    ROLE = "train"
    DEFAULT_HW = "H800"       # compute-optimized by default (paper §5.2)


class ActorGenCls(Worker):
    ROLE = "generate"
    DEFAULT_HW = "H20"        # bandwidth-optimized by default


class RewardCls(Worker):
    ROLE = "reward"
    DEFAULT_HW = "Serverless"


class EnvironmentCls(Worker):
    ROLE = "environment"
    DEFAULT_HW = "CPU"


def method_declarations(cls) -> Dict[str, Dict[str, Any]]:
    """Collect decorator metadata from a Worker class."""
    out: Dict[str, Dict[str, Any]] = {}
    for name in dir(cls):
        fn = getattr(cls, name, None)
        if not callable(fn):
            continue
        meta = {}
        for attr, key in [(REG_ATTR, "register"), (HW_ATTR, "hw_mapping"),
                          (SLS_ATTR, "serverless")]:
            if hasattr(fn, attr):
                meta[key] = getattr(fn, attr)
        if meta:
            out[name] = meta
    return out

"""Resource plane: the resource manager (paper §5.2 "Resource Binding").

Tracks heterogeneous hardware pools in a shared metadata store (a dict
standing in for Redis), interprets worker-level hardware-affinity
declarations, binds Workers to concrete device groups, and falls back to
compatible defaults when the preferred pool is exhausted rather than
stalling deployment.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence

from repro.core.hardware import (REGISTRY, ROLE_CLASS_AFFINITY,
                                 HardwareSpec)


@dataclasses.dataclass
class DeviceGroup:
    pool: str                  # hardware name, e.g. "H800"
    device_ids: List[int]
    owner: Optional[str] = None

    @property
    def size(self) -> int:
        return len(self.device_ids)


@dataclasses.dataclass
class Binding:
    worker_id: str
    role: str
    group: DeviceGroup
    fallback: bool = False     # True if not on the preferred pool


# fallback order per hardware class (paper: "opportunistically falls back
# to compatible default resources")
FALLBACKS = {
    "H800": ["H20"],
    "H20": ["H800"],
    "TPUv5p": ["TPUv5e"],
    "TPUv5e": ["TPUv5p"],
    "CPU": [],
    "Serverless": [],
}


class ResourceManager:
    """Global real-time view of disaggregated resource pools."""

    def __init__(self, pools: Dict[str, int]):
        """pools: hardware name -> device count, e.g. {"H800": 96, "H20": 32,
        "CPU": 512, "Serverless": 10**6}."""
        for name in pools:
            if name not in REGISTRY:
                raise KeyError(f"unknown hardware {name!r}")
        self._lock = threading.Lock()
        # guarded by: _lock
        self._free: Dict[str, List[int]] = {
            name: list(range(n)) for name, n in pools.items()}
        # the "Redis" metadata store
        self._meta: Dict[str, Binding] = {}           # guarded by: _lock
        self.pools = dict(pools)

    def spec(self, pool: str) -> HardwareSpec:
        return REGISTRY[pool]

    def available(self, pool: str) -> int:
        with self._lock:
            return len(self._free.get(pool, []))

    # ------------------------------------------------------------------
    def _bind_locked(self, worker_id: str, role: str, candidates,
                     n_devices: int) -> Optional[Binding]:   # requires: _lock
        """Try (pool, is_fallback) candidates in order; caller holds lock."""
        for pool, is_fb in candidates:
            free = self._free.get(pool, [])
            if len(free) >= n_devices:
                ids = [free.pop() for _ in range(n_devices)]
                grp = DeviceGroup(pool=pool, device_ids=sorted(ids),
                                  owner=worker_id)
                b = Binding(worker_id=worker_id, role=role, group=grp,
                            fallback=is_fb)
                self._meta[worker_id] = b
                return b
        return None

    def _affine_candidates(self, role: str, n_devices: int):   # requires: _lock
        """Preference order for a role: pools whose hardware class matches
        the role's affinity (most free devices first, so load spreads), then
        the remaining pools as fallbacks. Caller holds lock."""
        klass = ROLE_CLASS_AFFINITY.get(role)
        names = sorted(
            self.pools,
            key=lambda n: (REGISTRY[n].klass != klass,
                           -len(self._free.get(n, []))))
        return [(n, REGISTRY[n].klass != klass) for n in names]

    def bind(self, worker_id: str, role: str, preferred: str,
             n_devices: int = 1,
             allow_fallback: bool = True) -> Optional[Binding]:
        """Bind a worker to ``n_devices`` of the preferred pool, falling back
        to a compatible pool if exhausted. Returns None if impossible."""
        with self._lock:
            cands = [(preferred, False)] + [
                (fb, True) for fb in
                (FALLBACKS.get(preferred, []) if allow_fallback else [])]
            return self._bind_locked(worker_id, role, cands, n_devices)

    def bind_affine(self, worker_id: str, role: str,
                    n_devices: int = 1) -> Optional[Binding]:
        """Role-affine binding (paper §5.2): prefill-role workers land on
        compute-class pools, decode-role on bandwidth-class pools, falling
        back opportunistically to any pool with capacity rather than
        stalling deployment. ``fallback=True`` on the returned Binding
        means the worker is NOT on its class-preferred hardware."""
        with self._lock:
            return self._bind_locked(
                worker_id, role, self._affine_candidates(role, n_devices),
                n_devices)

    def rebind(self, worker_id: str, new_role: str) -> Optional[Binding]:
        """Atomically release a worker's device group and re-bind it under
        ``new_role``'s affinity (the dynamic prefill<->decode role switch).
        The freed devices are visible to the new bind, so a single-pool
        manager re-binds in place; on a heterogeneous pool the group
        migrates to the new role's preferred class when it has capacity.
        Returns None (old binding restored) only if re-binding is
        impossible, which cannot happen while the freed group exists."""
        with self._lock:
            old = self._meta.pop(worker_id, None)
            if old is None:
                return None
            self._free.setdefault(old.group.pool, []).extend(
                old.group.device_ids)
            b = self._bind_locked(
                worker_id, new_role,
                self._affine_candidates(new_role, old.group.size),
                old.group.size)
            if b is None:        # restore: never leave the worker unbound
                ids = self._free[old.group.pool]
                for d in old.group.device_ids:
                    ids.remove(d)
                self._meta[worker_id] = old
            return b

    def release(self, worker_id: str):
        with self._lock:
            b = self._meta.pop(worker_id, None)
            if b is not None:
                self._free.setdefault(b.group.pool, []).extend(
                    b.group.device_ids)

    def binding(self, worker_id: str) -> Optional[Binding]:
        with self._lock:
            return self._meta.get(worker_id)

    def bindings_by_pool(self, pool: str) -> List[Binding]:
        with self._lock:
            return [b for b in self._meta.values() if b.group.pool == pool]

    def snapshot(self) -> Dict[str, Dict]:
        with self._lock:
            return {
                "free": {k: len(v) for k, v in self._free.items()},
                "bound": {k: dataclasses.asdict(v)
                          for k, v in self._meta.items()},
            }


def parse_pools(spec: str) -> Dict[str, int]:
    """Parse a ``--pools`` flag value like ``"H800:8,H20:8"`` into the
    pool dict a ResourceManager is built from."""
    pools: Dict[str, int] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, count = part.partition(":")
        name = name.strip()
        if name not in REGISTRY:
            raise ValueError(f"unknown hardware {name!r} in --pools "
                             f"(known: {sorted(REGISTRY)})")
        try:
            n = int(count)
        except ValueError:
            raise ValueError(f"bad device count in --pools entry {part!r}")
        if n <= 0:
            raise ValueError(f"device count must be positive in {part!r}")
        pools[name] = pools.get(name, 0) + n
    if not pools:
        raise ValueError(f"empty --pools spec {spec!r}")
    return pools

"""Resource plane: the resource manager (paper §5.2 "Resource Binding").

Tracks heterogeneous hardware pools in a shared metadata store (a dict
standing in for Redis), interprets worker-level hardware-affinity
declarations, binds Workers to concrete device groups, and falls back to
compatible defaults when the preferred pool is exhausted rather than
stalling deployment.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence

from repro.core.hardware import REGISTRY, HardwareSpec


@dataclasses.dataclass
class DeviceGroup:
    pool: str                  # hardware name, e.g. "H800"
    device_ids: List[int]
    owner: Optional[str] = None

    @property
    def size(self) -> int:
        return len(self.device_ids)


@dataclasses.dataclass
class Binding:
    worker_id: str
    role: str
    group: DeviceGroup
    fallback: bool = False     # True if not on the preferred pool


# fallback order per hardware class (paper: "opportunistically falls back
# to compatible default resources")
FALLBACKS = {
    "H800": ["H20"],
    "H20": ["H800"],
    "TPUv5p": ["TPUv5e"],
    "TPUv5e": ["TPUv5p"],
    "CPU": [],
    "Serverless": [],
}


class ResourceManager:
    """Global real-time view of disaggregated resource pools."""

    def __init__(self, pools: Dict[str, int]):
        """pools: hardware name -> device count, e.g. {"H800": 96, "H20": 32,
        "CPU": 512, "Serverless": 10**6}."""
        for name in pools:
            if name not in REGISTRY:
                raise KeyError(f"unknown hardware {name!r}")
        self._lock = threading.Lock()
        self._free: Dict[str, List[int]] = {
            name: list(range(n)) for name, n in pools.items()}
        self._meta: Dict[str, Binding] = {}   # the "Redis" metadata store
        self.pools = dict(pools)

    def spec(self, pool: str) -> HardwareSpec:
        return REGISTRY[pool]

    def available(self, pool: str) -> int:
        with self._lock:
            return len(self._free.get(pool, []))

    # ------------------------------------------------------------------
    def bind(self, worker_id: str, role: str, preferred: str,
             n_devices: int = 1,
             allow_fallback: bool = True) -> Optional[Binding]:
        """Bind a worker to ``n_devices`` of the preferred pool, falling back
        to a compatible pool if exhausted. Returns None if impossible."""
        with self._lock:
            for pool, is_fb in [(preferred, False)] + [
                    (fb, True) for fb in
                    (FALLBACKS.get(preferred, []) if allow_fallback else [])]:
                free = self._free.get(pool, [])
                if len(free) >= n_devices:
                    ids = [free.pop() for _ in range(n_devices)]
                    grp = DeviceGroup(pool=pool, device_ids=sorted(ids),
                                      owner=worker_id)
                    b = Binding(worker_id=worker_id, role=role, group=grp,
                                fallback=is_fb)
                    self._meta[worker_id] = b
                    return b
        return None

    def release(self, worker_id: str):
        with self._lock:
            b = self._meta.pop(worker_id, None)
            if b is not None:
                self._free.setdefault(b.group.pool, []).extend(
                    b.group.device_ids)

    def binding(self, worker_id: str) -> Optional[Binding]:
        with self._lock:
            return self._meta.get(worker_id)

    def bindings_by_pool(self, pool: str) -> List[Binding]:
        with self._lock:
            return [b for b in self._meta.values() if b.group.pool == pool]

    def snapshot(self) -> Dict[str, Dict]:
        with self._lock:
            return {
                "free": {k: len(v) for k, v in self._free.items()},
                "bound": {k: dataclasses.asdict(v)
                          for k, v in self._meta.items()},
            }

"""Cluster abstraction (paper §5.1/§5.3, Listing 2): a proxy + controller
for a role-specific Worker group. It spawns workers through the resource
manager, binds worker methods onto itself, and realizes the three decorator
semantics: execute_all aggregation, hardware-affinity routing, and
serverless redirection — with fallback to compatible resources when the
preferred target is unavailable.
"""
from __future__ import annotations

import functools
import itertools
from typing import Any, Callable, Dict, List, Optional, Type

from repro.core.resource import ResourceManager
from repro.core.serverless import ServerlessPlatform
from repro.core.worker import (HW_ATTR, REG_ATTR, SLS_ATTR, Worker,
                               WorkerInfo, method_declarations)

_counter = itertools.count()


class Cluster:
    def __init__(self, res_manager: ResourceManager, worker_cls: Type[Worker],
                 num_workers: int,
                 hw_preference: Optional[str] = None,
                 devices_per_worker: Optional[int] = None,
                 serverless: Optional[ServerlessPlatform] = None,
                 worker_kwargs: Optional[Dict[str, Any]] = None):
        self.rm = res_manager
        self.worker_cls = worker_cls
        self.role = worker_cls.ROLE
        self.serverless = serverless
        self.workers: List[Worker] = []
        self._decls = method_declarations(worker_cls)
        self._create_workers(num_workers,
                             hw_preference or worker_cls.DEFAULT_HW,
                             devices_per_worker
                             or worker_cls.DEVICES_PER_WORKER,
                             worker_kwargs or {})
        self._bind_worker_methods()

    # ------------------------------------------------------------------
    def _create_workers(self, n: int, hw: str, devs: int, kwargs: Dict):
        bound_ids: List[str] = []
        try:
            for _ in range(n):
                wid = f"{self.role}-{next(_counter)}"
                binding = self.rm.bind(wid, self.role, hw, n_devices=devs)
                if binding is None:
                    raise RuntimeError(
                        f"resource manager cannot bind {wid} to {hw} "
                        f"(snapshot: {self.rm.snapshot()['free']})")
                bound_ids.append(wid)
                info = WorkerInfo(worker_id=wid, role=self.role,
                                  resource_type=binding.group.pool,
                                  device_ids=tuple(binding.group.device_ids))
                w = self.worker_cls(info, **kwargs)
                self._apply_serverless_decls(w)
                w.setup()
                self.workers.append(w)
        except BaseException:
            # unwind: a partially-created cluster must not strand the
            # first k-1 device groups in the resource manager
            for w in self.workers:
                try:
                    w.teardown()
                except Exception:
                    pass
            self.workers.clear()
            for wid in bound_ids:
                self.rm.release(wid)
            raise

    def _apply_serverless_decls(self, worker: Worker):
        for mname, meta in self._decls.items():
            sls = meta.get("serverless")
            if not sls:
                continue
            if self.serverless is None:
                raise RuntimeError(
                    f"{mname} declares serverless offload but the Cluster "
                    "was built without a ServerlessPlatform")
            url = sls["serverless_url"]
            call_fc = functools.partial(self.serverless.invoke, url)
            setattr(worker, sls["attribute"], call_fc)

    def _bind_worker_methods(self):
        """Expose each declared worker method on the Cluster as a proxy."""
        for mname, meta in self._decls.items():
            if hasattr(self, mname):
                continue
            if "hw_mapping" in meta:
                proxy = functools.partial(self._call_hw_mapped, mname,
                                          meta["hw_mapping"]["hw_affinity"])
            elif "register" in meta:
                proxy = functools.partial(self._call_execute_all, mname,
                                          meta["register"]["mode"])
            else:
                proxy = functools.partial(self._call_execute_all, mname,
                                          "execute_all")
            setattr(self, mname, proxy)

    # ------------------------------------------------------------------
    # decorator realizations
    # ------------------------------------------------------------------
    def _call_execute_all(self, mname: str, mode: str, *args, **kwargs):
        """Single-controller: broadcast inputs, invoke on all Workers,
        aggregate results (a list, like ray.get of all refs)."""
        targets = self.workers if mode == "execute_all" else self.workers[:1]
        return [getattr(w, mname)(*args, **kwargs) for w in targets]

    def _call_hw_mapped(self, mname: str, hw_affinity: Dict[str, str],
                        *args, tag_name: str = "default", **kwargs):
        """Hardware-affinity routing (R1): filter workers whose resource
        type matches the preferred hardware for this tag; fall back to any
        worker when the preferred pool has none (forward progress under
        transient contention)."""
        hw_type = hw_affinity.get(tag_name, hw_affinity["default"])
        matched = [w for w in self.workers if w.resource_type == hw_type]
        if not matched:
            matched = self.workers         # compatible fallback
        w = self._pick_least_loaded(matched)
        return getattr(w, mname)(*args, **kwargs)

    @staticmethod
    def _pick_least_loaded(workers: List[Worker]) -> Worker:
        def load(w):
            return getattr(w, "load", lambda: 0)()
        return min(workers, key=load)

    # ------------------------------------------------------------------
    def workers_on(self, pool: str) -> List[Worker]:
        return [w for w in self.workers if w.resource_type == pool]

    def shutdown(self):
        for w in self.workers:
            w.teardown()
            self.rm.release(w.info.worker_id)
        self.workers.clear()

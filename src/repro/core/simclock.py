"""Minimal discrete-event simulation engine (SimPy-like, generator-based).

The cluster-scale benchmarks replay the RollArt control plane against
modeled hardware latencies in virtual time. Processes are generators that
yield either ``sim.timeout(dt)`` or an ``Event``; ``Simulator.run`` drives
them through a time-ordered heap.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Generator, List, Optional


class Event:
    """One-shot event; processes yield it to wait, anyone may trigger it."""

    __slots__ = ("sim", "triggered", "value", "_waiters")

    def __init__(self, sim: "Simulator"):
        self.sim = sim
        self.triggered = False
        self.value: Any = None
        self._waiters: List = []

    def trigger(self, value: Any = None):
        if self.triggered:
            return
        self.triggered = True
        self.value = value
        for proc in self._waiters:
            self.sim._schedule(self.sim.now, proc, value)
        self._waiters.clear()


class Timeout:
    __slots__ = ("delay",)

    def __init__(self, delay: float):
        self.delay = max(0.0, float(delay))


class _Process:
    __slots__ = ("gen", "done_event", "name")

    def __init__(self, gen: Generator, done_event: Event, name: str):
        self.gen = gen
        self.done_event = done_event
        self.name = name


class Simulator:
    def __init__(self):
        self.now = 0.0
        self._heap: List = []
        self._counter = itertools.count()

    # -- public API ------------------------------------------------------
    def timeout(self, delay: float) -> Timeout:
        return Timeout(delay)

    def event(self) -> Event:
        return Event(self)

    def process(self, gen: Generator, name: str = "proc") -> Event:
        """Spawn a process; returns an Event triggered with its return."""
        done = Event(self)
        proc = _Process(gen, done, name)
        self._schedule(self.now, proc, None)
        return done

    def run(self, until: Optional[float] = None):
        while self._heap:
            t, _, proc, value = heapq.heappop(self._heap)
            if until is not None and t > until:
                heapq.heappush(self._heap, (t, next(self._counter), proc,
                                            value))
                self.now = until
                return
            self.now = t
            self._step(proc, value)
        if until is not None:
            self.now = max(self.now, until)

    # -- internals --------------------------------------------------------
    def _schedule(self, t: float, proc: _Process, value: Any):
        heapq.heappush(self._heap, (t, next(self._counter), proc, value))

    def _step(self, proc: _Process, send_value: Any):
        try:
            yielded = proc.gen.send(send_value)
        except StopIteration as stop:
            proc.done_event.trigger(stop.value)
            return
        if isinstance(yielded, Timeout):
            self._schedule(self.now + yielded.delay, proc, None)
        elif isinstance(yielded, Event):
            if yielded.triggered:
                self._schedule(self.now, proc, yielded.value)
            else:
                yielded._waiters.append(proc)
        else:
            raise TypeError(f"process {proc.name} yielded {yielded!r}; "
                            "expected Timeout or Event")


class Resource:
    """Counting resource (e.g. a GPU pool) with FIFO queuing."""

    def __init__(self, sim: Simulator, capacity: int, name: str = "res"):
        self.sim = sim
        self.capacity = capacity
        self.in_use = 0
        self.name = name
        self._queue: List[Event] = []
        # utilization accounting
        self._busy_time = 0.0
        self._last_t = 0.0

    def _account(self):
        self._busy_time += self.in_use * (self.sim.now - self._last_t)
        self._last_t = self.sim.now

    def acquire(self):
        """Process helper: ``yield from res.acquire()``."""
        while self.in_use >= self.capacity:
            ev = self.sim.event()
            self._queue.append(ev)
            yield ev
        self._account()
        self.in_use += 1

    def release(self):
        self._account()
        self.in_use -= 1
        if self._queue:
            self._queue.pop(0).trigger()

    def utilization(self, capacity: Optional[int] = None) -> float:
        self._account()
        denom = (capacity or self.capacity) * max(self.sim.now, 1e-9)
        return self._busy_time / denom


def all_of(sim: Simulator, events: List[Event]) -> Event:
    """Event that fires when all inputs have fired."""
    out = sim.event()
    remaining = [len(events)]
    if not events:
        out.trigger([])
        return out
    results = [None] * len(events)

    def waiter(i, ev):
        val = yield ev
        results[i] = val
        remaining[0] -= 1
        if remaining[0] == 0:
            out.trigger(results)

    for i, ev in enumerate(events):
        sim.process(waiter(i, ev), name="all_of")
    return out


def any_of(sim: Simulator, events: List[Event]) -> Event:
    out = sim.event()

    def waiter(i, ev):
        val = yield ev
        out.trigger((i, val))

    for i, ev in enumerate(events):
        sim.process(waiter(i, ev), name="any_of")
    return out

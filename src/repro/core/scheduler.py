"""Rollout scheduler + asynchronous training orchestration (paper §6).

``LiveRLRunner`` drives the REAL pipeline (tiny models, real environments,
real GRPO updates) through the paper's six-step weight-sync protocol:

  (1) get_batch   — blocking retrieval from SampleBuffer
  (2) suspend     — LLMProxy stops admitting requests (in-flight preserved)
  (3) update      — engines pull the latest weights from the Mooncake store
                    (a version-matched pull is a no-op: nothing re-prefills)
  (4) resume      — pending generation continues
  (5) recomp      — in-flight trajectories' KV caches rebuilt under the new
                    weights (so they continue instead of restarting)
  (6) train_step  — the GRPO update, genuinely overlapped with rollout

Since the Rollout-as-a-Service refactor the runner no longer owns the
dispatch loop: ALL pump/drain work lives in
:class:`repro.serve.RolloutService`, and the runner is simply the
service's first tenant. It contributes a pull-based job ``source``
(:meth:`_next_job` — the backpressure + group-top-up policy), per-tick
policy hooks (staleness enforcement before admission, redundancy
cancellation after the drain), and a ``sink`` (the SampleBuffer). The
trainer therefore reaches the engines through exactly the same admission
path an external serving client uses, and the runner contains NO direct
``proxy.pump()`` call.

The overlap is real, not cooperative: in the asynchronous modes
("rollart", "areal", "one_off") the entire rollout side — proxy pump,
EnvManager completion cascade, serverless reward scoring — runs on the
service's background thread, which keeps producing into ``SampleBuffer``
while the trainer thread executes the six-step protocol. The ONLY barrier
between the two threads is the suspend → update → resume critical
section, taken under the SERVICE lock (:meth:`RolloutService.barrier`,
the role the runner's private pump lock used to play) so a weight swap
never races a decode step. Reward scoring is non-blocking
(``ServerlessPlatform.invoke_async``): a scored trajectory enters the
buffer when its future resolves — drained in submission order so batch
composition stays deterministic — and the weight push after each train
step happens on its own thread, awaited only at the next suspend barrier.
``StepMetrics.decode_during_train`` counts decode tokens the engines
generated while ``train_step`` ran (> 0 in the threaded modes, 0 in the
synchronous baselines; see benchmarks/async_overlap.py).

Also implements trajectory-level staleness enforcement (abort EnvManagers
whose start_version < n - alpha, every rollout tick — stricter than AReaL)
and redundant environment rollouts (launch extra groups, cancel the slowest
once the target count is met; exploits GRPO's group structure).

Modes ("rollart", "sync", "sync_plus", "one_off", "areal") reproduce the
paper's baselines with the same code path, differing only in coordination:
  sync      — rollout and training strictly alternate; blocking reward
  sync_plus — sync + async (serverless-offloaded) reward scoring
  one_off   — training consumes the PREVIOUS iteration's batch while the
              next one rolls out (threaded; one-step pipeline)
  areal     — staleness bound applied at trajectory start only (threaded)
  rollart   — bounded staleness alpha enforced per tick + affinity
              (threaded)

Concurrency note: the runner's rollout-side state (``active`` managers,
``_pending_rewards``, ``_completed_this_round``, sampler/seed RNGs) is
ALIASED into its service tenant — the same list/deque objects, never
rebound by either side — and belongs to the service-lock domain
documented in ``repro.serve.service``. The runner's policy hooks run
inside the service tick (lock held); the FT plane mutates the same state
from its documented quiescent barrier (``repro.ft.failure``).
"""
from __future__ import annotations

import itertools
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.buffer import SampleBuffer
from repro.core.envmanager import EMState, EnvManager, RolloutPolicy
from repro.core.profiler import AffinityProfiler
from repro.core.proxy import LLMProxy
from repro.core.serverless import ServerlessPlatform
from repro.core.weightstore import (MooncakeStore, pull_param_chunks,
                                    pull_params, push_params,
                                    push_params_sharded)
from repro.data.pipeline import Trajectory, TaskSampler, pack_batch
from repro.data.tokenizer import ByteTokenizer
from repro.envs import make_env
from repro.rl.trainer import TrainState
from repro.serve.service import RolloutJob, RolloutService

MODES = ("rollart", "sync", "sync_plus", "one_off", "areal")
THREADED_MODES = ("rollart", "areal", "one_off")

# Default multi-task mix: the paper's Fig. 3/5 analysis centers on the
# long-tail SWE/webshop environments, so the live runner schedules them by
# default — weighted toward the fast decode-heavy tasks so batches keep
# filling while the long-tail trajectories mature.
DEFAULT_TASKS = ("math", "game", "swe", "webshop")
DEFAULT_TASK_WEIGHTS = (0.35, 0.35, 0.15, 0.15)


@dataclass
class RunnerConfig:
    batch_size: int = 8
    group_size: int = 4
    alpha: int = 1
    mode: str = "rollart"
    tasks: tuple = DEFAULT_TASKS
    # None = the weighted default mix when `tasks` is DEFAULT_TASKS,
    # uniform otherwise; an explicit tuple must match len(tasks)
    task_weights: Optional[tuple] = None
    redundancy: float = 1.0           # env groups launched / needed
    online_affinity: bool = False     # paper §9: auto-derive hw_mapping
    pd_disagg: bool = False           # §6.3: proxy must be two-stage
    #   (prefill pool -> KV handoff -> decode pool; see
    #   repro.core.proxy.build_pd_proxy for constructing such a proxy)
    # resource plane (launchers: --pools / --affinity). `pools` is the
    # heterogeneous device inventory a ResourceManager is built from;
    # `affinity` binds engines role-affinely through it and enables the
    # dynamic prefill<->decode rebalancer.
    pools: Optional[Dict[str, int]] = None
    affinity: bool = False
    # decode macro-step size: K scanned decode steps per jit dispatch
    # (InferenceEngine.steps_per_dispatch; launchers build the proxy's
    # engines with this). Commands drain between macro-steps, so the
    # runner's ABORT-driven controls — per-tick staleness enforcement and
    # redundancy cancellation — act within at most K decode tokens per
    # slot; lower it when abort latency matters more than throughput.
    steps_per_dispatch: int = 8
    max_new_tokens: int = 32
    temperature: float = 1.0
    reward_url: str = "fc://rollart/reward"
    max_pump_steps: int = 200000
    # backpressure: the worker stops spawning new env groups once the
    # buffer already holds this many batches ahead of the trainer
    max_buffered_batches: int = 2
    batch_timeout_s: float = 300.0    # threaded-mode starvation guard
    # fault tolerance: a reward invocation that dies (ServerlessError —
    # container eviction or an injected fault) is re-submitted from its
    # retained payload up to this many times before the error surfaces
    reward_retry_limit: int = 2
    # weighted-QoS share of the trainer tenant when the RolloutService is
    # shared with external serving tenants (stride scheduling; see
    # repro.serve.service — irrelevant while the trainer is alone)
    tenant_weight: float = 1.0
    seed: int = 0

    def sampler_weights(self) -> Optional[List[float]]:
        if self.task_weights is not None:
            return list(self.task_weights)
        if tuple(self.tasks) == DEFAULT_TASKS:
            return list(DEFAULT_TASK_WEIGHTS)
        return None                   # custom task set: uniform


@dataclass
class StepMetrics:
    step: int
    wall_s: float
    loss: float
    reward_mean: float
    evicted: int                 # evictions during THIS step (delta)
    aborted: int                 # aborts during THIS step (delta)
    trajs: int
    decode_during_train: int = 0     # decode tokens generated while
    #                                  train_step ran (overlap evidence)
    batch_fetched_step: int = 0      # trainer step at which the trained
    #                                  batch left the buffer (-1 = primed
    #                                  before any training; < step in
    #                                  one_off mode: previous-batch rule)
    batch_max_version: int = 0       # newest start_version in the batch
    role_switches: int = 0           # dynamic prefill<->decode role
    #                                  switches during THIS step (delta)
    deduped: int = 0                 # replayed trajectories dropped by the
    #                                  buffer's traj_id dedup (delta; > 0
    #                                  only after a rollout-plane restore)
    fetch_s: float = 0.0             # step (1): blocking batch retrieval
    barrier_s: float = 0.0           # steps (2)-(5): push-await + suspend/
    #                                  update/resume critical section
    train_s: float = 0.0             # step (6): the GRPO update itself
    staleness: int = 0               # weight-version staleness of the
    #                                  trained batch: trainer version at
    #                                  fetch minus the OLDEST start_version
    #                                  in the batch (worst case)

    def to_dict(self) -> Dict[str, float]:
        """Stable flat schema — key order and types are
        ``STEP_METRICS_SCHEMA``, consumed verbatim by the runner's
        per-step log line and the ``repro_step_*`` gauge exporter
        (``repro.obs.instrument``); regression-tested in
        tests/test_observability.py. Add fields THERE, not ad hoc."""
        return {name: typ(getattr(self, name))
                for name, typ in STEP_METRICS_SCHEMA}


# (field, type) pairs defining the stable StepMetrics export schema; the
# obs plane derives one `repro_step_<field>` gauge per entry.
STEP_METRICS_SCHEMA = (
    ("step", int),
    ("wall_s", float),
    ("fetch_s", float),
    ("barrier_s", float),
    ("train_s", float),
    ("loss", float),
    ("reward_mean", float),
    ("evicted", int),
    ("aborted", int),
    ("trajs", int),
    ("decode_during_train", int),
    ("batch_fetched_step", int),
    ("batch_max_version", int),
    ("staleness", int),
    ("role_switches", int),
    ("deduped", int),
)


TRAINER_TENANT = "trainer"


class LiveRLRunner:
    """Producer/consumer runner of the full RollArt pipeline — tenant #0
    of a :class:`~repro.serve.RolloutService`.

    Asynchronous modes run the rollout side on the service's background
    thread; synchronous baselines tick the same service cooperatively on
    the trainer thread. Call :meth:`close` (or use as a context manager)
    to shut the service and the push thread down — close is idempotent
    and exception-safe (double-close / close-after-crash return promptly).
    """

    def __init__(self, cfg: RunnerConfig, proxy: LLMProxy,
                 train_state: TrainState,
                 train_step_fn: Callable,
                 serverless: ServerlessPlatform,
                 reward_fn: Callable[[Dict], float],
                 store: Optional[MooncakeStore] = None,
                 seq_len: int = 512,
                 service: Optional[RolloutService] = None):
        self.cfg = cfg
        assert cfg.mode in MODES
        if cfg.pd_disagg and not proxy.pd_disagg:
            raise ValueError("RunnerConfig.pd_disagg=True requires a "
                             "PD-disaggregated LLMProxy (build_pd_proxy)")
        if cfg.affinity and (proxy.rm is None or proxy.rebalancer is None):
            raise ValueError(
                "RunnerConfig.affinity=True requires a proxy built with a "
                "ResourceManager and a RebalancerConfig (build_pd_proxy("
                "resource_manager=..., rebalancer=...))")
        self.proxy = proxy
        self.state = train_state
        self.train_step_fn = train_step_fn
        self.serverless = serverless
        self.serverless.deploy(cfg.reward_url, reward_fn)
        self.store = store or MooncakeStore(bucket_mb=1)
        self.buffer = SampleBuffer(alpha=cfg.alpha)
        self.tok = ByteTokenizer()
        self.sampler = TaskSampler(list(cfg.tasks), seed=cfg.seed,
                                   weights=cfg.sampler_weights())
        self.seq_len = seq_len
        self.version = 0
        self.profiler = AffinityProfiler() if cfg.online_affinity else None
        self._seed_counter = itertools.count(cfg.seed * 1000)
        self.history: List[StepMetrics] = []
        self.threaded = cfg.mode in THREADED_MODES
        # async modes score rewards through invoke_async + a pending-
        # futures drain; plain "sync" keeps the blocking inline call
        self._use_async_reward = cfg.mode != "sync"
        # --- the serving tier -----------------------------------------
        # An externally supplied service lets the trainer share the data
        # plane with serving tenants (launch/serve.py --service); by
        # default the runner builds a private one.
        self.service = service if service is not None else RolloutService(
            proxy, max_pump_steps=cfg.max_pump_steps)
        self._tenant = self.service.register_tenant(
            TRAINER_TENANT,
            weight=cfg.tenant_weight,
            tokenizer=self.tok,
            sink=self.buffer.put,
            source=self._next_job,
            pre_tick=self._enforce_staleness,
            post_tick=self._post_tick,
            observe=(self._observe_em if self.profiler is not None
                     else None),
            version_fn=lambda: self.version,
            reward_url=cfg.reward_url,
            serverless=self.serverless,
            use_async_reward=self._use_async_reward,
            reward_retry_limit=cfg.reward_retry_limit)
        # Aliases into the tenant/service state: the SAME objects, never
        # rebound by either side (the FT plane mutates them in place
        # through the runner under its quiescent barrier)
        self.active: List[EnvManager] = self._tenant.active
        self._pending_rewards = self._tenant.pending_rewards
        self._completed_lock = self.service._completed_lock
        self._completed_this_round = self._tenant.completed
        # fault-tolerance hook: called at the end of every suspend ->
        # update -> resume barrier while the service lock is still held
        # (the rollout plane is quiescent there) — the FT supervisor
        # installs its snapshot capture here (see repro.ft.supervisor)
        self.barrier_hook: Optional[Callable[["LiveRLRunner", int], None]] \
            = None
        # traj_ids trained per step (dedup / parity audits)
        self.trained_log: List[List[str]] = []
        # async weight push: one thread so publications stay ordered
        self._push_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="weight-push")
        self._push_future: Optional[Future] = None
        self._closed = False
        # one_off pipeline state: the batch fetched last step, trained on
        # this step while its successor rolls out
        self._prev_batch: Optional[List[Trajectory]] = None
        self._prev_batch_fetched_step = -1
        self.last_batch: List[Trajectory] = []
        self._last_evicted = 0
        self._last_aborted = 0
        self._last_role_switches = 0
        self._last_deduped = 0
        # weight-sync format: a plane with TP engine groups publishes
        # PER-SHARD chunks (engines assemble their own shards and never
        # materialize a full per-engine copy); a single-device plane
        # keeps the dense per-leaf format. Chunk dims follow the same
        # serve rules the engines place with, so chunks and shards line
        # up by construction.
        self._tp_chunks = self.proxy.max_group_size()
        if self._tp_chunks > 1:
            from repro.distributed.sharding import model_axis_dims
            self._chunk_dims = model_axis_dims(self.state.params,
                                               self._tp_chunks)
        else:
            self._chunk_dims = None
        # publish v0 weights
        self._publish_params(self.state.params, 0)

    # ------------------------------------------------------------------
    # rollout policy (runs inside the service tick via the tenant hooks)
    # ------------------------------------------------------------------
    def _next_job(self) -> Optional[RolloutJob]:
        """Trainer job source (service admission pulls from here): top up
        env groups unless the buffer is already ``max_buffered_batches``
        ahead of the trainer (backpressure: the service must not produce
        unboundedly). The backlog includes trajectories parked on
        unresolved reward futures, or slow serverless calls would defeat
        the bound. Returns one env-group job, or None when satisfied."""
        backlog = self.buffer.size() + len(self._pending_rewards)
        if (backlog >= self.cfg.batch_size
                * max(1, self.cfg.max_buffered_batches)):
            return None
        need_groups = int(np.ceil(
            self.cfg.batch_size / self.cfg.group_size * self.cfg.redundancy))
        alive = len({em.group_id for em in self.active
                     if em.state in (EMState.IDLE, EMState.GENERATING)})
        if alive >= need_groups:
            return None
        task = self.sampler.sample()
        gid = f"v{self.version}.g{alive}.{task}.{next(self._seed_counter)}"
        envs, seeds = [], []
        for _ in range(self.cfg.group_size):
            envs.append(make_env(task, seed=next(self._seed_counter)))
            seeds.append(next(self._seed_counter))
        return RolloutJob(
            kind="env", tag=task, envs=envs, seeds=seeds, group_id=gid,
            policy=RolloutPolicy(max_new_tokens=self.cfg.max_new_tokens,
                                 temperature=self.cfg.temperature),
            version=self.version,
            # the trainer consumes trajectories through the buffer, not
            # the per-job token stream — don't accumulate chunks nobody
            # reads (serving tenants opt in per job instead)
            stream=False)

    def _enforce_staleness(self):
        """RollArt: per-tick trajectory-level staleness control (tenant
        ``pre_tick`` hook, before admission)."""
        if self.cfg.mode == "areal":
            return   # AReaL bounds staleness at trajectory start only
        bound = self.version - self.cfg.alpha
        for em in list(self.active):
            if em.state == EMState.GENERATING and em.start_version < bound:
                em.abort()

    def _post_tick(self):
        """Tenant ``post_tick`` hook: redundant rollouts — once the
        buffer has a full batch, cancel the slowest in-flight rollouts
        beyond what the next iteration can use."""
        if (self.cfg.redundancy > 1.0
                and self.buffer.size() >= self.cfg.batch_size):
            self._cancel_surplus()

    def _cancel_surplus(self):
        """Abort only the surplus beyond ``batch_size * redundancy``
        in-flight trajectories (the headroom the next iteration launches
        with), slowest first — matching the simulator's per-iteration
        redundancy semantics. Aborting everything would also kill the
        groups the next batch needs and force cold restarts."""
        headroom = int(np.ceil(self.cfg.batch_size * self.cfg.redundancy))
        generating = [em for em in self.active
                      if em.state == EMState.GENERATING]
        surplus = len(generating) - headroom
        if surplus <= 0:
            return
        generating.sort(key=lambda em: em.turns)   # least progress first
        for em in generating[:surplus]:
            em.abort()

    def _observe_em(self, em: EnvManager):
        """Tenant ``observe`` hook (§9 online affinity profiling)."""
        prefill = sum(1 for m in em.loss_mask if m == 0)
        decode = len(em.tokens) - prefill
        self.profiler.observe(em.tag, prefill, decode, em.turns)

    def _on_em_complete(self, em: EnvManager):
        """Completion callback for managers resurrected OUTSIDE the
        service's job path (the FT snapshot restore re-wires restored
        managers here); same contract as the service's own hook."""
        with self._completed_lock:
            self._completed_this_round.append(em)

    # ------------------------------------------------------------------
    # service delegation shims (the FT plane and the test suite drive
    # the rollout plane through these; all dispatch is service-owned)
    # ------------------------------------------------------------------
    def _ensure_inflight(self):
        """Admit trainer jobs now (pulls :meth:`_next_job` dry)."""
        self.service.admit(only=TRAINER_TENANT)

    def _drain_completions(self) -> int:
        return self.service.drain_completions()

    def _drain_rewards(self, block: bool = False) -> int:
        return self.service.drain_rewards(block=block)

    def _drain_rollout(self):
        """Synchronous baselines: rollout and training strictly
        alternate, so — like the simulator's sync mode — leftover
        in-flight rollouts are CANCELLED after the batch, not completed
        into the next one."""
        self.service.drain_tenant(TRAINER_TENANT)

    @property
    def reward_retries(self) -> int:
        return self._tenant.stats["reward_retries"]

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def _start_rollout_worker(self):
        self.service.start()

    def _pause_rollout_worker(self):
        """Park the service thread; returns only once no tick is in
        flight (any tick that already passed the flag check finishes
        first)."""
        self.service.pause()

    def close(self):
        """Shut down the service thread and the weight-push thread.
        Idempotent and exception-safe: double-close is a no-op, and a
        close after a service-thread crash returns promptly instead of
        hanging on the join (regression: tests/test_rollout_service.py)."""
        if self._closed:
            return
        self._closed = True
        try:
            self.service.close()
        finally:
            try:
                self._await_push()
            finally:
                self._push_pool.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------------
    # trainer side helpers
    # ------------------------------------------------------------------
    def _await_batch(self) -> List[Trajectory]:
        """Protocol step (1). Threaded modes block on the buffer (the
        service produces concurrently); synchronous modes tick the
        service cooperatively until a batch exists."""
        if self.threaded:
            deadline = time.monotonic() + self.cfg.batch_timeout_s
            while True:
                if self.service.error is not None:
                    raise RuntimeError("rollout worker died") \
                        from self.service.error
                try:
                    return self.buffer.get_batch(self.cfg.batch_size,
                                                 timeout=0.2)
                except TimeoutError:
                    if time.monotonic() > deadline:
                        raise RuntimeError(
                            "rollout starved: no batch collected")
        pumps = 0
        while True:
            batch = self.buffer.try_get_batch(self.cfg.batch_size)
            if batch is not None:
                return batch
            self.service.tick()
            pumps += 1
            if pumps > self.cfg.max_pump_steps:
                raise RuntimeError("rollout starved: no batch collected")

    def _publish_params(self, params, version: int) -> int:
        """Publish one weight version in the plane's format: per-shard
        chunks when any engine runs a TP group, dense otherwise. The FT
        restore path republishes through this too, so a restored plane
        keeps pulling the format its engines expect."""
        if self._tp_chunks > 1:
            return push_params_sharded(self.store, params, version,
                                       self._tp_chunks, self._chunk_dims)
        return push_params(self.store, params, version)

    def _push_async(self):
        """Publish the new weights off-thread; the transfer overlaps the
        resumed rollout and is awaited at the next suspend barrier."""
        params, version = self.state.params, self.version
        self._push_future = self._push_pool.submit(
            self._publish_params, params, version)

    def _await_push(self):
        if self._push_future is not None:
            self._push_future.result()
            self._push_future = None

    def _decode_tokens_total(self) -> int:
        return sum(h.engine.decode_tokens for h in self.proxy.handles)

    def placement_report(self, **kw) -> List[Dict]:
        """Modeled prefill/decode latency + cost per engine pool (PerfModel
        pricing of the live placement; see LLMProxy.placement_report)."""
        return self.proxy.placement_report(**kw)

    # ------------------------------------------------------------------
    # the six-step protocol (the consumer thread)
    # ------------------------------------------------------------------
    def run_steps(self, num_steps: int) -> List[StepMetrics]:
        sync_like = self.cfg.mode in ("sync", "sync_plus")
        one_off = self.cfg.mode == "one_off"
        if self.threaded:
            self._start_rollout_worker()
        try:
            for _ in range(num_steps):
                step = len(self.history)
                t0 = time.monotonic()
                # (1) get_batch. one_off trains on the PREVIOUS iteration's
                # batch (fetched at the end of the last step, so it was in
                # hand before this step began) while its successor rolls out.
                if one_off:
                    if self._prev_batch is None:
                        self._prev_batch = self._await_batch()   # priming
                        self._prev_batch_fetched_step = -1
                    batch_trajs = self._prev_batch
                    fetched_step = self._prev_batch_fetched_step
                else:
                    batch_trajs = self._await_batch()
                    fetched_step = step
                t_fetch = time.monotonic()
                self.last_batch = batch_trajs
                staleness = self.version - min(t.start_version
                                               for t in batch_trajs)
                # (2)-(5) the ONLY rollout/trainer barrier: suspend,
                # pull + update + in-flight KV recompute, resume — atomic
                # w.r.t. the service tick so a weight swap never races a
                # decode.
                self._await_push()
                with self.service.barrier():
                    self.proxy.suspend()
                    # (5) recomp happens inside update_all[_chunks]
                    # (no-op for engines already at version v)
                    if self._tp_chunks > 1:
                        pulled = pull_param_chunks(self.store,
                                                   self.state.params)
                        if pulled is not None:
                            chunks, v = pulled
                            self.proxy.update_all_chunks(
                                chunks, v, recompute_caches=True)
                    else:
                        pulled = pull_params(self.store, self.state.params)
                        if pulled is not None:
                            params, v = pulled
                            self.proxy.update_all(params, v,
                                                  recompute_caches=True)
                    self.proxy.resume()
                    if self.barrier_hook is not None:
                        # rollout snapshot point: the service lock is
                        # held, so every engine slot / env manager /
                        # pending reward is quiescent and mutually
                        # consistent
                        self.barrier_hook(self, step)
                t_barrier = time.monotonic()
                # (6) train_step, overlapped with the resumed rollout
                batch = self._pack(batch_trajs)
                d0 = self._decode_tokens_total()
                self.state, metrics = self.train_step_fn(self.state, batch)
                loss = float(metrics["loss"])   # blocks until step done
                t_train = time.monotonic()
                d1 = self._decode_tokens_total()
                self.version = int(self.state.version)
                self.buffer.set_version(self.version)
                if self.profiler is not None:
                    with self.service.barrier():    # §9 online re-routing
                        self.profiler.apply_to(self.proxy)
                self._push_async()
                if one_off:
                    # the batch produced while we trained becomes the NEXT
                    # iteration's training data
                    self._prev_batch = self._await_batch()
                    self._prev_batch_fetched_step = step
                if sync_like:
                    self._drain_rollout()
                rewards = [t.reward for t in batch_trajs]
                ev_total = self.buffer.total_evicted
                ab_total = self.proxy.aborted
                rs_total = self.proxy.role_switches
                dd_total = self.buffer.total_deduped
                sm = StepMetrics(
                    step=step, wall_s=time.monotonic() - t0,
                    loss=loss,
                    reward_mean=float(np.mean(rewards)),
                    evicted=ev_total - self._last_evicted,
                    aborted=ab_total - self._last_aborted,
                    trajs=len(batch_trajs),
                    decode_during_train=d1 - d0,
                    batch_fetched_step=fetched_step,
                    batch_max_version=max(t.start_version
                                          for t in batch_trajs),
                    role_switches=rs_total - self._last_role_switches,
                    deduped=dd_total - self._last_deduped,
                    fetch_s=t_fetch - t0,
                    barrier_s=t_barrier - t_fetch,
                    train_s=t_train - t_barrier,
                    staleness=staleness)
                self._last_evicted, self._last_aborted = ev_total, ab_total
                self._last_role_switches = rs_total
                self._last_deduped = dd_total
                self.trained_log.append([t.traj_id for t in batch_trajs])
                self.history.append(sm)
        finally:
            if self.threaded:
                self._pause_rollout_worker()
            self._await_push()
        return self.history

    def _pack(self, trajs: List[Trajectory]) -> Dict:
        import jax.numpy as jnp
        # GRPO: group-normalize rewards within same-group trajectories,
        # falling back to batch normalization for stragglers
        by_group: Dict[str, List[Trajectory]] = {}
        for t in trajs:
            by_group.setdefault(t.group_id, []).append(t)
        rewards = np.asarray([t.reward for t in trajs], np.float32)
        adv = np.zeros_like(rewards)
        idx = {id(t): i for i, t in enumerate(trajs)}
        for group in by_group.values():
            r = np.asarray([t.reward for t in group], np.float32)
            mu, sd = r.mean(), r.std()
            base = (r - mu) / (sd + 1e-6) if len(group) > 1 else r - mu
            for t, a in zip(group, base):
                adv[idx[id(t)]] = a
        batch = pack_batch(trajs, self.seq_len)
        batch["advantages"] = adv
        return {k: jnp.asarray(v) for k, v in batch.items()}

"""Rollout scheduler + asynchronous training orchestration (paper §6).

``LiveRLRunner`` drives the REAL pipeline (tiny models, real environments,
real GRPO updates) through the paper's six-step weight-sync protocol:

  (1) get_batch   — blocking retrieval from SampleBuffer
  (2) suspend     — LLMProxy stops admitting requests (in-flight preserved)
  (3) update      — engines pull the latest weights from the Mooncake store
                    (a version-matched pull is a no-op: nothing re-prefills)
  (4) resume      — pending generation continues
  (5) recomp      — in-flight trajectories' KV caches rebuilt under the new
                    weights (so they continue instead of restarting)
  (6) train_step  — the GRPO update, genuinely overlapped with rollout

The overlap is real, not cooperative: in the asynchronous modes ("rollart",
"areal", "one_off") the entire rollout side — proxy pump, EnvManager
completion cascade, serverless reward scoring — runs on a persistent
background worker thread that keeps producing into ``SampleBuffer`` while
the trainer thread executes the six-step protocol. The ONLY barrier between
the two threads is the suspend → update → resume critical section, taken
under the shared pump lock so a weight swap never races a decode step.
Reward scoring is non-blocking (``ServerlessPlatform.invoke_async``): a
scored trajectory enters the buffer when its future resolves — drained in
submission order so batch composition stays deterministic — and the weight
push after each train step happens on its own thread, awaited only at the
next suspend barrier. ``StepMetrics.decode_during_train`` counts decode
tokens the engines generated while ``train_step`` ran (> 0 in the threaded
modes, 0 in the synchronous baselines; see benchmarks/async_overlap.py).

Also implements trajectory-level staleness enforcement (abort EnvManagers
whose start_version < n - alpha, every rollout tick — stricter than AReaL)
and redundant environment rollouts (launch extra groups, cancel the slowest
once the target count is met; exploits GRPO's group structure).

Modes ("rollart", "sync", "sync_plus", "one_off", "areal") reproduce the
paper's baselines with the same code path, differing only in coordination:
  sync      — rollout and training strictly alternate; blocking reward
  sync_plus — sync + async (serverless-offloaded) reward scoring
  one_off   — training consumes the PREVIOUS iteration's batch while the
              next one rolls out (threaded; one-step pipeline)
  areal     — staleness bound applied at trajectory start only (threaded)
  rollart   — bounded staleness alpha enforced per tick + affinity
              (threaded)
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.buffer import SampleBuffer
from repro.core.envmanager import EMState, EnvManager, RolloutPolicy
from repro.core.profiler import AffinityProfiler
from repro.core.proxy import LLMProxy
from repro.core.serverless import ServerlessPlatform
from repro.core.weightstore import MooncakeStore, pull_params, push_params
from repro.data.pipeline import Trajectory, TaskSampler, pack_batch
from repro.data.tokenizer import ByteTokenizer
from repro.envs import make_env
from repro.rl.trainer import TrainState

MODES = ("rollart", "sync", "sync_plus", "one_off", "areal")
THREADED_MODES = ("rollart", "areal", "one_off")

# Default multi-task mix: the paper's Fig. 3/5 analysis centers on the
# long-tail SWE/webshop environments, so the live runner schedules them by
# default — weighted toward the fast decode-heavy tasks so batches keep
# filling while the long-tail trajectories mature.
DEFAULT_TASKS = ("math", "game", "swe", "webshop")
DEFAULT_TASK_WEIGHTS = (0.35, 0.35, 0.15, 0.15)


@dataclass
class RunnerConfig:
    batch_size: int = 8
    group_size: int = 4
    alpha: int = 1
    mode: str = "rollart"
    tasks: tuple = DEFAULT_TASKS
    # None = the weighted default mix when `tasks` is DEFAULT_TASKS,
    # uniform otherwise; an explicit tuple must match len(tasks)
    task_weights: Optional[tuple] = None
    redundancy: float = 1.0           # env groups launched / needed
    online_affinity: bool = False     # paper §9: auto-derive hw_mapping
    pd_disagg: bool = False           # §6.3: proxy must be two-stage
    #   (prefill pool -> KV handoff -> decode pool; see
    #   repro.core.proxy.build_pd_proxy for constructing such a proxy)
    # resource plane (launchers: --pools / --affinity). `pools` is the
    # heterogeneous device inventory a ResourceManager is built from;
    # `affinity` binds engines role-affinely through it and enables the
    # dynamic prefill<->decode rebalancer.
    pools: Optional[Dict[str, int]] = None
    affinity: bool = False
    # decode macro-step size: K scanned decode steps per jit dispatch
    # (InferenceEngine.steps_per_dispatch; launchers build the proxy's
    # engines with this). Commands drain between macro-steps, so the
    # runner's ABORT-driven controls — per-tick staleness enforcement and
    # redundancy cancellation — act within at most K decode tokens per
    # slot; lower it when abort latency matters more than throughput.
    steps_per_dispatch: int = 8
    max_new_tokens: int = 32
    temperature: float = 1.0
    reward_url: str = "fc://rollart/reward"
    max_pump_steps: int = 200000
    # backpressure: the worker stops spawning new env groups once the
    # buffer already holds this many batches ahead of the trainer
    max_buffered_batches: int = 2
    batch_timeout_s: float = 300.0    # threaded-mode starvation guard
    # fault tolerance: a reward invocation that dies (ServerlessError —
    # container eviction or an injected fault) is re-submitted from its
    # retained payload up to this many times before the error surfaces
    reward_retry_limit: int = 2
    seed: int = 0

    def sampler_weights(self) -> Optional[List[float]]:
        if self.task_weights is not None:
            return list(self.task_weights)
        if tuple(self.tasks) == DEFAULT_TASKS:
            return list(DEFAULT_TASK_WEIGHTS)
        return None                   # custom task set: uniform


@dataclass
class StepMetrics:
    step: int
    wall_s: float
    loss: float
    reward_mean: float
    evicted: int                 # evictions during THIS step (delta)
    aborted: int                 # aborts during THIS step (delta)
    trajs: int
    decode_during_train: int = 0     # decode tokens generated while
    #                                  train_step ran (overlap evidence)
    batch_fetched_step: int = 0      # trainer step at which the trained
    #                                  batch left the buffer (-1 = primed
    #                                  before any training; < step in
    #                                  one_off mode: previous-batch rule)
    batch_max_version: int = 0       # newest start_version in the batch
    role_switches: int = 0           # dynamic prefill<->decode role
    #                                  switches during THIS step (delta)
    deduped: int = 0                 # replayed trajectories dropped by the
    #                                  buffer's traj_id dedup (delta; > 0
    #                                  only after a rollout-plane restore)


class LiveRLRunner:
    """Producer/consumer runner of the full RollArt pipeline.

    Asynchronous modes run the rollout side on a background worker thread
    (`_rollout_worker_loop`); synchronous baselines tick the same rollout
    code cooperatively on the trainer thread. Call :meth:`close` (or use as
    a context manager) to join the worker and the push thread.
    """

    def __init__(self, cfg: RunnerConfig, proxy: LLMProxy,
                 train_state: TrainState,
                 train_step_fn: Callable,
                 serverless: ServerlessPlatform,
                 reward_fn: Callable[[Dict], float],
                 store: Optional[MooncakeStore] = None,
                 seq_len: int = 512):
        self.cfg = cfg
        assert cfg.mode in MODES
        if cfg.pd_disagg and not proxy.pd_disagg:
            raise ValueError("RunnerConfig.pd_disagg=True requires a "
                             "PD-disaggregated LLMProxy (build_pd_proxy)")
        if cfg.affinity and (proxy.rm is None or proxy.rebalancer is None):
            raise ValueError(
                "RunnerConfig.affinity=True requires a proxy built with a "
                "ResourceManager and a RebalancerConfig (build_pd_proxy("
                "resource_manager=..., rebalancer=...))")
        self.proxy = proxy
        self.state = train_state
        self.train_step_fn = train_step_fn
        self.serverless = serverless
        self.serverless.deploy(cfg.reward_url, reward_fn)
        self.store = store or MooncakeStore(bucket_mb=1)
        self.buffer = SampleBuffer(alpha=cfg.alpha)
        self.tok = ByteTokenizer()
        # guarded by: _pump_lock
        self.sampler = TaskSampler(list(cfg.tasks), seed=cfg.seed,
                                   weights=cfg.sampler_weights())
        self.seq_len = seq_len
        self.version = 0
        self.profiler = AffinityProfiler() if cfg.online_affinity else None
        self.active: List[EnvManager] = []         # guarded by: _pump_lock
        self._seed_counter = itertools.count(cfg.seed * 1000)  # guarded by: _pump_lock
        self.history: List[StepMetrics] = []
        self.threaded = cfg.mode in THREADED_MODES
        # async modes score rewards through invoke_async + a pending-
        # futures drain; plain "sync" keeps the blocking inline call
        self._use_async_reward = cfg.mode != "sync"
        # pump-vs-control barrier: the worker holds it per rollout tick,
        # the trainer holds it across suspend -> update -> resume
        self._pump_lock = threading.Lock()
        self._completed_lock = threading.Lock()
        self._completed_this_round: List[EnvManager] = []  # guarded by: _completed_lock
        # [trajectory, payload, reward-future, attempts] entries, drained
        # in submission order; the payload is retained so a lost
        # invocation (ServerlessError) can be re-submitted, and so a
        # rollout snapshot can re-issue pending rewards after a restore
        self._pending_rewards: collections.deque = collections.deque()  # guarded by: _pump_lock
        # fault-tolerance hook: called at the end of every suspend ->
        # update -> resume barrier while the pump lock is still held (the
        # rollout plane is quiescent there) — the FT supervisor installs
        # its snapshot capture here (see repro.ft.supervisor)
        self.barrier_hook: Optional[Callable[["LiveRLRunner", int], None]] \
            = None
        # traj_ids trained per step (dedup / parity audits)
        self.trained_log: List[List[str]] = []
        self.reward_retries = 0                    # guarded by: _pump_lock
        self._run_rollout = threading.Event()
        self._stop = threading.Event()
        self._rollout_thread: Optional[threading.Thread] = None
        self._rollout_error: Optional[BaseException] = None
        # async weight push: one thread so publications stay ordered
        self._push_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="weight-push")
        self._push_future: Optional[Future] = None
        # one_off pipeline state: the batch fetched last step, trained on
        # this step while its successor rolls out
        self._prev_batch: Optional[List[Trajectory]] = None
        self._prev_batch_fetched_step = -1
        self.last_batch: List[Trajectory] = []
        self._last_evicted = 0
        self._last_aborted = 0
        self._last_role_switches = 0
        self._last_deduped = 0
        # publish v0 weights
        push_params(self.store, self.state.params, version=0)

    # ------------------------------------------------------------------
    # rollout side (worker thread in threaded modes, cooperative in sync)
    # ------------------------------------------------------------------
    def _spawn_group(self, task: str, group_id: str, n: int):   # requires: _pump_lock
        for _ in range(n):
            env = make_env(task, seed=next(self._seed_counter))
            em = EnvManager(
                env, self.proxy, tokenizer=self.tok,
                policy=RolloutPolicy(max_new_tokens=self.cfg.max_new_tokens,
                                     temperature=self.cfg.temperature),
                tag=task, group_id=group_id,
                on_complete=self._on_em_complete)
            self.active.append(em)
            em.start(version=self.version, seed=next(self._seed_counter))

    def _on_em_complete(self, em: EnvManager):
        with self._completed_lock:
            self._completed_this_round.append(em)

    def _score_and_buffer(self, em: EnvManager):   # requires: _pump_lock
        """Reward stage. Async modes submit the serverless call and return
        immediately — the trajectory enters the buffer when its future
        resolves (``_drain_rewards``), not inline in the pump."""
        traj = em.trajectory()
        if self.profiler is not None and em.turns:
            prefill = sum(1 for m in em.loss_mask if m == 0)
            decode = len(em.tokens) - prefill
            self.profiler.observe(em.tag, prefill, decode, em.turns)
        if em.state in (EMState.FAILED, EMState.ABORTED):
            return   # redundant rollouts / staleness absorb these
        payload = {
            "env_return": em.env_return,
            "tokens": traj.tokens,
            "loss_mask": traj.loss_mask,
            "num_tokens": len(traj.tokens),
            "text": self.tok.decode(traj.tokens),
        }
        if self._use_async_reward:
            # analysis: ignore[blocking-under-lock] pool.submit only: the
            # call executes on the serverless pool thread, not here
            fut = self.serverless.invoke_async(self.cfg.reward_url, payload)
            self._pending_rewards.append([traj, payload, fut, 0])
        else:
            # analysis: ignore[blocking-under-lock] sync baseline BY
            # DESIGN: "sync" mode scores rewards inline in the tick (the
            # pump lock is the worker-vs-barrier mutex and sync modes
            # have no worker thread, so nothing is serialized behind it)
            traj.reward = float(self.serverless.invoke(self.cfg.reward_url,
                                                       payload))
            self.buffer.put(traj)

    def _drain_rewards(self, block: bool = False) -> int:   # requires: _pump_lock
        """Move reward-scored trajectories into the buffer. Completed-
        PREFIX drain: trajectories are buffered in reward SUBMISSION order
        even when a later future resolves first, so batch composition does
        not depend on serverless timing. A lost invocation (the platform
        raises — e.g. an injected ``ServerlessError``) is re-submitted
        from its retained payload up to ``reward_retry_limit`` times; only
        then does the error surface to the caller."""
        n = 0
        while self._pending_rewards:
            entry = self._pending_rewards[0]
            traj, payload, fut, attempts = entry
            if not block and not fut.done():
                break
            try:
                traj.reward = float(fut.result())
            except Exception:
                if attempts >= self.cfg.reward_retry_limit:
                    raise
                # analysis: ignore[blocking-under-lock] pool.submit only
                entry[2] = self.serverless.invoke_async(
                    self.cfg.reward_url, payload)
                entry[3] = attempts + 1
                self.reward_retries += 1
                if not block:
                    break
                continue
            self._pending_rewards.popleft()
            self.buffer.put(traj)
            n += 1
        return n

    def _drain_completions(self) -> int:   # requires: _pump_lock
        with self._completed_lock:
            done = self._completed_this_round
            self._completed_this_round = []
        for em in done:
            self._score_and_buffer(em)
            if em in self.active:
                self.active.remove(em)
        return len(done)

    def _enforce_staleness(self):   # requires: _pump_lock
        """RollArt: per-tick trajectory-level staleness control."""
        if self.cfg.mode == "areal":
            return   # AReaL bounds staleness at trajectory start only
        bound = self.version - self.cfg.alpha
        for em in list(self.active):
            if em.state == EMState.GENERATING and em.start_version < bound:
                em.abort()

    def _ensure_inflight(self):   # requires: _pump_lock
        """Keep enough environment groups running to feed the buffer —
        unless it is already ``max_buffered_batches`` ahead of the trainer
        (backpressure: the worker must not produce unboundedly). The
        backlog includes trajectories parked on unresolved reward futures,
        or slow serverless calls would defeat the bound."""
        backlog = self.buffer.size() + len(self._pending_rewards)
        if (backlog >= self.cfg.batch_size
                * max(1, self.cfg.max_buffered_batches)):
            return
        need_groups = int(np.ceil(
            self.cfg.batch_size / self.cfg.group_size * self.cfg.redundancy))
        alive = len({em.group_id for em in self.active
                     if em.state in (EMState.IDLE, EMState.GENERATING)})
        for g in range(need_groups - alive):
            task = self.sampler.sample()
            gid = f"v{self.version}.g{g}.{task}.{next(self._seed_counter)}"
            self._spawn_group(task, gid, self.cfg.group_size)

    def _rollout_tick(self) -> int:   # requires: _pump_lock
        """One rollout iteration: staleness enforcement, env-group top-up,
        one proxy pump, completion cascade, reward drain, surplus
        cancellation. Returns an activity count (0 == idle tick; the pump
        contribution is decode TOKENS, so the count — like every
        token-denominated signal the runner reads — is invariant to the
        engines' steps_per_dispatch batching)."""
        self._enforce_staleness()
        self._ensure_inflight()
        n = self.proxy.pump()
        n += self._drain_completions()
        n += self._drain_rewards()
        # redundant rollouts: once the buffer has a full batch, cancel the
        # slowest in-flight rollouts beyond what the next iteration can use
        if (self.cfg.redundancy > 1.0
                and self.buffer.size() >= self.cfg.batch_size):
            self._cancel_surplus()
        return n

    def _cancel_surplus(self):   # requires: _pump_lock
        """Abort only the surplus beyond ``batch_size * redundancy``
        in-flight trajectories (the headroom the next iteration launches
        with), slowest first — matching the simulator's per-iteration
        redundancy semantics. Aborting everything would also kill the
        groups the next batch needs and force cold restarts."""
        headroom = int(np.ceil(self.cfg.batch_size * self.cfg.redundancy))
        generating = [em for em in self.active
                      if em.state == EMState.GENERATING]
        surplus = len(generating) - headroom
        if surplus <= 0:
            return
        generating.sort(key=lambda em: em.turns)   # least progress first
        for em in generating[:surplus]:
            em.abort()

    # ------------------------------------------------------------------
    # background rollout worker (the producer thread)
    # ------------------------------------------------------------------
    def _rollout_worker_loop(self):
        try:
            while not self._stop.is_set():
                if not self._run_rollout.wait(timeout=0.05):
                    continue
                with self._pump_lock:
                    if not self._run_rollout.is_set():
                        continue
                    n = self._rollout_tick()
                if n == 0:
                    time.sleep(0.002)   # idle: yield the GIL to the trainer
        except BaseException as e:        # surfaced by _await_batch
            self._rollout_error = e
            self._run_rollout.clear()

    def _start_rollout_worker(self):
        if self._stop.is_set():
            raise RuntimeError("runner is closed; create a new LiveRLRunner")
        if self._rollout_thread is None:
            self._rollout_thread = threading.Thread(
                target=self._rollout_worker_loop, name="rollout-worker",
                daemon=True)
            self._rollout_thread.start()
        self._run_rollout.set()

    def _pause_rollout_worker(self):
        """Park the worker; returns only once no tick is in flight (any
        tick that already passed the flag check finishes first)."""
        self._run_rollout.clear()
        with self._pump_lock:
            pass

    def close(self):
        """Join the rollout worker and the weight-push thread."""
        self._run_rollout.clear()
        self._stop.set()
        if self._rollout_thread is not None:
            self._rollout_thread.join(timeout=10.0)
            self._rollout_thread = None
        self._await_push()
        self._push_pool.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------------
    # trainer side helpers
    # ------------------------------------------------------------------
    def _await_batch(self) -> List[Trajectory]:
        """Protocol step (1). Threaded modes block on the buffer (the
        worker produces concurrently); synchronous modes pump the rollout
        cooperatively until a batch exists."""
        if self.threaded:
            deadline = time.monotonic() + self.cfg.batch_timeout_s
            while True:
                if self._rollout_error is not None:
                    raise RuntimeError("rollout worker died") \
                        from self._rollout_error
                try:
                    return self.buffer.get_batch(self.cfg.batch_size,
                                                 timeout=0.2)
                except TimeoutError:
                    if time.monotonic() > deadline:
                        raise RuntimeError(
                            "rollout starved: no batch collected")
        pumps = 0
        while True:
            batch = self.buffer.try_get_batch(self.cfg.batch_size)
            if batch is not None:
                return batch
            # sync modes have no worker thread, so the pump lock is
            # uncontended here — taken anyway so every _rollout_tick call
            # site satisfies the same documented discipline
            with self._pump_lock:
                self._rollout_tick()
            pumps += 1
            if pumps > self.cfg.max_pump_steps:
                raise RuntimeError("rollout starved: no batch collected")

    def _drain_rollout(self):
        """Synchronous baselines: rollout and training strictly alternate,
        so — like the simulator's sync mode — leftover in-flight rollouts
        are CANCELLED after the batch, not completed into the next one
        (each iteration trains on freshly generated trajectories). The
        pump lock is uncontended in sync modes (no worker thread) but
        taken anyway: the rollout state keeps one documented guard."""
        with self._pump_lock:
            for em in list(self.active):
                em.abort()
            pumps = 0
            while self.proxy.busy:
                self.proxy.pump()
                self._drain_completions()
                self._drain_rewards()
                pumps += 1
                if pumps > self.cfg.max_pump_steps:
                    raise RuntimeError("rollout did not drain")
            self._drain_completions()
            self._drain_rewards(block=True)

    def _push_async(self):
        """Publish the new weights off-thread; the transfer overlaps the
        resumed rollout and is awaited at the next suspend barrier."""
        params, version = self.state.params, self.version
        self._push_future = self._push_pool.submit(
            push_params, self.store, params, version)

    def _await_push(self):
        if self._push_future is not None:
            self._push_future.result()
            self._push_future = None

    def _decode_tokens_total(self) -> int:
        return sum(h.engine.decode_tokens for h in self.proxy.handles)

    def placement_report(self, **kw) -> List[Dict]:
        """Modeled prefill/decode latency + cost per engine pool (PerfModel
        pricing of the live placement; see LLMProxy.placement_report)."""
        return self.proxy.placement_report(**kw)

    # ------------------------------------------------------------------
    # the six-step protocol (the consumer thread)
    # ------------------------------------------------------------------
    def run_steps(self, num_steps: int) -> List[StepMetrics]:
        sync_like = self.cfg.mode in ("sync", "sync_plus")
        one_off = self.cfg.mode == "one_off"
        if self.threaded:
            self._start_rollout_worker()
        try:
            for _ in range(num_steps):
                step = len(self.history)
                t0 = time.monotonic()
                # (1) get_batch. one_off trains on the PREVIOUS iteration's
                # batch (fetched at the end of the last step, so it was in
                # hand before this step began) while its successor rolls out.
                if one_off:
                    if self._prev_batch is None:
                        self._prev_batch = self._await_batch()   # priming
                        self._prev_batch_fetched_step = -1
                    batch_trajs = self._prev_batch
                    fetched_step = self._prev_batch_fetched_step
                else:
                    batch_trajs = self._await_batch()
                    fetched_step = step
                self.last_batch = batch_trajs
                # (2)-(5) the ONLY rollout/trainer barrier: suspend,
                # pull + update + in-flight KV recompute, resume — atomic
                # w.r.t. the pump so a weight swap never races a decode.
                self._await_push()
                with self._pump_lock:
                    self.proxy.suspend()
                    pulled = pull_params(self.store, self.state.params)
                    if pulled is not None:
                        params, v = pulled
                        # (5) recomp happens inside update_all (no-op for
                        # engines already at version v)
                        self.proxy.update_all(params, v,
                                              recompute_caches=True)
                    self.proxy.resume()
                    if self.barrier_hook is not None:
                        # rollout snapshot point: the pump lock is held,
                        # so every engine slot / env manager / pending
                        # reward is quiescent and mutually consistent
                        self.barrier_hook(self, step)
                # (6) train_step, overlapped with the resumed rollout
                batch = self._pack(batch_trajs)
                d0 = self._decode_tokens_total()
                self.state, metrics = self.train_step_fn(self.state, batch)
                loss = float(metrics["loss"])   # blocks until step done
                d1 = self._decode_tokens_total()
                self.version = int(self.state.version)
                self.buffer.set_version(self.version)
                if self.profiler is not None:
                    with self._pump_lock:       # §9 online re-routing
                        self.profiler.apply_to(self.proxy)
                self._push_async()
                if one_off:
                    # the batch produced while we trained becomes the NEXT
                    # iteration's training data
                    self._prev_batch = self._await_batch()
                    self._prev_batch_fetched_step = step
                if sync_like:
                    self._drain_rollout()
                rewards = [t.reward for t in batch_trajs]
                ev_total = self.buffer.total_evicted
                ab_total = self.proxy.aborted
                rs_total = self.proxy.role_switches
                dd_total = self.buffer.total_deduped
                sm = StepMetrics(
                    step=step, wall_s=time.monotonic() - t0,
                    loss=loss,
                    reward_mean=float(np.mean(rewards)),
                    evicted=ev_total - self._last_evicted,
                    aborted=ab_total - self._last_aborted,
                    trajs=len(batch_trajs),
                    decode_during_train=d1 - d0,
                    batch_fetched_step=fetched_step,
                    batch_max_version=max(t.start_version
                                          for t in batch_trajs),
                    role_switches=rs_total - self._last_role_switches,
                    deduped=dd_total - self._last_deduped)
                self._last_evicted, self._last_aborted = ev_total, ab_total
                self._last_role_switches = rs_total
                self._last_deduped = dd_total
                self.trained_log.append([t.traj_id for t in batch_trajs])
                self.history.append(sm)
        finally:
            if self.threaded:
                self._pause_rollout_worker()
            self._await_push()
        return self.history

    def _pack(self, trajs: List[Trajectory]) -> Dict:
        import jax.numpy as jnp
        # GRPO: group-normalize rewards within same-group trajectories,
        # falling back to batch normalization for stragglers
        by_group: Dict[str, List[Trajectory]] = {}
        for t in trajs:
            by_group.setdefault(t.group_id, []).append(t)
        rewards = np.asarray([t.reward for t in trajs], np.float32)
        adv = np.zeros_like(rewards)
        idx = {id(t): i for i, t in enumerate(trajs)}
        for group in by_group.values():
            r = np.asarray([t.reward for t in group], np.float32)
            mu, sd = r.mean(), r.std()
            base = (r - mu) / (sd + 1e-6) if len(group) > 1 else r - mu
            for t, a in zip(group, base):
                adv[idx[id(t)]] = a
        batch = pack_batch(trajs, self.seq_len)
        batch["advantages"] = adv
        return {k: jnp.asarray(v) for k, v in batch.items()}

"""Rollout scheduler + asynchronous training orchestration (paper §6).

``LiveRLRunner`` drives the REAL pipeline (tiny models, real environments,
real GRPO updates) through the paper's six-step weight-sync protocol:

  (1) get_batch   — blocking retrieval from SampleBuffer
  (2) suspend     — LLMProxy stops admitting requests (in-flight preserved)
  (3) update      — engines pull the latest weights from the Mooncake store
  (4) resume      — pending generation continues
  (5) recomp      — in-flight trajectories' KV caches rebuilt under the new
                    weights (so they continue instead of restarting)
  (6) train_step  — the GRPO update, overlapped with resumed rollout

plus trajectory-level staleness enforcement (abort EnvManagers whose
start_version < n - alpha, every iteration — stricter than AReaL) and
redundant environment rollouts (launch extra groups, cancel the slowest
once the target count is met; exploits GRPO's group structure).

Modes ("rollart", "sync", "sync_plus", "one_off", "areal") reproduce the
paper's baselines with the same code path, differing only in coordination:
  sync      — rollout and training strictly alternate; batched env waits
  sync_plus — sync + async reward + serverless offload
  one_off   — training consumes the previous iteration's trajectories
  areal     — staleness bound applied at trajectory start only
  rollart   — bounded staleness alpha enforced per iteration + affinity
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.buffer import SampleBuffer
from repro.core.envmanager import EMState, EnvManager, RolloutPolicy
from repro.core.profiler import AffinityProfiler
from repro.core.proxy import LLMProxy
from repro.core.serverless import ServerlessPlatform
from repro.core.weightstore import MooncakeStore, pull_params, push_params
from repro.data.pipeline import Trajectory, TaskSampler, pack_batch
from repro.data.tokenizer import ByteTokenizer
from repro.envs import make_env
from repro.rl.trainer import TrainState

MODES = ("rollart", "sync", "sync_plus", "one_off", "areal")


@dataclass
class RunnerConfig:
    batch_size: int = 8
    group_size: int = 4
    alpha: int = 1
    mode: str = "rollart"
    tasks: tuple = ("math", "game")
    redundancy: float = 1.0           # env groups launched / needed
    online_affinity: bool = False     # paper §9: auto-derive hw_mapping
    pd_disagg: bool = False           # §6.3: proxy must be two-stage
    #   (prefill pool -> KV handoff -> decode pool; see
    #   repro.core.proxy.build_pd_proxy for constructing such a proxy)
    max_new_tokens: int = 32
    temperature: float = 1.0
    reward_url: str = "fc://rollart/reward"
    max_pump_steps: int = 200000
    seed: int = 0


@dataclass
class StepMetrics:
    step: int
    wall_s: float
    loss: float
    reward_mean: float
    evicted: int
    aborted: int
    trajs: int


class LiveRLRunner:
    """Cooperative single-process runner of the full RollArt pipeline."""

    def __init__(self, cfg: RunnerConfig, proxy: LLMProxy,
                 train_state: TrainState,
                 train_step_fn: Callable,
                 serverless: ServerlessPlatform,
                 reward_fn: Callable[[Dict], float],
                 store: Optional[MooncakeStore] = None,
                 seq_len: int = 512):
        self.cfg = cfg
        assert cfg.mode in MODES
        if cfg.pd_disagg and not proxy.pd_disagg:
            raise ValueError("RunnerConfig.pd_disagg=True requires a "
                             "PD-disaggregated LLMProxy (build_pd_proxy)")
        self.proxy = proxy
        self.state = train_state
        self.train_step_fn = train_step_fn
        self.serverless = serverless
        self.serverless.deploy(cfg.reward_url, reward_fn)
        self.store = store or MooncakeStore(bucket_mb=1)
        self.buffer = SampleBuffer(alpha=cfg.alpha)
        self.tok = ByteTokenizer()
        self.sampler = TaskSampler(list(cfg.tasks), seed=cfg.seed)
        self.seq_len = seq_len
        self.version = 0
        self.profiler = AffinityProfiler() if cfg.online_affinity else None
        self.active: List[EnvManager] = []
        self._seed_counter = itertools.count(cfg.seed * 1000)
        self.history: List[StepMetrics] = []
        # publish v0 weights
        push_params(self.store, self.state.params, version=0)
        self._completed_this_round: List[EnvManager] = []

    # ------------------------------------------------------------------
    # rollout side
    # ------------------------------------------------------------------
    def _spawn_group(self, task: str, group_id: str, n: int):
        for _ in range(n):
            env = make_env(task, seed=next(self._seed_counter))
            em = EnvManager(
                env, self.proxy, tokenizer=self.tok,
                policy=RolloutPolicy(max_new_tokens=self.cfg.max_new_tokens,
                                     temperature=self.cfg.temperature),
                tag=task, group_id=group_id,
                on_complete=self._on_em_complete)
            self.active.append(em)
            em.start(version=self.version, seed=next(self._seed_counter))

    def _on_em_complete(self, em: EnvManager):
        self._completed_this_round.append(em)

    def _score_and_buffer(self, em: EnvManager):
        """Reward stage: serverless scoring as soon as a trajectory lands."""
        traj = em.trajectory()
        if self.profiler is not None and em.turns:
            prefill = sum(1 for m in em.loss_mask if m == 0)
            decode = len(em.tokens) - prefill
            self.profiler.observe(em.tag, prefill, decode, em.turns)
        if em.state in (EMState.FAILED, EMState.ABORTED):
            return   # redundant rollouts / staleness absorb these
        payload = {
            "env_return": em.env_return,
            "tokens": traj.tokens,
            "loss_mask": traj.loss_mask,
            "num_tokens": len(traj.tokens),
            "text": self.tok.decode(traj.tokens),
        }
        traj.reward = float(self.serverless.invoke(self.cfg.reward_url,
                                                   payload))
        self.buffer.put(traj)

    def _enforce_staleness(self):
        """RollArt: per-iteration trajectory-level staleness control."""
        if self.cfg.mode == "areal":
            return   # AReaL bounds staleness at trajectory start only
        bound = self.version - self.cfg.alpha
        for em in self.active:
            if em.state == EMState.GENERATING and em.start_version < bound:
                em.abort()

    def _ensure_inflight(self):
        """Keep enough environment groups running to feed the buffer."""
        need_groups = int(np.ceil(
            self.cfg.batch_size / self.cfg.group_size * self.cfg.redundancy))
        alive = len({em.group_id for em in self.active
                     if em.state in (EMState.IDLE, EMState.GENERATING)})
        for g in range(need_groups - alive):
            task = self.sampler.sample()
            gid = f"v{self.version}.g{g}.{task}.{next(self._seed_counter)}"
            self._spawn_group(task, gid, self.cfg.group_size)

    def _pump(self):
        """One cooperative tick: engines decode; completions cascade."""
        self.proxy.pump()
        done, self._completed_this_round = self._completed_this_round, []
        for em in done:
            self._score_and_buffer(em)
            if em in self.active:
                self.active.remove(em)
        # redundant rollouts: once the buffer has a full batch, cancel the
        # slowest in-flight rollouts beyond what the next iteration can use
        if (self.cfg.redundancy > 1.0
                and self.buffer.size() >= self.cfg.batch_size):
            self._cancel_surplus()

    def _cancel_surplus(self):
        """Abort only the surplus beyond ``batch_size * redundancy``
        in-flight trajectories (the headroom the next iteration launches
        with), slowest first — matching the simulator's per-iteration
        redundancy semantics. Aborting everything would also kill the
        groups the next batch needs and force cold restarts."""
        headroom = int(np.ceil(self.cfg.batch_size * self.cfg.redundancy))
        generating = [em for em in self.active
                      if em.state == EMState.GENERATING]
        surplus = len(generating) - headroom
        if surplus <= 0:
            return
        generating.sort(key=lambda em: em.turns)   # least progress first
        for em in generating[:surplus]:
            em.abort()

    # ------------------------------------------------------------------
    # the six-step protocol
    # ------------------------------------------------------------------
    def run_steps(self, num_steps: int) -> List[StepMetrics]:
        sync_like = self.cfg.mode in ("sync", "sync_plus")
        for step in range(num_steps):
            t0 = time.monotonic()
            self._ensure_inflight()
            # (1) get_batch: pump the pipeline until a batch is ready
            pumps = 0
            while True:
                batch_trajs = self.buffer.try_get_batch(self.cfg.batch_size)
                if batch_trajs is not None:
                    break
                self._ensure_inflight()
                self._pump()
                pumps += 1
                if pumps > self.cfg.max_pump_steps:
                    raise RuntimeError("rollout starved: no batch collected")
            # (2) suspend
            self.proxy.suspend()
            # (3) update: engines pull the newest weights from the store
            pulled = pull_params(self.store, self.state.params)
            if pulled is not None:
                params, v = pulled
                # (5) recomp happens inside update_all (cache rebuild)
                self.proxy.update_all(params, v, recompute_caches=True)
            # (4) resume
            self.proxy.resume()
            # (6) train_step (+ publish weights for the next pull)
            batch = self._pack(batch_trajs)
            self.state, metrics = self.train_step_fn(self.state, batch)
            self.version = int(self.state.version)
            self.buffer.set_version(self.version)
            self._enforce_staleness()
            if self.profiler is not None:
                self.profiler.apply_to(self.proxy)   # §9 online re-routing
            push_params(self.store, self.state.params, version=self.version)
            if sync_like:
                # synchronous baselines: drain all rollout before continuing
                while self.proxy.busy:
                    self._pump()
            rewards = [t.reward for t in batch_trajs]
            sm = StepMetrics(
                step=step, wall_s=time.monotonic() - t0,
                loss=float(metrics["loss"]),
                reward_mean=float(np.mean(rewards)),
                evicted=self.buffer.total_evicted,
                aborted=self.proxy.aborted, trajs=len(batch_trajs))
            self.history.append(sm)
        return self.history

    def _pack(self, trajs: List[Trajectory]) -> Dict:
        import jax.numpy as jnp
        # GRPO: group-normalize rewards within same-group trajectories,
        # falling back to batch normalization for stragglers
        by_group: Dict[str, List[Trajectory]] = {}
        for t in trajs:
            by_group.setdefault(t.group_id, []).append(t)
        rewards = np.asarray([t.reward for t in trajs], np.float32)
        adv = np.zeros_like(rewards)
        idx = {id(t): i for i, t in enumerate(trajs)}
        for group in by_group.values():
            r = np.asarray([t.reward for t in group], np.float32)
            mu, sd = r.mean(), r.std()
            base = (r - mu) / (sd + 1e-6) if len(group) > 1 else r - mu
            for t, a in zip(group, base):
                adv[idx[id(t)]] = a
        batch = pack_batch(trajs, self.seq_len)
        batch["advantages"] = adv
        return {k: jnp.asarray(v) for k, v in batch.items()}

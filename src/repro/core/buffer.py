"""SampleBuffer (paper §6.2): buffers scored trajectories for training with
the per-trajectory asynchronous staleness bound alpha.

Invariants (property-tested in tests/test_staleness.py):
- a trajectory with start_version < current_version - alpha is NEVER
  returned by get_batch (it is evicted eagerly);
- with E concurrent environments the buffer holds O(alpha * E) pending
  trajectories across versions (eager eviction bounds growth);
- get_batch blocks until ``batch_size`` valid trajectories exist.

Unlike AReaL, which bounds staleness only at trajectory *start*, RollArt
re-checks the bound every iteration, so long-tail trajectories spanning
multiple versions are aborted (the control plane also aborts their
in-flight generation via LLMProxy).
"""
from __future__ import annotations

import itertools
import threading
from typing import Callable, List, Optional

from repro.data.pipeline import Trajectory


class SampleBuffer:
    def __init__(self, alpha: int = 1,
                 on_evict: Optional[Callable[[Trajectory], None]] = None):
        self.alpha = alpha
        self._seq = itertools.count()   # arrival order (deterministic FIFO)
        self._items: List[Trajectory] = []
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self.on_evict = on_evict
        self.current_version = 0
        # stats
        self.total_put = 0
        self.total_evicted = 0
        self.total_consumed = 0

    # ------------------------------------------------------------------
    def put(self, traj: Trajectory):
        with self._cv:
            traj.seq = next(self._seq)
            if self._is_stale(traj, self.current_version):
                self._evict(traj)
                return
            self._items.append(traj)
            self.total_put += 1
            self._cv.notify_all()

    def _is_stale(self, traj: Trajectory, version: int) -> bool:
        return traj.start_version < version - self.alpha

    def _evict(self, traj: Trajectory):
        self.total_evicted += 1
        if self.on_evict:
            self.on_evict(traj)

    def set_version(self, version: int):
        """Advance the trainer's weight version; eagerly evict stale."""
        with self._cv:
            self.current_version = version
            keep = []
            for t in self._items:
                if self._is_stale(t, version):
                    self._evict(t)
                else:
                    keep.append(t)
            self._items = keep
            self._cv.notify_all()

    # ------------------------------------------------------------------
    def size(self) -> int:
        with self._lock:
            return len(self._items)

    def try_get_batch(self, batch_size: int) -> Optional[List[Trajectory]]:
        """Non-blocking: a batch of the OLDEST valid trajectories, or None."""
        with self._cv:
            self._items = self._evict_stale_locked()
            if len(self._items) < batch_size:
                return None
            # oldest first: version, then numeric arrival order (the
            # lexicographic traj_id would put "t10" before "t2")
            self._items.sort(key=lambda t: (t.start_version, t.seq))
            batch, self._items = (self._items[:batch_size],
                                  self._items[batch_size:])
            self.total_consumed += len(batch)
            return batch

    def _evict_stale_locked(self) -> List[Trajectory]:
        keep = []
        for t in self._items:
            if self._is_stale(t, self.current_version):
                self._evict(t)
            else:
                keep.append(t)
        return keep

    def get_batch(self, batch_size: int,
                  timeout: Optional[float] = None) -> List[Trajectory]:
        """Blocking get_batch (protocol step (1))."""
        with self._cv:
            def ready():
                self._items = self._evict_stale_locked()
                return len(self._items) >= batch_size
            if not self._cv.wait_for(ready, timeout=timeout):
                raise TimeoutError(
                    f"get_batch({batch_size}) timed out with "
                    f"{len(self._items)} buffered")
            self._items.sort(key=lambda t: (t.start_version, t.seq))
            batch, self._items = (self._items[:batch_size],
                                  self._items[batch_size:])
            self.total_consumed += len(batch)
            return batch

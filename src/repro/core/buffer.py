"""SampleBuffer (paper §6.2): buffers scored trajectories for training with
the per-trajectory asynchronous staleness bound alpha.

Invariants (property-tested in tests/test_staleness.py):
- a trajectory with start_version < current_version - alpha is NEVER
  returned by get_batch (it is evicted eagerly);
- with E concurrent environments the buffer holds O(alpha * E) pending
  trajectories across versions (eager eviction bounds growth);
- get_batch blocks until ``batch_size`` valid trajectories exist.

Unlike AReaL, which bounds staleness only at trajectory *start*, RollArt
re-checks the bound every iteration, so long-tail trajectories spanning
multiple versions are aborted (the control plane also aborts their
in-flight generation via LLMProxy).

Fault tolerance (paper §8): the buffer tracks the ``traj_id`` of every
consumed trajectory, and ``put`` drops replays of an already-consumed id
(``total_deduped``). When the FT supervisor restores the rollout plane
from a snapshot taken BEFORE the last few training steps, the replayed
EnvManagers regenerate trajectories the trainer already consumed — the
dedup filter guarantees no ``traj_id`` trains twice.
``snapshot_state``/``restore_state`` serialize the buffer for
rollout-level checkpointing (see ``repro.ft.snapshot``).
"""
from __future__ import annotations

import itertools
import threading
from typing import Callable, Dict, List, Optional

from repro.data.pipeline import Trajectory


class SampleBuffer:
    def __init__(self, alpha: int = 1,
                 on_evict: Optional[Callable[[Trajectory], None]] = None):
        self.alpha = alpha
        self._seq = itertools.count()              # guarded by: _lock
        self._items: List[Trajectory] = []         # guarded by: _lock
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self.on_evict = on_evict
        self.current_version = 0                   # guarded by: _lock
        # traj_ids handed to the trainer
        self._consumed: set = set()                # guarded by: _lock
        # traj_ids currently in _items
        self._buffered: set = set()                # guarded by: _lock
        # stats
        self.total_put = 0                         # guarded by: _lock
        self.total_evicted = 0                     # guarded by: _lock
        self.total_consumed = 0                    # guarded by: _lock
        self.total_deduped = 0                     # guarded by: _lock

    # ------------------------------------------------------------------
    def put(self, traj: Trajectory):
        with self._cv:
            if (traj.traj_id in self._consumed
                    or traj.traj_id in self._buffered):
                # replay of a trajectory already trained on — or already
                # buffered awaiting training (a rollout-plane restore from
                # a snapshot older than the completion that produced the
                # first copy): either way it must not train twice
                self.total_deduped += 1
                return
            traj.seq = next(self._seq)
            if self._is_stale(traj, self.current_version):
                self._evict(traj)
                return
            self._items.append(traj)
            self._buffered.add(traj.traj_id)
            self.total_put += 1
            self._cv.notify_all()

    def _is_stale(self, traj: Trajectory, version: int) -> bool:
        return traj.start_version < version - self.alpha

    def _evict(self, traj: Trajectory):   # requires: _lock
        self._buffered.discard(traj.traj_id)
        self.total_evicted += 1
        if self.on_evict:
            self.on_evict(traj)

    def set_version(self, version: int):
        """Advance the trainer's weight version; eagerly evict stale."""
        with self._cv:
            self.current_version = version
            keep = []
            for t in self._items:
                if self._is_stale(t, version):
                    self._evict(t)
                else:
                    keep.append(t)
            self._items = keep
            self._cv.notify_all()

    # ------------------------------------------------------------------
    def size(self) -> int:
        with self._lock:
            return len(self._items)

    def stats(self) -> Dict[str, int]:
        """Immutable snapshot of the buffer counters (one lock
        acquisition; the obs plane scrapes this concurrently with
        put/get traffic). Returns a fresh dict every call."""
        with self._lock:
            return {"depth": len(self._items),
                    "current_version": self.current_version,
                    "total_put": self.total_put,
                    "total_evicted": self.total_evicted,
                    "total_consumed": self.total_consumed,
                    "total_deduped": self.total_deduped}

    def try_get_batch(self, batch_size: int) -> Optional[List[Trajectory]]:
        """Non-blocking: a batch of the OLDEST valid trajectories, or None."""
        with self._cv:
            self._items = self._evict_stale_locked()
            if len(self._items) < batch_size:
                return None
            # oldest first: version, then numeric arrival order (the
            # lexicographic traj_id would put "t10" before "t2")
            self._items.sort(key=lambda t: (t.start_version, t.seq))
            batch, self._items = (self._items[:batch_size],
                                  self._items[batch_size:])
            self.total_consumed += len(batch)
            for t in batch:
                self._buffered.discard(t.traj_id)
                self._consumed.add(t.traj_id)
            return batch

    def _evict_stale_locked(self) -> List[Trajectory]:   # requires: _lock
        keep = []
        for t in self._items:
            if self._is_stale(t, self.current_version):
                self._evict(t)
            else:
                keep.append(t)
        return keep

    def get_batch(self, batch_size: int,
                  timeout: Optional[float] = None) -> List[Trajectory]:
        """Blocking get_batch (protocol step (1))."""
        with self._cv:
            def ready():
                self._items = self._evict_stale_locked()
                return len(self._items) >= batch_size
            if not self._cv.wait_for(ready, timeout=timeout):
                raise TimeoutError(
                    f"get_batch({batch_size}) timed out with "
                    f"{len(self._items)} buffered")
            self._items.sort(key=lambda t: (t.start_version, t.seq))
            batch, self._items = (self._items[:batch_size],
                                  self._items[batch_size:])
            self.total_consumed += len(batch)
            for t in batch:
                self._buffered.discard(t.traj_id)
                self._consumed.add(t.traj_id)
            return batch

    # ------------------------------------------------------------------
    # rollout-level checkpointing (repro.ft.snapshot)
    # ------------------------------------------------------------------
    def snapshot_state(self) -> Dict:
        """Consistent copy of the buffer for a rollout snapshot. Item
        ``seq`` numbers are preserved so FIFO ordering survives a
        restore."""
        with self._lock:
            # peek-then-recreate: read the next seq value without
            # perturbing the arrival ordering
            nxt = next(self._seq)
            self._seq = itertools.count(nxt)
            return {"items": list(self._items), "seq": nxt,
                    "version": self.current_version,
                    "consumed": set(self._consumed),
                    "total_put": self.total_put,
                    "total_evicted": self.total_evicted,
                    "total_consumed": self.total_consumed,
                    "total_deduped": self.total_deduped}

    def restore_state(self, state: Dict, keep_consumed: bool = False):
        """Rebuild the buffer from ``snapshot_state`` output. With
        ``keep_consumed`` the CURRENT consumed-id set is kept (unioned
        with the snapshot's) — the live-recovery path, where training
        advanced past the snapshot and replayed trajectories must dedup
        against the newer training frontier."""
        with self._cv:
            consumed = set(state["consumed"])
            if keep_consumed:
                consumed |= self._consumed
            self._consumed = consumed
            self._items = [t for t in state["items"]
                           if t.traj_id not in consumed]
            self._buffered = {t.traj_id for t in self._items}
            self.total_deduped += len(state["items"]) - len(self._items)
            self._seq = itertools.count(max(
                state["seq"], 1 + max((t.seq for t in self._items),
                                      default=-1)))
            self.current_version = state["version"]
            self.total_put = state["total_put"]
            self.total_evicted = state["total_evicted"]
            self.total_consumed = state["total_consumed"]
            self._cv.notify_all()

"""EnvManager (paper §6.1): a lightweight controller that drives ONE
environment's lifecycle — reset, then an independent loop alternating
LLMProxy generation with env.step — assembling a token-aligned multi-turn
trajectory. Each EnvManager runs on its own timeline, so a slow or failed
environment never blocks the others (R2).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, List, Optional

from repro.core.proxy import LLMProxy
from repro.data.pipeline import Trajectory
from repro.data.tokenizer import ByteTokenizer
from repro.envs.base import EnvError, TextEnv
from repro.rl.engine import GenRequest, GenResult

_ids = itertools.count()


class EMState(Enum):
    IDLE = 0
    GENERATING = 1
    DONE = 2
    FAILED = 3
    ABORTED = 4


@dataclass
class RolloutPolicy:
    max_new_tokens: int = 48
    temperature: float = 1.0
    max_prompt_tokens: int = 384
    stop_tokens: tuple = (2,)       # EOS


class EnvManager:
    def __init__(self, env: TextEnv, proxy: LLMProxy,
                 tokenizer: Optional[ByteTokenizer] = None,
                 policy: Optional[RolloutPolicy] = None,
                 tag: Optional[str] = None,
                 on_complete: Optional[Callable[["EnvManager"], None]] = None,
                 group_id: str = ""):
        self.em_id = f"em-{next(_ids)}"
        self.env = env
        self.proxy = proxy
        self.tok = tokenizer or ByteTokenizer()
        self.policy = policy or RolloutPolicy()
        self.tag = tag or env.TASK
        self.on_complete = on_complete
        self.group_id = group_id
        self.state = EMState.IDLE
        self.tokens: List[int] = []
        self.loss_mask: List[int] = []
        self.logprobs: List[float] = []
        self.turns = 0
        self.start_version = 0
        self.end_version = 0
        self.env_return = 0.0
        self._req_counter = itertools.count()
        self._active_req: Optional[str] = None

    # ------------------------------------------------------------------
    def start(self, version: int, seed: Optional[int] = None):
        """reset + first generation request."""
        self.start_version = version
        try:
            obs = self.env.reset(seed=seed)
        except EnvError:
            self.state = EMState.FAILED
            if self.on_complete:
                self.on_complete(self)
            return
        self._append_obs(obs)
        self._request_action()

    def _append_obs(self, obs: str):
        ids = self.tok.encode(obs + "\n", bos=not self.tokens)
        self.tokens.extend(ids)
        self.loss_mask.extend([0] * len(ids))
        self.logprobs.extend([0.0] * len(ids))

    def _prompt(self) -> List[int]:
        return self.tokens[-self.policy.max_prompt_tokens:]

    def _request_action(self):
        self.state = EMState.GENERATING
        rid = f"{self.em_id}.r{next(self._req_counter)}"
        self._active_req = rid
        self.proxy.submit(
            GenRequest(request_id=rid, prompt=self._prompt(),
                       max_new_tokens=self.policy.max_new_tokens,
                       temperature=self.policy.temperature,
                       stop_tokens=self.policy.stop_tokens, tag=self.tag),
            callback=self.on_generation)

    # ------------------------------------------------------------------
    def on_generation(self, result: GenResult):
        """Proxy callback: apply the action to the environment."""
        self._active_req = None
        if self.state in (EMState.ABORTED, EMState.DONE, EMState.FAILED):
            return
        if result.finish_reason == "aborted":
            self.state = EMState.ABORTED
            if self.on_complete:
                self.on_complete(self)
            return
        action_ids = [t for t in result.tokens
                      if t not in self.policy.stop_tokens]
        self.tokens.extend(action_ids)
        self.loss_mask.extend([1] * len(action_ids))
        self.logprobs.extend(result.logprobs[: len(action_ids)])
        self.end_version = result.weight_version
        action = self.tok.decode(action_ids)
        self.turns += 1
        try:
            obs, reward, done, _ = self.env.step(action)
        except EnvError:
            self.state = EMState.FAILED
            if self.on_complete:
                self.on_complete(self)
            return
        self.env_return += reward
        if done:
            self.state = EMState.DONE
            if self.on_complete:
                self.on_complete(self)
            return
        self._append_obs(obs)
        self._request_action()

    # ------------------------------------------------------------------
    def abort(self):
        """Cancel this trajectory (staleness bound / redundant rollouts).

        Idempotent. A GENERATING manager is cancelled through the proxy
        and completes via the aborted-result callback; a manager that is
        not generating (IDLE, or mid-transition) is completed HERE —
        ``on_complete`` must still fire, otherwise the runner never learns
        the manager terminated and leaks it in its active set forever.
        """
        if self.state in (EMState.DONE, EMState.FAILED, EMState.ABORTED):
            return                       # already completed; hook already ran
        if self.state == EMState.GENERATING and self._active_req:
            self.proxy.abort(self._active_req)
            return
        self.state = EMState.ABORTED
        if self.on_complete:
            self.on_complete(self)

    def trajectory(self) -> Trajectory:
        return Trajectory(
            traj_id=self.em_id, task=self.env.TASK,
            tokens=list(self.tokens), loss_mask=list(self.loss_mask),
            logprobs=list(self.logprobs),
            reward=self.env_return, group_id=self.group_id,
            start_version=self.start_version, version=self.end_version,
            turns=self.turns,
            meta={"state": self.state.name})

"""EnvManager (paper §6.1): a lightweight controller that drives ONE
environment's lifecycle — reset, then an independent loop alternating
LLMProxy generation with env.step — assembling a token-aligned multi-turn
trajectory. Each EnvManager runs on its own timeline, so a slow or failed
environment never blocks the others (R2).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional

from repro.core.proxy import LLMProxy
from repro.data.pipeline import Trajectory
from repro.data.tokenizer import ByteTokenizer
from repro.envs.base import EnvError, TextEnv
from repro.rl.engine import GenRequest, GenResult

_ids = itertools.count()


def em_counter_value() -> int:
    """Current value of the global EnvManager id counter (non-consuming;
    peek-then-recreate). Captured into rollout snapshots so a restore in a
    FRESH process can advance the counter past every snapshotted id —
    otherwise new managers could reuse an already-consumed ``traj_id`` and
    be wrongly dropped by the SampleBuffer dedup filter."""
    global _ids
    v = next(_ids)
    _ids = itertools.count(v)
    return v


def ensure_em_counter(minimum: int):
    """Advance the global id counter so future ids are >= ``minimum``."""
    global _ids
    v = next(_ids)
    _ids = itertools.count(max(v, minimum))


class EMState(Enum):
    IDLE = 0
    GENERATING = 1
    DONE = 2
    FAILED = 3
    ABORTED = 4


@dataclass
class RolloutPolicy:
    max_new_tokens: int = 48
    temperature: float = 1.0
    max_prompt_tokens: int = 384
    stop_tokens: tuple = (2,)       # EOS


class EnvManager:
    def __init__(self, env: TextEnv, proxy: LLMProxy,
                 tokenizer: Optional[ByteTokenizer] = None,
                 policy: Optional[RolloutPolicy] = None,
                 tag: Optional[str] = None,
                 on_complete: Optional[Callable[["EnvManager"], None]] = None,
                 group_id: str = "",
                 on_tokens: Optional[Callable] = None):
        self.em_id = f"em-{next(_ids)}"
        self.env = env
        self.proxy = proxy
        self.tok = tokenizer or ByteTokenizer()
        self.policy = policy or RolloutPolicy()
        self.tag = tag or env.TASK
        self.on_complete = on_complete
        # incremental token-stream subscriber, forwarded with every
        # generation request (see LLMProxy.submit / repro.serve.stream)
        self.on_tokens = on_tokens
        self.group_id = group_id
        self.state = EMState.IDLE
        self.tokens: List[int] = []
        self.loss_mask: List[int] = []
        self.logprobs: List[float] = []
        self.turns = 0
        self.start_version = 0
        self.end_version = 0
        self.env_return = 0.0
        self._req_counter = itertools.count()
        self._active_req: Optional[str] = None

    # ------------------------------------------------------------------
    def start(self, version: int, seed: Optional[int] = None):
        """reset + first generation request."""
        self.start_version = version
        try:
            obs = self.env.reset(seed=seed)
        except EnvError:
            self.state = EMState.FAILED
            if self.on_complete:
                self.on_complete(self)
            return
        self._append_obs(obs)
        self._request_action()

    def _append_obs(self, obs: str):
        ids = self.tok.encode(obs + "\n", bos=not self.tokens)
        self.tokens.extend(ids)
        self.loss_mask.extend([0] * len(ids))
        self.logprobs.extend([0.0] * len(ids))

    def _prompt(self) -> List[int]:
        return self.tokens[-self.policy.max_prompt_tokens:]

    def _request_action(self):
        self.state = EMState.GENERATING
        rid = f"{self.em_id}.r{next(self._req_counter)}"
        self._active_req = rid
        self.proxy.submit(
            GenRequest(request_id=rid, prompt=self._prompt(),
                       max_new_tokens=self.policy.max_new_tokens,
                       temperature=self.policy.temperature,
                       stop_tokens=self.policy.stop_tokens, tag=self.tag),
            callback=self.on_generation, on_tokens=self.on_tokens)

    # ------------------------------------------------------------------
    def on_generation(self, result: GenResult):
        """Proxy callback: apply the action to the environment."""
        self._active_req = None
        if self.state in (EMState.ABORTED, EMState.DONE, EMState.FAILED):
            return
        if result.finish_reason == "aborted":
            self.state = EMState.ABORTED
            if self.on_complete:
                self.on_complete(self)
            return
        action_ids = [t for t in result.tokens
                      if t not in self.policy.stop_tokens]
        self.tokens.extend(action_ids)
        self.loss_mask.extend([1] * len(action_ids))
        self.logprobs.extend(result.logprobs[: len(action_ids)])
        self.end_version = result.weight_version
        action = self.tok.decode(action_ids)
        self.turns += 1
        try:
            obs, reward, done, _ = self.env.step(action)
        except EnvError:
            self.state = EMState.FAILED
            if self.on_complete:
                self.on_complete(self)
            return
        self.env_return += reward
        if done:
            self.state = EMState.DONE
            if self.on_complete:
                self.on_complete(self)
            return
        self._append_obs(obs)
        self._request_action()

    # ------------------------------------------------------------------
    def abort(self):
        """Cancel this trajectory (staleness bound / redundant rollouts).

        Idempotent. A GENERATING manager is cancelled through the proxy
        and completes via the aborted-result callback; a manager that is
        not generating (IDLE, or mid-transition) is completed HERE —
        ``on_complete`` must still fire, otherwise the runner never learns
        the manager terminated and leaks it in its active set forever.
        """
        if self.state in (EMState.DONE, EMState.FAILED, EMState.ABORTED):
            return                       # already completed; hook already ran
        if self.state == EMState.GENERATING and self._active_req:
            self.proxy.abort(self._active_req)
            return
        self.state = EMState.ABORTED
        if self.on_complete:
            self.on_complete(self)

    def fail(self, reason: str = "injected"):
        """Mark this manager FAILED (environment crash, engine loss, or an
        injected fault — paper §8: env failures ~1/10 iterations).
        Idempotent like :meth:`abort`; an in-flight generation request is
        cancelled through the proxy, and its eventual aborted-result
        callback early-outs on the FAILED state."""
        if self.state in (EMState.DONE, EMState.FAILED, EMState.ABORTED):
            return
        rid = self._active_req
        self.state = EMState.FAILED
        if rid is not None:
            self.proxy.abort(rid)
        if self.on_complete:
            self.on_complete(self)

    def retry(self):
        """Re-issue the in-flight generation request after its engine was
        lost and no snapshot covers it: the trajectory's token prefix is
        intact on this side, so a fresh request (new id, re-prefill)
        resumes it from the last completed turn."""
        if self.state != EMState.GENERATING:
            return
        self._request_action()

    # ------------------------------------------------------------------
    # rollout-level checkpointing (repro.ft.snapshot)
    # ------------------------------------------------------------------
    def snapshot_state(self) -> Dict:
        """Serializable record of this manager's full state machine: token
        stream, env object (picklable: plain fields + ``random.Random``),
        versions, request counter, and the id of the in-flight request (its
        engine-side KV state is captured separately)."""
        nxt = next(self._req_counter)       # peek-then-recreate: capture
        self._req_counter = itertools.count(nxt)    # must not perturb ids
        return {
            "em_id": self.em_id, "tag": self.tag,
            "group_id": self.group_id, "state": self.state.name,
            "tokens": list(self.tokens), "loss_mask": list(self.loss_mask),
            "logprobs": list(self.logprobs), "turns": self.turns,
            "start_version": self.start_version,
            "end_version": self.end_version,
            "env_return": self.env_return,
            "req_counter": nxt,
            "active_req": self._active_req,
            "env": self.env,
        }

    @classmethod
    def restore_from(cls, rec: Dict, proxy: LLMProxy,
                     tokenizer: Optional[ByteTokenizer] = None,
                     policy: Optional[RolloutPolicy] = None,
                     on_complete: Optional[Callable] = None,
                     ) -> "EnvManager":
        """Rebuild a manager from ``snapshot_state`` output. The restored
        manager keeps its original ``em_id`` (so its trajectory dedups
        against a pre-crash completion) and its request counter (so a
        resumed request id matches the snapshotted engine-side state). The
        caller resumes generation via the proxy (reinject / submit) —
        ``restore_from`` itself issues no requests."""
        em = cls(rec["env"], proxy, tokenizer=tokenizer, policy=policy,
                 tag=rec["tag"], on_complete=on_complete,
                 group_id=rec["group_id"])
        em.em_id = rec["em_id"]
        em.state = EMState[rec["state"]]
        em.tokens = list(rec["tokens"])
        em.loss_mask = list(rec["loss_mask"])
        em.logprobs = list(rec["logprobs"])
        em.turns = rec["turns"]
        em.start_version = rec["start_version"]
        em.end_version = rec["end_version"]
        em.env_return = rec["env_return"]
        em._req_counter = itertools.count(rec["req_counter"])
        em._active_req = rec["active_req"]
        return em

    def trajectory(self) -> Trajectory:
        return Trajectory(
            traj_id=self.em_id, task=self.env.TASK,
            tokens=list(self.tokens), loss_mask=list(self.loss_mask),
            logprobs=list(self.logprobs),
            reward=self.env_return, group_id=self.group_id,
            start_version=self.start_version, version=self.end_version,
            turns=self.turns,
            meta={"state": self.state.name})

"""Asynchronous weight-update engine (paper §6.3 "Data Movement"), built on
a Mooncake-style CPU-resident bucket store.

After each train step the trainer *pushes* bucketized weights once over the
cross-cluster link to the store; inference workers *pull* the newest buckets
on demand over their own links, decoupling weight transfer from rollout.
Live mode stores real jax arrays (flattened into ~bucket_mb chunks); sim
mode tracks only sizes + versions. Transfer-time accounting reproduces the
paper's Table 4 decomposition (push / accumulated pull / exposed pull).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


@dataclass
class Bucket:
    name: str
    version: int
    nbytes: int
    payload: Any = None        # list of (leaf_index, array) in live mode;
    #                            sharded buckets carry
    #                            (leaf_index, shard_index, n_shards, dim,
    #                             array) entries instead
    sharded: bool = False


@dataclass
class TransferLog:
    push_s: float = 0.0
    pull_s: float = 0.0            # accumulated pull cost
    exposed_pull_s: float = 0.0    # pull cost not hidden by rollout
    pushes: int = 0
    pulls: int = 0


class MooncakeStore:
    """Versioned, bucketized weight store with simple latest-wins semantics."""

    def __init__(self, bucket_mb: int = 1024):
        self.bucket_bytes = bucket_mb * 2 ** 20
        self._lock = threading.Lock()
        self._buckets: Dict[int, List[Bucket]] = {}   # guarded by: _lock
        self._latest: int = -1                        # guarded by: _lock
        self.log = TransferLog()                      # guarded by: _lock

    # ------------------------------------------------------------------
    @property
    def latest_version(self) -> int:
        with self._lock:
            return self._latest

    def _pack(self, entries: List[Tuple], version: int,
              sharded: bool) -> List[Bucket]:
        """Pack (..., array) payload entries into ~bucket_bytes buckets."""
        buckets: List[Bucket] = []
        cur: List[Tuple] = []
        cur_bytes = 0
        for entry in entries:
            nb = int(np.asarray(entry[-1]).nbytes)
            if cur and cur_bytes + nb > self.bucket_bytes:
                buckets.append(Bucket(f"v{version}.b{len(buckets)}",
                                      version, cur_bytes, cur,
                                      sharded=sharded))
                cur, cur_bytes = [], 0
            cur.append(entry)
            cur_bytes += nb
        if cur:
            buckets.append(Bucket(f"v{version}.b{len(buckets)}",
                                  version, cur_bytes, cur,
                                  sharded=sharded))
        return buckets

    def bucketize(self, leaves: List[np.ndarray],
                  version: int) -> List[Bucket]:
        """Split a flat list of arrays into ~bucket_bytes buckets."""
        return self._pack(list(enumerate(leaves)), version, sharded=False)

    def bucketize_sharded(self, leaves: List[np.ndarray], version: int,
                          n_shards: int,
                          chunk_dims: List[Optional[int]]) -> List[Bucket]:
        """Split leaves into per-shard chunks first, THEN into buckets:
        leaf ``i`` with ``chunk_dims[i] = d`` is split into ``n_shards``
        equal chunks along dim ``d`` (the dim an n-way engine group
        shards over its "model" axis — ``sharding.model_axis_dims``);
        ``chunk_dims[i] = None`` leaves replicate and travel whole. An
        engine pulling version v then reads exactly the chunks its
        devices need (``InferenceEngine.update_from_chunks``) instead of
        a monolithic per-leaf array."""
        entries: List[Tuple] = []
        for i, leaf in enumerate(leaves):
            d = chunk_dims[i] if i < len(chunk_dims) else None
            arr = np.asarray(leaf)
            if d is None or arr.shape[d] % n_shards != 0:
                entries.append((i, 0, 1, None, arr))
            else:
                for j, part in enumerate(np.split(arr, n_shards, axis=d)):
                    entries.append((i, j, n_shards, d,
                                    np.ascontiguousarray(part)))
        return self._pack(entries, version, sharded=True)

    def publish(self, buckets: List[Bucket]):
        """Training side: write-once publication of a new version."""
        if not buckets:
            return
        version = buckets[0].version
        with self._lock:
            self._buckets[version] = list(buckets)
            self._latest = max(self._latest, version)
            # retain only the two most recent versions (bounded store)
            for v in [v for v in self._buckets if v < self._latest - 1]:
                del self._buckets[v]
            self.log.pushes += 1

    def publish_sizes(self, version: int, total_bytes: int):
        """Sim mode: publish version metadata without payloads."""
        n = max(1, int(np.ceil(total_bytes / self.bucket_bytes)))
        per = total_bytes // n
        self.publish([Bucket(f"v{version}.b{i}", version, per, None)
                      for i in range(n)])

    def pull_latest(self) -> Optional[List[Bucket]]:
        """Inference side: fetch the newest complete version's buckets."""
        with self._lock:
            if self._latest < 0:
                return None
            self.log.pulls += 1
            return list(self._buckets[self._latest])

    def version_bytes(self, version: Optional[int] = None) -> int:
        with self._lock:
            v = self._latest if version is None else version
            return sum(b.nbytes for b in self._buckets.get(v, []))


def flatten_params(params) -> List[np.ndarray]:
    import jax
    return [np.asarray(x) for x in jax.tree.leaves(params)]


def unflatten_like(params, leaves: List[np.ndarray]):
    import jax
    treedef = jax.tree.structure(params)
    return jax.tree.unflatten(treedef, leaves)


def push_params(store: MooncakeStore, params, version: int) -> int:
    """Live-mode publication of real weights. Returns bytes pushed."""
    leaves = flatten_params(params)
    buckets = store.bucketize(leaves, version)
    store.publish(buckets)
    return sum(b.nbytes for b in buckets)


def pull_params(store: MooncakeStore, like) -> Optional[Tuple[Any, int]]:
    """Live-mode pull: reassemble the latest version into ``like``'s
    structure. Returns (params, version) or None."""
    buckets = store.pull_latest()
    if not buckets:
        return None
    if any(b.sharded for b in buckets):
        raise RuntimeError(
            "store holds a sharded version; pull with pull_param_chunks "
            "(engines assemble shards via update_from_chunks)")
    import jax
    n_leaves = len(jax.tree.leaves(like))
    leaves: List[Optional[np.ndarray]] = [None] * n_leaves
    for b in buckets:
        for i, arr in b.payload:
            leaves[i] = arr
    if any(x is None for x in leaves):
        raise RuntimeError("incomplete bucket set")
    return unflatten_like(like, leaves), buckets[0].version


def push_params_sharded(store: MooncakeStore, params, version: int,
                        n_shards: int,
                        chunk_dims: List[Optional[int]]) -> int:
    """Live-mode publication of real weights as PER-SHARD chunks (§6.3
    data movement at TP scale: the trainer pushes once; each engine
    device pulls only its chunks). Returns bytes pushed."""
    leaves = flatten_params(params)
    buckets = store.bucketize_sharded(leaves, version, n_shards,
                                      chunk_dims)
    store.publish(buckets)
    return sum(b.nbytes for b in buckets)


def pull_param_chunks(store: MooncakeStore, like
                      ) -> Optional[Tuple[List[Tuple], int]]:
    """Live-mode pull of the latest version in CHUNK form: one
    ``(dim, [parts in shard order])`` entry per leaf of ``like`` —
    the input format of ``InferenceEngine.update_from_chunks``. Plain
    (unsharded) buckets degrade to single-part entries, so a mixed plane
    (e.g. an FT restore republishing a dense snapshot) still pulls
    through the one code path. Returns (chunks, version) or None."""
    buckets = store.pull_latest()
    if not buckets:
        return None
    import jax
    n_leaves = len(jax.tree.leaves(like))
    dims: List[Optional[int]] = [None] * n_leaves
    parts: List[Dict[int, np.ndarray]] = [dict() for _ in range(n_leaves)]
    counts = [1] * n_leaves
    for b in buckets:
        for entry in b.payload:
            if b.sharded:
                i, j, n, d, arr = entry
                dims[i] = d
                counts[i] = n
                parts[i][j] = arr
            else:
                i, arr = entry
                parts[i][0] = arr
    chunks: List[Tuple] = []
    for i in range(n_leaves):
        if len(parts[i]) != counts[i]:
            raise RuntimeError(
                f"incomplete bucket set: leaf {i} has {len(parts[i])} of "
                f"{counts[i]} shards")
        chunks.append((dims[i], [parts[i][j] for j in range(counts[i])]))
    return chunks, buckets[0].version

"""Serverless platform (R3): elastic, scale-to-zero execution of stateless
functions (reward computation). Live mode executes real Python callables on
a thread pool with autoscaling bookkeeping; sim mode exposes the same
latency model in virtual time (cold start + execution + payload I/O).

The paper's measured serverless reward I/O tax: payloads up to 5.2 MB,
per-call overhead max 2.1 s / mean 0.01 s (§7.5) — defaults reproduce that.
"""
from __future__ import annotations

import random
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Optional


class ServerlessError(RuntimeError):
    """An invocation was lost (container eviction, network partition, an
    injected fault). Callers holding the payload may retry — the live
    runner's reward drain does (``LiveRLRunner._drain_rewards``)."""


@dataclass
class ServerlessStats:
    invocations: int = 0
    cold_starts: int = 0
    total_exec_s: float = 0.0
    total_io_s: float = 0.0
    max_io_s: float = 0.0
    payload_bytes: int = 0
    peak_instances: int = 0
    failures: int = 0              # lost invocations (incl. injected)


@dataclass
class ServerlessConfig:
    cold_start_s: float = 1.5          # container spin-up
    keep_alive_s: float = 60.0         # instance reuse window
    io_mean_s: float = 0.01            # paper §7.5: mean 0.01 s/call
    io_max_s: float = 2.1              # paper §7.5: max 2.1 s/call
    io_tail_prob: float = 0.002        # probability of a tail I/O event
    max_concurrency: int = 1024
    # live mode: actually sleep the sampled per-call I/O tax instead of
    # only accounting it — makes blocking vs async reward scoring visible
    # in wall time (benchmarks/async_overlap.py)
    sleep_io: bool = False


def payload_nbytes(obj) -> int:
    """Approximate serialized size of an invocation payload (the paper
    measures up to 5.2 MB per reward call)."""
    if isinstance(obj, (bytes, bytearray)):
        return len(obj)
    if isinstance(obj, str):
        return len(obj.encode("utf-8", "ignore"))
    if obj is None or isinstance(obj, bool):
        return 1
    if isinstance(obj, (int, float)):
        return 8
    if isinstance(obj, dict):
        return sum(payload_nbytes(k) + payload_nbytes(v)
                   for k, v in obj.items())
    if isinstance(obj, (list, tuple, set, frozenset)):
        return sum(payload_nbytes(x) for x in obj)
    nbytes = getattr(obj, "nbytes", None)   # numpy / jax arrays
    if isinstance(nbytes, (int, float)):
        return int(nbytes)
    return 64


class ServerlessPlatform:
    """Registry + executor for serverless endpoints ("fc://...").

    Thread-safe: ``invoke`` / ``invoke_async`` may be called concurrently
    from the rollout worker, the trainer, and pool threads. All shared
    mutable state (the RNG, the warm map, and every ``stats`` field) is
    guarded by ``_lock``; ``max_concurrency`` is enforced by blocking
    admission on the same lock's condition variable.
    """

    def __init__(self, config: Optional[ServerlessConfig] = None,
                 seed: int = 0):
        self.cfg = config or ServerlessConfig()
        self._fns: Dict[str, Callable] = {}        # guarded by: _lock
        self._pool = ThreadPoolExecutor(max_workers=32)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        # url -> last-used wall time
        self._warm: Dict[str, float] = {}          # guarded by: _lock
        self._active = 0                           # guarded by: _lock
        self._rng = random.Random(seed)            # guarded by: _lock
        # url -> invocations to fail
        self._poison: Dict[str, int] = {}          # guarded by: _lock
        self.stats = ServerlessStats()             # guarded by: _lock
        # obs hook: called OUTSIDE all locks with (url, wall_seconds)
        # after each live invocation completes (success or failure)
        self.on_invoke: Optional[Callable[[str, float], None]] = None

    def snapshot(self) -> ServerlessStats:
        """Immutable copy of the counters plus the instantaneous
        in-flight count — the scrape surface (``self.stats`` itself is
        the live, lock-guarded object; never hand it out)."""
        with self._lock:
            snap = replace(self.stats)
            snap.peak_instances = max(snap.peak_instances, self._active)
            return snap

    @property
    def inflight(self) -> int:
        with self._lock:
            return self._active

    def deploy(self, url: str, fn: Callable):
        """Register a function behind a serverless URL."""
        if not url.startswith("fc://"):
            raise ValueError("serverless urls use the fc:// scheme")
        # under the lock: a deploy racing an invoke's registry lookup is
        # a real hazard once rollout-as-a-service endpoints deploy late
        with self._lock:
            self._fns[url] = fn

    def fail_next(self, url: str, n: int = 1):
        """Failure injection (paper §8): the next ``n`` invocations of
        ``url`` are lost — they raise :class:`ServerlessError` instead of
        executing. Models a container eviction mid-call."""
        with self._lock:
            self._poison[url] = self._poison.get(url, 0) + n

    # ------------------------------------------------------------------
    def sample_io_s(self) -> float:
        with self._lock:
            if self._rng.random() < self.cfg.io_tail_prob:
                return self._rng.uniform(0.5, self.cfg.io_max_s)
            return max(0.0, self._rng.gauss(self.cfg.io_mean_s,
                                            self.cfg.io_mean_s / 2))

    def is_cold(self, url: str, now: Optional[float] = None) -> bool:   # requires: _lock
        now = time.monotonic() if now is None else now
        last = self._warm.get(url)
        return last is None or (now - last) > self.cfg.keep_alive_s

    def _touch(self, url: str, now: Optional[float] = None):   # requires: _lock
        self._warm[url] = time.monotonic() if now is None else now

    # ------------------------------------------------------------------
    # live execution
    # ------------------------------------------------------------------
    def invoke(self, url: str, *args, **kwargs) -> Any:
        """Synchronous invocation (what a Worker's redirected attribute
        calls). Cold starts and I/O tax are accounted but not slept in live
        mode (tiny-model runs should stay fast); sim mode models them in
        virtual time via ``sim_latency``. Blocks while ``max_concurrency``
        instances are already executing."""
        with self._lock:
            fn = self._fns.get(url)
        if fn is None:
            raise KeyError(f"no function deployed at {url}")
        # O(payload) walk outside the lock: MB-scale reward payloads must
        # not serialize every concurrent invocation's admission
        nbytes = payload_nbytes(args) + payload_nbytes(kwargs)
        with self._cv:
            if self._poison.get(url, 0) > 0:
                self._poison[url] -= 1
                self.stats.failures += 1
                raise ServerlessError(f"invocation of {url} lost "
                                      "(injected fault)")
            while self._active >= self.cfg.max_concurrency:
                self._cv.wait()
            self.stats.invocations += 1
            if self.is_cold(url):
                self.stats.cold_starts += 1
            self._touch(url)
            self._active += 1
            self.stats.peak_instances = max(self.stats.peak_instances,
                                            self._active)
            self.stats.payload_bytes += nbytes
        t0 = time.monotonic()
        try:
            io = self.sample_io_s()
            if self.cfg.sleep_io:
                time.sleep(io)
            result = fn(*args, **kwargs)
            return result
        finally:
            dt = time.monotonic() - t0
            with self._cv:
                self._active -= 1
                self.stats.total_exec_s += dt
                self.stats.total_io_s += io
                self.stats.max_io_s = max(self.stats.max_io_s, io)
                self._cv.notify()
            hook = self.on_invoke
            if hook is not None:
                hook(url, dt)

    def invoke_async(self, url: str, *args, **kwargs) -> Future:
        return self._pool.submit(self.invoke, url, *args, **kwargs)

    # ------------------------------------------------------------------
    # sim-mode latency model
    # ------------------------------------------------------------------
    def sim_latency(self, url: str, exec_s: float, payload_bytes: int = 0,
                    now: float = 0.0) -> float:
        """Virtual-time latency of one invocation (used by the simulator)."""
        io = self.sample_io_s()
        with self._lock:
            self.stats.invocations += 1
            self.stats.payload_bytes += payload_bytes
            cold = self.is_cold(url, now)
            if cold:
                self.stats.cold_starts += 1
            self._touch(url, now)
            self.stats.total_io_s += io
            self.stats.max_io_s = max(self.stats.max_io_s, io)
            self.stats.total_exec_s += exec_s
        return (self.cfg.cold_start_s if cold else 0.0) + io + exec_s

"""Hardware taxonomy + analytic performance model.

GPU entries use the paper's Table 2 (H800/H20) so the benchmarks can
validate against the paper's measured ratios; TPU entries are the
deployment target per DESIGN.md §2. The performance model is a two-phase
(prefill=compute-bound, decode=bandwidth-bound) latency estimate with
efficiency factors calibrated once in ``benchmarks/calibration.py`` to
reproduce the paper's Fig. 4 ratios (H800 0.53x prefill-heavy; H20
0.49-0.79x decode-heavy).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig

# Role -> hardware-class affinity (paper §5.2 / Table 2 / Fig. 4):
# compute-bound prefill belongs on compute-class chips (H800/TPUv5p),
# bandwidth-bound decode on bandwidth-class chips (H20/TPUv5e). Colocated
# engines serve both phases; the prefill phase is the one that saturates
# first on a mismatched chip, so they default to compute-class.
ROLE_CLASS_AFFINITY: Dict[str, str] = {
    "prefill": "compute",
    "decode": "bandwidth",
    "colocated": "compute",
    "train": "compute",
    "generate": "bandwidth",
    "environment": "host",
    "reward": "elastic",
}


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    kind: str                 # "gpu" | "tpu" | "cpu" | "serverless"
    klass: str                # "compute" | "bandwidth" | "host" | "elastic"
    tflops_bf16: float        # peak TFLOP/s per device
    hbm_gb: float
    hbm_bw_gbs: float         # GB/s
    link_bw_gbs: float        # interconnect per device
    norm_cost: float          # normalized $ cost (paper Table 2)


# --- paper Table 2 ---------------------------------------------------------
H800 = HardwareSpec("H800", "gpu", "compute", 989.5, 80, 3350, 400, 2.85)
H20 = HardwareSpec("H20", "gpu", "bandwidth", 148.0, 96, 4000, 900, 1.00)
# --- TPU deployment target (assignment roofline constants for v5e) ---------
TPU_V5E = HardwareSpec("TPUv5e", "tpu", "bandwidth", 197.0, 16, 819, 50, 0.7)
TPU_V5P = HardwareSpec("TPUv5p", "tpu", "compute", 459.0, 95, 2765, 100, 2.2)
CPU_HOST = HardwareSpec("CPU", "cpu", "host", 0.0, 0, 0, 10, 0.05)
SERVERLESS = HardwareSpec("Serverless", "serverless", "elastic",
                          148.0, 96, 4000, 10, 0.0)

REGISTRY: Dict[str, HardwareSpec] = {
    h.name: h for h in [H800, H20, TPU_V5E, TPU_V5P, CPU_HOST, SERVERLESS]
}


# --- efficiency factors (calibrated against paper Fig. 4; see
#     benchmarks/calibration.py for the fit) --------------------------------
@dataclass
class PerfModel:
    prefill_mfu: float = 0.50         # fraction of peak FLOPs in prefill
    decode_bw_eff: float = 0.55       # fraction of peak HBM bw in decode
    decode_overhead_s: float = 0.001  # per-token scheduling overhead
    step_overhead_s: float = 0.3      # per generation request overhead

    def prefill_time(self, cfg: ModelConfig, prompt_tokens: int,
                     hw: HardwareSpec, tp_degree: int,
                     prefix_cached_frac: float = 0.0) -> float:
        """Compute-bound, per TP serving group: 2*N_active*T/(tp*peak*mfu)."""
        flops = 2.0 * cfg.active_param_count() * prompt_tokens \
            * (1.0 - prefix_cached_frac)
        return flops / max(tp_degree * hw.tflops_bf16 * 1e12
                           * self.prefill_mfu, 1.0)

    def kv_bytes_per_token(self, cfg: ModelConfig) -> float:
        if cfg.attention_free:
            return 0.0
        n_attn = sum(m == "attn" for m, _ in cfg.block_pattern) \
            * cfg.num_periods
        return 2.0 * cfg.num_kv_heads * cfg.head_dim * n_attn * 2.0

    def decode_time(self, cfg: ModelConfig, new_tokens: int,
                    hw: HardwareSpec, tp_degree: int,
                    context: int = 8192, concurrency: int = 32) -> float:
        """Bandwidth-bound, per TP serving group. Per engine step the group
        reads the weights ONCE for all ``concurrency`` streams plus each
        stream's KV cache (context * kv_bytes); at long contexts the KV
        traffic dominates — which is exactly why decode-heavy tasks prefer
        bandwidth-optimized chips (R1)."""
        weights = 2.0 * cfg.active_param_count()
        kv = context * self.kv_bytes_per_token(cfg)
        bw = tp_degree * hw.hbm_bw_gbs * 1e9 * self.decode_bw_eff
        # one engine step serves all streams: weights once + every stream's
        # KV cache; each stream advances one token per step
        t_step = (weights + max(concurrency, 1) * kv) / max(bw, 1.0)
        return new_tokens * (t_step + self.decode_overhead_s)

    def train_step_time(self, cfg: ModelConfig, batch_tokens: int,
                        hw: HardwareSpec, n_devices: int,
                        mfu: float = 0.35) -> float:
        flops = 6.0 * cfg.active_param_count() * batch_tokens
        return flops / max(n_devices * hw.tflops_bf16 * 1e12 * mfu, 1.0)

    def weight_bytes(self, cfg: ModelConfig) -> float:
        return 2.0 * cfg.param_count()

    def transfer_time(self, nbytes: float, bw_gbs: float,
                      latency_s: float = 0.005) -> float:
        return latency_s + nbytes / (bw_gbs * 1e9)

    # -- placement pricing (§5.2: the PerfModel as the placement layer) ----
    def role_latency(self, cfg: ModelConfig, role: str, hw: HardwareSpec,
                     tp_degree: int = 1, *, prompt_tokens: int = 512,
                     new_tokens: int = 256, concurrency: int = 32) -> float:
        """Modeled per-request latency of one serving group in ``role`` on
        ``hw``: the prefill phase for prefill-role, the decode loop for
        decode-role, and their sum for a colocated engine."""
        t_p = self.prefill_time(cfg, prompt_tokens, hw, tp_degree)
        t_d = self.decode_time(cfg, new_tokens, hw, tp_degree,
                               context=prompt_tokens + new_tokens,
                               concurrency=concurrency)
        return {"prefill": t_p, "decode": t_d}.get(role, t_p + t_d)

    def price_placement(self, cfg: ModelConfig, prefill_hw: HardwareSpec,
                        decode_hw: HardwareSpec, *, n_prefill: int = 1,
                        n_decode: int = 1, prompt_tokens: int = 4096,
                        new_tokens: int = 256,
                        concurrency: int = 32) -> Dict[str, float]:
        """Price a two-stage placement: request rate of the pipeline
        (bottleneck stage), its normalized dollar cost, and the
        cost-normalized throughput the paper's Table 2 ordering is stated
        in. A prefill group serves one request at a time; a decode group
        serves ``concurrency`` streams per engine step."""
        t_p = self.prefill_time(cfg, prompt_tokens, prefill_hw, 1)
        t_d = self.decode_time(cfg, new_tokens, decode_hw, 1,
                               context=prompt_tokens + new_tokens,
                               concurrency=concurrency)
        prefill_rate = n_prefill / max(t_p, 1e-12)
        decode_rate = n_decode * max(concurrency, 1) / max(t_d, 1e-12)
        rate = min(prefill_rate, decode_rate)
        cost = n_prefill * prefill_hw.norm_cost + n_decode * decode_hw.norm_cost
        return {
            "prefill_s": t_p, "decode_s": t_d,
            "prefill_rate_rps": prefill_rate, "decode_rate_rps": decode_rate,
            "rate_rps": rate, "norm_cost": cost,
            "tokens_per_s": rate * (prompt_tokens + new_tokens),
            "cost_norm_throughput": rate / max(cost, 1e-12),
        }


PERF = PerfModel()

"""Cluster-scale discrete-event simulation of the agentic RL pipeline.

Replays the RollArt control plane (trajectory-level rollout, GRPO group
structure, serverless reward, bounded-staleness async training, bucketized
weight sync) against modeled hardware in virtual time, at the paper's scale
(Qwen3-8B..32B, batch 512, 128 GPUs). Latency constants are calibrated from
the paper's own measurements (Table 2 specs, Table 3/4 transfer fits, §3
latency distributions); ``benchmarks/calibration.py`` validates the Fig. 4
hardware-affinity ratios.

Fidelity notes:
- decode is modeled per TP serving group (weights are read once per engine
  step for all concurrent streams), so pool throughput = slots / t_step;
- training batches require COMPLETE GRPO groups (group_size trajectories of
  the same prompt), which is what makes environment long tails gate the
  batch and gives redundant environment rollouts (Fig. 14b) their meaning;
- the staleness logic is the same SampleBuffer class used by the live
  runner, so the α-bound semantics have one implementation in both modes.

Modes: sync | sync_plus | one_off | areal | rollart   (§7.1 baselines)

The ``pd_disagg`` config here models §6.3 prefill/decode disaggregation in
virtual time (Table 5); its live data-plane counterpart is
``LLMProxy(pd_disagg=True)`` over prefill-/decode-role ``InferenceEngine``s
(see repro.core.proxy / repro.rl.engine, and benchmarks/pd_disagg_live.py
for the real-engine check of the Table-5 prediction).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import get_config
from repro.core.buffer import SampleBuffer
from repro.core.hardware import PERF, REGISTRY, HardwareSpec
from repro.core.serverless import ServerlessConfig, ServerlessPlatform
from repro.core.simclock import Resource, Simulator
from repro.data.pipeline import Trajectory
from repro.envs import ENV_CLASSES

# ---------------------------------------------------------------------------
# workload profiles (Table 1 + §8 characterization)
# ---------------------------------------------------------------------------


@dataclass
class TaskProfile:
    name: str
    turns: Tuple[int, int]             # uniform range
    obs_tokens: Tuple[float, float]    # mean, std per turn
    resp_tokens: Tuple[float, float]   # mean, std per turn
    kind: str                          # "prefill_heavy" | "decode_heavy"

    def sample_turns(self, rng):
        return rng.randint(*self.turns)

    def sample_obs(self, rng):
        return max(16, int(rng.gauss(*self.obs_tokens)))

    def sample_resp(self, rng):
        return max(16, int(rng.gauss(*self.resp_tokens)))


TASK_PROFILES: Dict[str, TaskProfile] = {
    "swe": TaskProfile("swe", (30, 50), (600, 200), (400, 150),
                       "prefill_heavy"),
    "webshop": TaskProfile("webshop", (5, 30), (300, 100), (200, 80),
                           "prefill_heavy"),
    "frozenlake": TaskProfile("frozenlake", (20, 60), (150, 50), (100, 40),
                              "prefill_heavy"),
    # decode-heavy tasks carry reasoning-model CoT lengths (§8: responses
    # reach 46k tokens; means in the 8-12k range)
    "math": TaskProfile("math", (1, 5), (120, 40), (8000, 3000),
                        "decode_heavy"),
    "game": TaskProfile("game", (1, 1), (80, 20), (12000, 4000),
                        "decode_heavy"),
}

# cross-cluster transfer constants fit from paper Tables 3/4
TCP_BW_GBS = 2.1          # effective TCP GB/s (Table 3 fit)
RDMA_BW_GBS = 11.5        # effective RDMA GB/s (Table 3 fit)
RDMA_LAT_S = 4.1          # RDMA setup (Table 3 fit)
MOONCAKE_PUSH_GBS = 0.46  # Table 4 fit: bucketized push over Ethernet
MOONCAKE_PULL_GBS = 2.5   # Table 4 fit: intra-cluster pull


def default_tp(model_name: str) -> int:
    """Rollout tensor-parallel degrees from paper §7.1 (1/2/4 for 8/14/32B)."""
    if "32b" in model_name or "30b" in model_name:
        return 4
    if "14b" in model_name:
        return 2
    return 1


@dataclass
class GenPool:
    hw: HardwareSpec
    n_devices: int
    tp_degree: int = 4
    weight_bytes: float = 0.0
    kv_bytes_per_stream: float = 2.0e9   # avg-context KV footprint
    max_slots_per_group: int = 24

    def __post_init__(self):
        self.resource: Optional[Resource] = None

    @property
    def n_groups(self) -> int:
        return max(1, self.n_devices // self.tp_degree)

    @property
    def slots_per_group(self) -> int:
        """HBM-derived concurrency: (group HBM - weights) / KV per stream.
        This is where bandwidth-optimized chips' larger HBM (H20: 96 GB)
        buys extra batch slots."""
        free = (self.tp_degree * self.hw.hbm_gb * 1e9 * 0.9
                - self.weight_bytes)
        return int(max(1, min(self.max_slots_per_group,
                              free / self.kv_bytes_per_stream)))

    def capacity(self) -> int:
        return self.n_groups * self.slots_per_group


@dataclass
class SimRLConfig:
    model: str = "qwen3-32b"
    tasks: Tuple[str, ...] = ("swe", "math", "frozenlake", "webshop", "game")
    batch_size: int = 512
    group_size: int = 8
    alpha: int = 1
    mode: str = "rollart"
    num_steps: int = 8
    seed: int = 0
    # resources
    train_hw: str = "H800"
    train_devices: int = 32
    gen_pools: Tuple[Tuple[str, int], ...] = (("H800", 64), ("H20", 32))
    tp_degree: int = 0                 # 0 -> default_tp(model)
    hw_affinity: Optional[Dict[str, str]] = None   # task -> pool (R1)
    reward_serverless: bool = True
    reward_gpu_devices: int = 4
    reward_exec_s: Tuple[float, float] = (0.5, 2.5)
    # environment latency
    env_latency_scale: float = 1.0
    env_gauss_override: Optional[Tuple[float, float]] = None  # (mu, sigma)
    # redundancy: groups launched / groups needed (Fig. 14b)
    redundancy: float = 1.0
    # concurrent environment budget, as a multiple of batch_size
    # (environments are real CPU pods, not free; buffer growth is O(alpha*E))
    max_env_factor: float = 2.5
    # weight sync
    async_weight_sync: bool = True
    train_mfu: float = 0.35
    prefix_cache: float = 0.8
    # PD disaggregation (§6.3)
    pd_disagg: bool = False
    pd_prefill_pool: str = "H800"
    pd_decode_pool: str = "H20"


@dataclass
class SimMetrics:
    step_times: List[float] = field(default_factory=list)
    tokens: List[int] = field(default_factory=list)
    rollout_s: List[float] = field(default_factory=list)
    train_s: List[float] = field(default_factory=list)
    gen_util: Dict[str, float] = field(default_factory=dict)
    reward_util: float = 0.0
    evicted: int = 0
    aborted: int = 0
    completed: int = 0
    failed: int = 0
    groups_completed: int = 0
    groups_dead: int = 0
    exposed_sync_s: List[float] = field(default_factory=list)
    push_s: float = 0.0
    pull_s: float = 0.0

    @property
    def avg_step_s(self) -> float:
        return sum(self.step_times) / max(len(self.step_times), 1)

    @property
    def throughput_tok_s(self) -> float:
        return sum(self.tokens) / max(sum(self.step_times), 1e-9)


class _SimBuffer(SampleBuffer):
    """SampleBuffer with a sim Event notification on put/version change."""

    def __init__(self, sim: Simulator, alpha: int):
        super().__init__(alpha=alpha)
        self.sim = sim
        self._notify = sim.event()

    def _wake(self):
        ev, self._notify = self._notify, self.sim.event()
        ev.trigger()

    def put(self, traj):
        super().put(traj)
        self._wake()

    def set_version(self, v):
        super().set_version(v)
        self._wake()

    def wait_event(self):
        return self._notify


class _Group:
    """GRPO group tracker: a batch entry is a COMPLETE group."""

    __slots__ = ("gid", "task", "need", "done", "dead", "start_version")

    def __init__(self, gid, task, need, start_version):
        self.gid = gid
        self.task = task
        self.need = need
        self.done: List[Trajectory] = []
        self.dead = False
        self.start_version = start_version


class SimRL:
    def __init__(self, cfg: SimRLConfig):
        self.cfg = cfg
        self.sim = Simulator()
        self.rng = random.Random(cfg.seed)
        self.model = get_config(cfg.model)
        self.tp = cfg.tp_degree or default_tp(cfg.model)
        self.buffer = _SimBuffer(self.sim, cfg.alpha)
        self.metrics = SimMetrics()
        self.version = 0
        self.traj_tokens: Dict[str, int] = {}
        self._traj_counter = 0
        self._group_counter = 0
        self._live: Dict[str, dict] = {}        # traj id -> state
        self._groups: Dict[str, _Group] = {}
        self.pools: Dict[str, GenPool] = {}
        kv_per_tok = (2 * self.model.num_kv_heads * self.model.head_dim
                      * self.model.num_layers * 2)
        avg_ctx = 8192.0
        for name, n in cfg.gen_pools:
            p = GenPool(REGISTRY[name], n, tp_degree=self.tp,
                        weight_bytes=PERF.weight_bytes(self.model),
                        kv_bytes_per_stream=kv_per_tok * avg_ctx)
            p.resource = Resource(self.sim, p.capacity(), name)
            self.pools[name] = p
        self.affinity = dict(cfg.hw_affinity or {})
        self.affinity.setdefault("default", cfg.gen_pools[0][0])
        self.serverless = ServerlessPlatform(
            ServerlessConfig(cold_start_s=1.5), seed=cfg.seed)
        self.reward_gpu = Resource(self.sim, cfg.reward_gpu_devices * 2,
                                   "reward_gpu")
        self._train_tokens = 0
        self._done = False

    # ------------------------------------------------------------------
    # timing models
    # ------------------------------------------------------------------
    def _pool_for(self, task: str) -> GenPool:
        """Affinity routing with the Cluster's fallback semantics: prefer the
        task's pool, but redirect to a compatible pool when the preferred one
        is saturated (forward progress under transient contention, §5.3)."""
        name = self.affinity.get(task, self.affinity["default"])
        pool = self.pools.get(name, next(iter(self.pools.values())))
        if pool.resource is not None and \
                pool.resource.in_use >= pool.capacity():
            alts = sorted(self.pools.values(),
                          key=lambda p: p.resource.in_use / p.capacity())
            return alts[0]
        return pool

    def _gen_time(self, pool: GenPool, new_ctx: int, resp: int,
                  context: int) -> float:
        if self.cfg.pd_disagg:
            pp = self.pools[self.cfg.pd_prefill_pool]
            dp = self.pools[self.cfg.pd_decode_pool]
            tp_ = PERF.prefill_time(self.model, new_ctx, pp.hw, pp.tp_degree,
                                    prefix_cached_frac=0.0)
            conc = max(1, dp.resource.in_use // dp.n_groups)
            td = PERF.decode_time(self.model, resp, dp.hw, dp.tp_degree,
                                  context=context, concurrency=conc)
            tkv = PERF.transfer_time(new_ctx * 2 * self.model.d_model, 25.0)
            return tp_ + td + tkv
        tp_ = PERF.prefill_time(self.model, new_ctx, pool.hw, pool.tp_degree,
                                prefix_cached_frac=0.0)
        # concurrency = live occupancy per group: during the drain phase of
        # a phased iteration the batch empties and stragglers decode faster
        conc = max(1, pool.resource.in_use // pool.n_groups)
        td = PERF.decode_time(self.model, resp, pool.hw, pool.tp_degree,
                              context=context, concurrency=conc)
        return tp_ + td

    def _env_latency(self, profile, which: str) -> Tuple[float, bool]:
        cfg = self.cfg
        if cfg.env_gauss_override is not None:
            mu, sigma = cfg.env_gauss_override
            return max(0.05, self.rng.gauss(mu, sigma)), False
        lat = ENV_CLASSES[profile.name].LATENCY
        t, failed = (lat.sample_reset(self.rng) if which == "reset"
                     else lat.sample_step(self.rng))
        return t * cfg.env_latency_scale, failed

    def _train_time(self, batch) -> float:
        tokens = sum(self.traj_tokens.get(t.traj_id, 0) for t in batch)
        self._train_tokens = tokens
        return PERF.train_step_time(self.model, tokens,
                                    REGISTRY[self.cfg.train_hw],
                                    self.cfg.train_devices,
                                    mfu=self.cfg.train_mfu)

    def _weight_sync_times(self) -> Tuple[float, float]:
        gb = PERF.weight_bytes(self.model) / 1e9
        return gb / MOONCAKE_PUSH_GBS, gb / MOONCAKE_PULL_GBS

    # ------------------------------------------------------------------
    # group lifecycle
    # ------------------------------------------------------------------
    def spawn_group(self, task: Optional[str] = None,
                    batched_env: bool = False) -> _Group:
        task = task or self.rng.choice(self.cfg.tasks)
        gid = f"g{self._group_counter}"
        self._group_counter += 1
        grp = _Group(gid, task, self.cfg.group_size, self.version)
        self._groups[gid] = grp
        for _ in range(self.cfg.group_size):
            self.sim.process(
                self._trajectory_proc(grp, batched_env=batched_env),
                name="traj")
        return grp

    def _group_member_done(self, grp: _Group, traj: Optional[Trajectory]):
        if grp.dead:
            return
        if traj is None:                     # member failed or aborted
            grp.dead = True
            self.metrics.groups_dead += 1
            del self._groups[grp.gid]
            self.buffer._wake()              # waiters may need to respawn
            return
        grp.done.append(traj)
        if len(grp.done) == grp.need:
            self.metrics.groups_completed += 1
            del self._groups[grp.gid]
            for t in grp.done:
                self.buffer.put(t)

    def _trajectory_proc(self, grp: _Group, batched_env: bool = False):
        cfg, sim = self.cfg, self.sim
        profile = TASK_PROFILES[grp.task]
        tid = f"t{self._traj_counter}"
        self._traj_counter += 1
        state = {"start_version": grp.start_version, "aborted": False,
                 "grp": grp}
        self._live[tid] = state

        def finish(traj):
            self._live.pop(tid, None)
            self._group_member_done(grp, traj)

        t_reset, failed = self._env_latency(profile, "reset")
        yield sim.timeout(t_reset)
        if failed or grp.dead:
            self.metrics.failed += int(failed)
            finish(None)
            return

        turns = profile.sample_turns(self.rng)
        context = profile.sample_obs(self.rng)
        total = context
        pool = self._pool_for(grp.task)
        for turn in range(turns):
            if state["aborted"] or grp.dead:
                self.metrics.aborted += 1
                finish(None)
                return
            resp = profile.sample_resp(self.rng)
            # with prefix caching only the last observation + cache misses
            # are prefetched on later turns
            new_ctx = context if turn == 0 else \
                max(64, int(context * (1 - cfg.prefix_cache)))
            yield from pool.resource.acquire()
            yield sim.timeout(self._gen_time(pool, new_ctx, resp, context))
            pool.resource.release()
            context += resp
            total += resp
            t_step, failed = self._env_latency(profile, "step")
            yield sim.timeout(t_step)
            if failed:
                self.metrics.failed += 1
                finish(None)
                return
            obs = profile.sample_obs(self.rng)
            context += obs
            total += obs

        # reward stage (R3)
        exec_s = self.rng.uniform(*cfg.reward_exec_s)
        if cfg.reward_serverless:
            t_r = self.serverless.sim_latency("fc://sim/reward", exec_s,
                                              payload_bytes=total * 4,
                                              now=sim.now)
            yield sim.timeout(t_r)
        else:
            yield from self.reward_gpu.acquire()
            yield sim.timeout(exec_s)
            self.reward_gpu.release()

        self.metrics.completed += 1
        traj = Trajectory(traj_id=tid, task=grp.task, tokens=[],
                          loss_mask=[], logprobs=[], reward=1.0,
                          group_id=grp.gid,
                          start_version=grp.start_version,
                          version=self.version)
        self.traj_tokens[tid] = total
        traj.meta["tokens"] = total
        finish(traj)

    # ------------------------------------------------------------------
    # batched-env iteration (the Sync baseline's rollout, Fig. 5b)
    # ------------------------------------------------------------------
    def _batched_iteration_proc(self, n_groups: int):
        sim, cfg = self.sim, self.cfg
        n = n_groups * cfg.group_size
        tasks = [self.rng.choice(cfg.tasks) for _ in range(n_groups)
                 for _ in range(cfg.group_size)]
        profiles = [TASK_PROFILES[t] for t in tasks]
        resets = []
        for p in profiles:
            t, failed = self._env_latency(p, "reset")
            if failed:                       # batch-wide retry (Fig. 3)
                t += self._env_latency(p, "reset")[0]
                self.metrics.failed += 1
            resets.append(t)
        yield sim.timeout(max(resets))
        turns = [p.sample_turns(self.rng) for p in profiles]
        ctx = [p.sample_obs(self.rng) for p in profiles]
        total = list(ctx)
        for turn in range(max(turns)):
            alive = [i for i in range(n) if turns[i] > turn]
            if not alive:
                break
            t_gen = 0.0
            for i in alive:
                pool = self._pool_for(tasks[i])
                resp = profiles[i].sample_resp(self.rng)
                new_ctx = ctx[i] if turn == 0 else \
                    int(ctx[i] * (1 - cfg.prefix_cache))
                t_gen = max(t_gen, self._gen_time(pool, new_ctx, resp,
                                                  ctx[i]))
                ctx[i] += resp
                total[i] += resp
            yield sim.timeout(t_gen)
            t_env = max(self._env_latency(profiles[i], "step")[0]
                        for i in alive)       # env barrier
            yield sim.timeout(t_env)
            for i in alive:
                obs = profiles[i].sample_obs(self.rng)
                ctx[i] += obs
                total[i] += obs
        # batched reward on dedicated GPUs, in concurrency-limited waves
        cap = max(1, self.reward_gpu.capacity)
        waves = (n + cap - 1) // cap
        exec_s = sum(max(self.rng.uniform(*cfg.reward_exec_s)
                         for _ in range(min(cap, n))) for _ in range(waves))
        yield sim.timeout(exec_s)
        for i in range(n):
            tid = f"t{self._traj_counter}"
            self._traj_counter += 1
            self.metrics.completed += 1
            traj = Trajectory(traj_id=tid, task=tasks[i], tokens=[],
                              loss_mask=[], logprobs=[], reward=1.0,
                              group_id=f"bg{i // cfg.group_size}",
                              start_version=self.version,
                              version=self.version)
            self.traj_tokens[tid] = total[i]
            self.buffer.put(traj)

    # ------------------------------------------------------------------
    # staleness + spawning (async modes)
    # ------------------------------------------------------------------
    def _enforce_staleness(self):
        if self.cfg.mode == "areal":
            return                           # start-only bound
        bound = self.version - self.cfg.alpha
        for st in self._live.values():
            if st["start_version"] < bound:
                st["aborted"] = True

    def _spawner_proc(self):
        """Keep the generation pools saturated: in continuous (areal/rollart)
        mode the batch arrives at the PRODUCTION RATE, so in-flight groups
        are sized to generation capacity, not to one batch (the paper's
        production deployment runs thousands of concurrent environments)."""
        cfg = self.cfg
        groups_needed = cfg.batch_size // cfg.group_size
        cap_groups = sum(p.capacity() for p in self.pools.values()) \
            // cfg.group_size
        env_groups = int(cfg.max_env_factor * groups_needed)
        target = int(max(groups_needed * max(1.0, cfg.redundancy) + 2,
                         min(cap_groups, env_groups)))
        while not self._done:
            pending_groups = len(self._groups) \
                + self.buffer.size() // cfg.group_size
            for _ in range(max(0, target - pending_groups)):
                self.spawn_group()
            yield self.sim.timeout(2.0)

    # ------------------------------------------------------------------
    # trainers
    # ------------------------------------------------------------------
    def _trainer_async_proc(self):
        """areal / rollart: continuous rollout + bounded-staleness training."""
        cfg, sim = self.cfg, self.sim
        for step in range(cfg.num_steps):
            t0 = sim.now
            while True:
                batch = self.buffer.try_get_batch(cfg.batch_size)
                if batch is not None:
                    break
                yield self.buffer.wait_event()
            rollout_done = sim.now
            t_train = self._train_time(batch)
            yield sim.timeout(t_train)
            self.version += 1
            self.buffer.set_version(self.version)
            self._enforce_staleness()
            push_s, pull_s = self._weight_sync_times()
            self.metrics.push_s += push_s
            self.metrics.pull_s += pull_s
            if cfg.async_weight_sync:
                # Mooncake: push overlaps rollout; only the tail of the pull
                # (buckets published after the final train micro-batches) is
                # exposed during suspend/resume (Table 4: 67-78% hidden)
                exposed = pull_s * 0.28
            else:
                exposed = push_s + pull_s
            self.metrics.exposed_sync_s.append(exposed)
            yield sim.timeout(exposed)
            self.metrics.step_times.append(sim.now - t0)
            self.metrics.rollout_s.append(rollout_done - t0)
            self.metrics.train_s.append(t_train)
            self.metrics.tokens.append(self._train_tokens)
        self._done = True

    def _trainer_phased_proc(self):
        """sync / sync_plus / one_off."""
        cfg, sim = self.cfg, self.sim
        one_off = cfg.mode == "one_off"
        groups_needed = cfg.batch_size // cfg.group_size
        prev_batch = None
        steps_recorded = 0
        while steps_recorded < cfg.num_steps:
            t0 = sim.now
            if cfg.mode == "sync":
                yield self.sim.process(
                    self._batched_iteration_proc(groups_needed))
                batch = self.buffer.try_get_batch(cfg.batch_size)
            else:
                # trajectory-level rollout for THIS iteration: all groups
                # must finish under the current weights (no cross-iteration
                # decoupling — the one-off/sync+ tail penalty)
                n_spawn = int(groups_needed * max(1.0, cfg.redundancy))
                for _ in range(n_spawn):
                    self.spawn_group()
                while True:
                    batch = self.buffer.try_get_batch(cfg.batch_size)
                    if batch is not None:
                        break
                    # replace dead groups so the iteration can complete
                    have = (len(self._groups)
                            + self.buffer.size() // cfg.group_size)
                    for _ in range(max(0, groups_needed - have)):
                        self.spawn_group()
                    yield self.buffer.wait_event()
                for st in self._live.values():
                    st["aborted"] = True      # cancel redundant leftovers
            rollout_done = sim.now

            train_batch = prev_batch if one_off else batch
            if one_off:
                prev_batch = batch
            exposed_train = 0.0
            push_s, pull_s = self._weight_sync_times()
            if train_batch is not None:
                t_train = self._train_time(train_batch)
                if one_off:
                    # training AND the weight push of the previous version
                    # overlap the rollout we just waited for; only the
                    # residual + the local pull block the boundary
                    exposed_train = max(0.0, t_train + push_s
                                        - (rollout_done - t0))
                    t_sync = pull_s
                else:
                    exposed_train = t_train
                    t_sync = push_s + pull_s
                yield sim.timeout(exposed_train)
                self.version += 1
                self.buffer.set_version(self.version)
            else:
                t_sync = 0.0
            self.metrics.exposed_sync_s.append(t_sync)
            yield sim.timeout(t_sync)
            if train_batch is not None:
                self.metrics.step_times.append(sim.now - t0)
                self.metrics.rollout_s.append(rollout_done - t0)
                self.metrics.train_s.append(exposed_train)
                self.metrics.tokens.append(self._train_tokens)
                steps_recorded += 1
        self._done = True

    # ------------------------------------------------------------------
    def run(self) -> SimMetrics:
        self._done = False
        if self.cfg.mode in ("rollart", "areal"):
            self.sim.process(self._spawner_proc(), name="spawner")
            self.sim.process(self._trainer_async_proc(), name="trainer")
        else:
            self.sim.process(self._trainer_phased_proc(), name="trainer")
        self.sim.run()
        for name, pool in self.pools.items():
            self.metrics.gen_util[name] = pool.resource.utilization()
        self.metrics.reward_util = self.reward_gpu.utilization()
        self.metrics.evicted = self.buffer.total_evicted
        return self.metrics


def run_sim(**kwargs) -> SimMetrics:
    return SimRL(SimRLConfig(**kwargs)).run()

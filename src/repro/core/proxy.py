"""LLMProxy (paper §6.1): the gateway between EnvManagers and inference
workers. Dispatches generation requests at per-trajectory granularity,
routing each request to the hardware class preferred for its task-domain
tag (R1), and forwards ADD/ABORT commands so trajectory admission or
cancellation never stalls ongoing generation. Also implements the
suspend/resume half of the weight-sync protocol (R4).

Prefill/decode disaggregation (§6.3, live counterpart of the simulator's
``pd_disagg`` config): with ``pd_disagg=True`` the proxy runs a two-stage
dispatch — each request's ADD is routed to a prefill-role engine on the
compute-bound pool (H800-class); when that engine emits the request's
:class:`~repro.rl.engine.KVHandoff` (prompt cache + first sampled token),
the proxy migrates it to the least-loaded decode-role engine on the
bandwidth-bound pool (H20-class), where the decode loop runs. ADD/ABORT
and suspend/update/resume semantics are preserved across the handoff: the
route table always points at the engine currently owning the request, and
an abort that races the migration is resolved at handoff time.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.rl.engine import (GenRequest, GenResult, InferenceEngine,
                             KVHandoff)


@dataclass
class EngineHandle:
    engine: InferenceEngine
    pool: str                   # hardware pool name ("H800"/"H20"/...)
    name: str = ""

    def load(self) -> int:
        return self.engine.num_active + self.engine.queue_len

    @property
    def role(self) -> str:
        return self.engine.role


class LLMProxy:
    def __init__(self, handles: List[EngineHandle],
                 hw_affinity: Optional[Dict[str, str]] = None,
                 pd_disagg: bool = False):
        """hw_affinity: task tag -> pool name, must include "default".

        With ``pd_disagg=True`` the handle list must contain at least one
        ``role="prefill"`` and one ``role="decode"`` engine (all built from
        the same model with the same ``max_len`` so cache slots are
        shape-compatible across the handoff).
        """
        if not handles:
            raise ValueError("LLMProxy needs at least one engine")
        self.handles = handles
        self.pd_disagg = pd_disagg
        self.prefill_handles = [h for h in handles if h.role == "prefill"]
        self.decode_handles = [h for h in handles if h.role == "decode"]
        if pd_disagg:
            if not self.prefill_handles or not self.decode_handles:
                raise ValueError("pd_disagg=True needs at least one "
                                 "prefill-role and one decode-role engine")
            lens = {h.engine.max_len for h in handles}
            if len(lens) != 1:
                raise ValueError(f"PD pools must share max_len, got {lens}")
            for h in self.prefill_handles:
                h.engine.on_handoff = self._make_handoff_hook(h)
            # prefill engines step first so a handoff produced this pump
            # is injected before the decode engines step
            self._pump_order = (self.prefill_handles + self.decode_handles
                                + [h for h in handles
                                   if h.role == "colocated"])
        else:
            self._pump_order = list(handles)
        default_pool = (self.prefill_handles[0].pool if pd_disagg
                        else handles[0].pool)
        self.hw_affinity = dict(hw_affinity or {"default": default_pool})
        self.hw_affinity.setdefault("default", default_pool)
        self._route: Dict[str, EngineHandle] = {}
        self._callbacks: Dict[str, Callable[[GenResult], None]] = {}
        self._abort_requested: set = set()
        self._lock = threading.Lock()
        self.suspended = False
        for h in handles:
            h.engine.on_finish = self._make_finish_hook(h)
        # stats
        self.requests = 0
        self.aborted = 0
        self.handoffs = 0
        self.routed_by_pool: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def _make_finish_hook(self, handle: EngineHandle):
        def hook(result: GenResult):
            with self._lock:
                cb = self._callbacks.pop(result.request_id, None)
                self._route.pop(result.request_id, None)
                self._abort_requested.discard(result.request_id)
            if cb:
                cb(result)
        return hook

    def _make_handoff_hook(self, src: EngineHandle):
        def hook(handoff: KVHandoff):
            rid = handoff.request.request_id
            with self._lock:
                if rid in self._abort_requested:
                    # abort raced the prefill: resolve it here instead of
                    # migrating a cancelled trajectory
                    cb = self._callbacks.pop(rid, None)
                    self._route.pop(rid, None)
                    self._abort_requested.discard(rid)
                    dst = None
                else:
                    dst = min(self.decode_handles, key=lambda h: h.load())
                    self._route[rid] = dst
                    # migrations are counted in `handoffs` (and per-engine
                    # handoffs_in), NOT routed_by_pool, so the latter keeps
                    # summing to `requests` in both modes
                    self.handoffs += 1
                    # enqueue while still holding the proxy lock: a
                    # concurrent abort() that observes route=dst must find
                    # its ABORT ordered after this INJECT in dst's queue
                    handoff.source = src.pool
                    dst.engine.inject(handoff)
            if dst is None and cb:
                cb(GenResult(
                    request_id=rid, tokens=list(handoff.new_tokens),
                    logprobs=list(handoff.logprobs),
                    finish_reason="aborted",
                    weight_version=src.engine.weight_version,
                    prefill_tokens=len(handoff.request.prompt),
                    decode_tokens=0))
        return hook

    def _select(self, tag: str) -> EngineHandle:
        cands = self.prefill_handles if self.pd_disagg else self.handles
        pool = self.hw_affinity.get(tag, self.hw_affinity["default"])
        matched = [h for h in cands if h.pool == pool]
        if not matched:
            matched = cands                  # fallback: forward progress
        return min(matched, key=lambda h: h.load())

    # ------------------------------------------------------------------
    def submit(self, req: GenRequest,
               callback: Callable[[GenResult], None]):
        """Trajectory-level dispatch (ADD command)."""
        h = self._select(req.tag)
        with self._lock:
            self._callbacks[req.request_id] = callback
            self._route[req.request_id] = h
            self.requests += 1
            self.routed_by_pool[h.pool] = \
                self.routed_by_pool.get(h.pool, 0) + 1
        h.engine.add_request(req)

    def abort(self, request_id: str):
        """ABORT command: cancel one trajectory's generation (wherever it
        currently lives — prefill engine, in migration, or decode engine).
        Unknown or already-finished ids are a no-op: they are not counted
        in ``aborted`` (nothing was cancelled) and, in PD mode, must not
        pin an ``_abort_requested`` entry forever."""
        with self._lock:
            h = self._route.get(request_id)
            if h is None:
                return
            self.aborted += 1
            if self.pd_disagg:
                self._abort_requested.add(request_id)
        h.engine.abort(request_id)

    # ------------------------------------------------------------------
    # weight-sync protocol hooks (steps (2)-(4))
    # ------------------------------------------------------------------
    def suspend(self):
        self.suspended = True
        for h in self.handles:
            h.engine.suspend()

    def resume(self):
        self.suspended = False
        for h in self.handles:
            h.engine.resume()

    def update_all(self, params, version: int, recompute_caches: bool = True):
        """Protocol steps (3) update + (5) KV-cache recomputation.
        Engines already at ``version`` no-op (see
        ``InferenceEngine.update_params``), so pulling an unchanged store
        version — always true on iteration 0 — costs nothing instead of
        re-prefilling every in-flight KV cache."""
        for h in self.handles:
            h.engine.update_params(params, version,
                                   recompute_caches=recompute_caches)

    # ------------------------------------------------------------------
    def pump(self) -> int:
        """Advance every engine by one step; returns active slot count.
        In PD mode prefill engines step before decode engines so a fresh
        handoff starts decoding in the same pump."""
        return sum(h.engine.step() for h in self._pump_order)

    @property
    def busy(self) -> bool:
        return any(h.engine.has_pending for h in self.handles)

    def stats(self) -> Dict:
        return {
            "requests": self.requests,
            "aborted": self.aborted,
            "pd_disagg": self.pd_disagg,
            "handoffs": self.handoffs,
            "routed_by_pool": dict(self.routed_by_pool),
            "engines": [
                {"pool": h.pool, "name": h.name, "role": h.role,
                 "steps": h.engine.steps,
                 "busy_steps": h.engine.busy_steps,
                 "prefill_tokens": h.engine.prefill_tokens,
                 "decode_tokens": h.engine.decode_tokens,
                 "handoffs_out": h.engine.handoffs_out,
                 "handoffs_in": h.engine.handoffs_in}
                for h in self.handles],
        }


def build_pd_proxy(model, params, *, prefill_pool: str = "H800",
                   decode_pool: str = "H20", n_prefill: int = 1,
                   n_decode: int = 1, max_slots: int = 8,
                   max_len: int = 512, seed: int = 0,
                   hw_affinity: Optional[Dict[str, str]] = None) -> LLMProxy:
    """Build a PD-disaggregated proxy: ``n_prefill`` prefill-role engines on
    the compute pool and ``n_decode`` decode-role engines on the bandwidth
    pool (the live analogue of the simulator's ``gen_pools`` +
    ``pd_disagg=True`` configuration)."""
    handles = []
    for i in range(n_prefill):
        eng = InferenceEngine(model, params, max_slots=max_slots,
                              max_len=max_len, seed=seed + i,
                              role="prefill")
        handles.append(EngineHandle(eng, prefill_pool, f"prefill-{i}"))
    for i in range(n_decode):
        eng = InferenceEngine(model, params, max_slots=max_slots,
                              max_len=max_len, seed=seed + 1000 + i,
                              role="decode")
        handles.append(EngineHandle(eng, decode_pool, f"decode-{i}"))
    return LLMProxy(handles, hw_affinity=hw_affinity, pd_disagg=True)

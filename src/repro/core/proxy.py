"""LLMProxy (paper §6.1): the gateway between EnvManagers and inference
workers. Dispatches generation requests at per-trajectory granularity,
routing each request to the hardware class preferred for its task-domain
tag (R1), and forwards ADD/ABORT commands so trajectory admission or
cancellation never stalls ongoing generation. Also implements the
suspend/resume half of the weight-sync protocol (R4).

Prefill/decode disaggregation (§6.3, live counterpart of the simulator's
``pd_disagg`` config): with ``pd_disagg=True`` the proxy runs a two-stage
dispatch — each request's ADD is routed to a prefill-role engine on the
compute-bound pool (H800-class); when that engine emits the request's
:class:`~repro.rl.engine.KVHandoff` (prompt cache + first sampled token),
the proxy migrates it to the least-loaded decode-role engine on the
bandwidth-bound pool (H20-class), where the decode loop runs. ADD/ABORT
and suspend/update/resume semantics are preserved across the handoff: the
route table always points at the engine currently owning the request, and
an abort that races the migration is resolved at handoff time.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.hardware import PERF, REGISTRY, ROLE_CLASS_AFFINITY
from repro.core.resource import Binding, ResourceManager
from repro.rl.engine import (GenRequest, GenResult, InferenceEngine,
                             KVHandoff)


@dataclass
class RequestLifecycle:
    """Per-request data-plane timestamps (``time.monotonic``), recorded
    by the proxy as the single source of truth for latency SLOs —
    submit (``submit()``), admit (first engine progress report),
    first-token (first report with generated tokens), finish (result
    delivery). ``token_times`` holds one ``(t, cum_tokens)`` entry per
    progress arrival that grew the stream, so per-token inter-token gaps
    are derivable without client-side chunk reconstruction."""
    request_id: str
    t_submit: float
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    tokens: int = 0
    finish_reason: str = ""
    token_times: List[Tuple[float, int]] = field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    @property
    def total(self) -> Optional[float]:
        if self.t_finish is None:
            return None
        return self.t_finish - self.t_submit

    def gaps(self) -> List[float]:
        """Per-token inter-token gaps: each progress arrival's elapsed
        time divided by the tokens it delivered (a K-token macro-step
        counts as K tokens over one arrival gap)."""
        out = []
        for (t0, n0), (t1, n1) in zip(self.token_times,
                                      self.token_times[1:]):
            if n1 > n0:
                out.append((t1 - t0) / (n1 - n0))
        return out

    def snapshot(self) -> "RequestLifecycle":
        return replace(self, token_times=list(self.token_times))


@dataclass
class EngineHandle:
    engine: InferenceEngine
    pool: str                   # hardware pool name ("H800"/"H20"/...)
    name: str = ""
    binding: Optional[Binding] = None   # device group held via the
    #                                     ResourceManager (None = unmanaged)

    def load(self) -> int:
        return self.engine.num_active + self.engine.queue_len

    @property
    def role(self) -> str:
        return self.engine.role


@dataclass
class RebalancerConfig:
    """Hysteresis band for the dynamic prefill<->decode role switch. The
    proxy tracks the decode/prefill queue-depth ratio each pump; only after
    ``window`` consecutive pumps outside [low, high] — and at least
    ``cooldown`` pumps since the last switch — does an engine flip roles,
    so transient bursts never thrash the placement.

    The ratio itself is dispatch-invariant (request-denominated), but the
    DYNAMICS are not: with ``steps_per_dispatch=K`` a decode engine drains
    up to K tokens per slot per pump, so a backlog that K=1 would let
    accumulate across ``window`` pumps may clear within one. That is the
    intended effect of the macro-step — more decode throughput means less
    need to switch — but deployments that want aggressive rebalancing on
    small workloads should lower ``high``/``window`` (or the engines'
    ``steps_per_dispatch``) accordingly."""
    high: float = 4.0        # decode backlog dominates: prefill -> decode
    low: float = 0.25        # prefill backlog dominates: decode -> prefill
    window: int = 4          # consecutive out-of-band pumps required
    cooldown: int = 16       # min pumps between two switches


class LLMProxy:
    def __init__(self, handles: List[EngineHandle],
                 hw_affinity: Optional[Dict[str, str]] = None,
                 pd_disagg: bool = False,
                 resource_manager: Optional[ResourceManager] = None,
                 rebalancer: Optional[RebalancerConfig] = None):
        """hw_affinity: task tag -> pool name, must include "default".

        With ``pd_disagg=True`` the handle list must contain at least one
        ``role="prefill"`` and one ``role="decode"`` engine (all built from
        the same model with the same ``max_len`` so cache slots are
        shape-compatible across the handoff). ``resource_manager`` lets the
        proxy release/re-bind device groups when the dynamic ``rebalancer``
        (PD mode only) switches an engine's role.
        """
        if not handles:
            raise ValueError("LLMProxy needs at least one engine")
        if rebalancer is not None and not pd_disagg:
            raise ValueError("the dynamic rebalancer switches prefill<->"
                             "decode roles and requires pd_disagg=True")
        self.handles = handles
        self.pd_disagg = pd_disagg
        self.rm = resource_manager
        self.rebalancer = rebalancer
        if pd_disagg:
            pre = [h for h in handles if h.role == "prefill"]
            dec = [h for h in handles if h.role == "decode"]
            if not pre or not dec:
                raise ValueError("pd_disagg=True needs at least one "
                                 "prefill-role and one decode-role engine")
            lens = {h.engine.max_len for h in handles}
            if len(lens) != 1:
                raise ValueError(f"PD pools must share max_len, got {lens}")
        self._refresh_roles()
        default_pool = (self.prefill_handles[0].pool if pd_disagg
                        else handles[0].pool)
        self.hw_affinity = dict(hw_affinity or {"default": default_pool})
        self.hw_affinity.setdefault("default", default_pool)
        self._route: Dict[str, EngineHandle] = {}        # guarded by: _lock
        self._callbacks: Dict[str, Callable[[GenResult], None]] = {}  # guarded by: _lock
        self._abort_requested: set = set()               # guarded by: _lock
        # streaming-token subscribers, keyed by request id (Rollout-as-a-
        # Service tier). Keyed on the REQUEST, not the engine, so a stream
        # follows its trajectory across PD handoffs, role switches, and
        # FT re-injection without re-subscribing.
        self._streams: Dict[str, Callable] = {}          # guarded by: _lock
        # per-request lifecycle timestamps (submit/admit/first-token/
        # finish): live records keyed by request id, finished records
        # moved to a bounded deque consumers drain
        # (drain_completed_lifecycles). Both mutated from submitter and
        # engine-hook threads, hence under the routing lock.
        self._lifecycle: Dict[str, RequestLifecycle] = {}  # guarded by: _lock
        self._completed_lifecycles = collections.deque(maxlen=8192)  # guarded by: _lock
        self._lock = threading.Lock()
        self.suspended = False      # bare flag, atomic under the GIL
        # SLO observation hooks (bare, single-assignment at wiring time):
        # called OUTSIDE every proxy/engine lock with one float —
        # on_ttft(seconds) at first-token, on_gap(seconds-per-token) on
        # each later progress arrival. Wired to the obs-plane histograms
        # by repro.obs.instrument.
        self.on_ttft: Optional[Callable[[float], None]] = None
        self.on_gap: Optional[Callable[[float], None]] = None
        for h in handles:
            h.engine.on_finish = self._make_finish_hook(h)
            h.engine.on_progress = self._make_progress_hook(h)
        # stats (engine hooks bump these from engine threads, so they
        # share the routing lock; rebalancer state below does not — it is
        # touched only by the single pump/control thread)
        self.requests = 0                                # guarded by: _lock
        self.aborted = 0                                 # guarded by: _lock
        self.handoffs = 0                                # guarded by: _lock
        self.recoveries = 0                              # guarded by: _lock
        self.routed_by_pool: Dict[str, int] = {}         # guarded by: _lock
        # rebalancer state/stats
        self.role_switches = 0
        self.switch_migrations = 0     # in-flight KV moved by role switches
        self.switch_log: List[Dict] = []
        self._pumps = 0
        self._last_switch_pump: Optional[int] = None
        self._streak_high = 0
        self._streak_low = 0

    def _refresh_roles(self):
        """Recompute role views after construction or a role switch: the
        prefill/decode handle lists, the pump order (prefill engines step
        first so a handoff produced this pump is injected before the decode
        engines step), and the handoff hooks of prefill engines."""
        self.prefill_handles = [h for h in self.handles
                                if h.role == "prefill"]
        self.decode_handles = [h for h in self.handles if h.role == "decode"]
        if self.pd_disagg:
            for h in self.prefill_handles:
                h.engine.on_handoff = self._make_handoff_hook(h)
            self._pump_order = (self.prefill_handles + self.decode_handles
                                + [h for h in self.handles
                                   if h.role == "colocated"])
        else:
            self._pump_order = list(self.handles)

    # ------------------------------------------------------------------
    def _make_finish_hook(self, handle: EngineHandle):
        def hook(result: GenResult):
            now = time.monotonic()
            with self._lock:
                cb = self._callbacks.pop(result.request_id, None)
                self._route.pop(result.request_id, None)
                self._abort_requested.discard(result.request_id)
                self._streams.pop(result.request_id, None)
                lc = self._lifecycle.pop(result.request_id, None)
                if lc is not None:
                    lc.t_finish = now
                    lc.finish_reason = result.finish_reason
                    self._completed_lifecycles.append(lc)
            if cb:
                cb(result)
        return hook

    def _make_progress_hook(self, handle: EngineHandle):
        """Engine streaming hook: runs under the emitting engine's
        ``_step_lock``, so the subscriber lookup takes ``_lock`` briefly
        and the subscriber itself (a TokenStream push — leaf lock only)
        is invoked OUTSIDE it, preserving the cross-class lock order
        documented in ``repro.rl.engine``.

        Also the lifecycle stamping point: the first progress report is
        the admit stamp, the first report that GREW the stream is the
        first-token stamp. Cumulative delivery makes replays (PD
        handoff, KV recompute, FT re-injection) no-ops here too — a
        report that doesn't grow the stream stamps nothing. The SLO
        hooks fire outside the lock, like the stream subscriber."""
        def hook(rid: str, cum_tokens: List[int], cum_logprobs: List[float]):
            now = time.monotonic()
            ttft_obs = gap_obs = None
            with self._lock:
                fn = self._streams.get(rid)
                lc = self._lifecycle.get(rid)
                if lc is not None:
                    if lc.t_admit is None:
                        lc.t_admit = now
                    n = len(cum_tokens)
                    if n > lc.tokens:
                        if lc.t_first_token is None:
                            lc.t_first_token = now
                            ttft_obs = now - lc.t_submit
                        else:
                            t_prev, n_prev = lc.token_times[-1]
                            gap_obs = (now - t_prev) / (n - n_prev)
                        lc.token_times.append((now, n))
                        lc.tokens = n
            if ttft_obs is not None and self.on_ttft is not None:
                self.on_ttft(ttft_obs)
            if gap_obs is not None and self.on_gap is not None:
                self.on_gap(gap_obs)
            if fn is not None:
                fn(rid, cum_tokens, cum_logprobs)
        return hook

    def _route_handoff(self, handoff: KVHandoff, src_pool: str,
                       weight_version: int) -> bool:
        """Route a prefilled trajectory to the least-loaded decode engine,
        or resolve a raced abort instead of migrating a cancelled
        trajectory. Returns True if the handoff was injected. Shared by the
        prefill handoff hook and the role-switch migration path."""
        rid = handoff.request.request_id
        with self._lock:
            if rid in self._abort_requested:
                cb = self._callbacks.pop(rid, None)
                self._route.pop(rid, None)
                self._abort_requested.discard(rid)
                self._streams.pop(rid, None)
                dst = None
            else:
                dst = min(self.decode_handles, key=lambda h: h.load())
                self._route[rid] = dst
                # enqueue while still holding the proxy lock: a
                # concurrent abort() that observes route=dst must find
                # its ABORT ordered after this INJECT in dst's queue
                handoff.source = src_pool
                dst.engine.inject(handoff)
        if dst is None and cb:
            cb(GenResult(
                request_id=rid, tokens=list(handoff.new_tokens),
                logprobs=list(handoff.logprobs),
                finish_reason="aborted",
                weight_version=weight_version,
                prefill_tokens=len(handoff.request.prompt),
                decode_tokens=0))
        return dst is not None

    def _make_handoff_hook(self, src: EngineHandle):
        def hook(handoff: KVHandoff):
            # migrations are counted in `handoffs` (and per-engine
            # handoffs_in), NOT routed_by_pool, so the latter keeps
            # summing to `requests` in both modes
            if self._route_handoff(handoff, src.pool,
                                   src.engine.weight_version):
                # under the lock: several prefill engines can emit
                # handoffs concurrently, and `+=` outside it loses counts
                with self._lock:
                    self.handoffs += 1
        return hook

    def _select(self, tag: str) -> EngineHandle:
        cands = self.prefill_handles if self.pd_disagg else self.handles
        pool = self.hw_affinity.get(tag, self.hw_affinity["default"])
        matched = [h for h in cands if h.pool == pool]
        if not matched:
            matched = cands                  # fallback: forward progress
        return min(matched, key=lambda h: h.load())

    # ------------------------------------------------------------------
    def submit(self, req: GenRequest,
               callback: Callable[[GenResult], None],
               on_tokens: Optional[Callable] = None):
        """Trajectory-level dispatch (ADD command). ``on_tokens``
        subscribes an incremental token stream — called with
        ``(request_id, cumulative_tokens, cumulative_logprobs)`` as the
        engines emit (see ``InferenceEngine.on_progress``)."""
        h = self._select(req.tag)
        now = time.monotonic()
        with self._lock:
            self._callbacks[req.request_id] = callback
            if on_tokens is not None:
                self._streams[req.request_id] = on_tokens
            self._route[req.request_id] = h
            self._lifecycle[req.request_id] = RequestLifecycle(
                request_id=req.request_id, t_submit=now)
            self.requests += 1
            self.routed_by_pool[h.pool] = \
                self.routed_by_pool.get(h.pool, 0) + 1
        h.engine.add_request(req)

    def abort(self, request_id: str):
        """ABORT command: cancel one trajectory's generation (wherever it
        currently lives — prefill engine, in migration, or decode engine).
        Unknown or already-finished ids are a no-op: they are not counted
        in ``aborted`` (nothing was cancelled) and, in PD mode, must not
        pin an ``_abort_requested`` entry forever."""
        with self._lock:
            h = self._route.get(request_id)
            if h is None:
                return
            self.aborted += 1
            if self.pd_disagg:
                self._abort_requested.add(request_id)
        h.engine.abort(request_id)

    # ------------------------------------------------------------------
    # fault tolerance (repro.ft): recovery dispatch + route inspection
    # ------------------------------------------------------------------
    def requests_on(self, handle: EngineHandle) -> List[str]:
        """Request ids currently routed to ``handle`` (in a slot, queued,
        or mid-migration toward it) — the blast radius of losing that
        engine."""
        with self._lock:
            return [rid for rid, h in self._route.items() if h is handle]

    def routed(self, request_id: str) -> bool:
        """True while the request is live somewhere in the plane."""
        with self._lock:
            return request_id in self._route

    def pending_abort_ids(self) -> set:
        """Request ids with an ABORT pending at the proxy level (the PD
        migration guard). Engine-queued aborts are NOT included — snapshot
        capture reads those from the per-engine command snapshots it takes
        anyway, so the full in-flight-abort set costs one pass instead of
        one engine-queue scan per request."""
        with self._lock:
            return set(self._abort_requested)

    def drop_routes(self, request_ids: List[str]):
        """Forget routes/callbacks for requests lost with a dead engine
        and not recoverable from any snapshot (the callers re-issue them
        as fresh requests, or fail the owning EnvManager)."""
        with self._lock:
            for rid in request_ids:
                self._route.pop(rid, None)
                self._callbacks.pop(rid, None)
                self._abort_requested.discard(rid)
                self._streams.pop(rid, None)
                self._lifecycle.pop(rid, None)

    def reinject(self, handoff: KVHandoff,
                 callback: Optional[Callable[[GenResult], None]] = None,
                 on_tokens: Optional[Callable] = None
                 ) -> EngineHandle:
        """Recovery dispatch: route a snapshotted KVHandoff to the
        least-loaded decode-capable engine and inject it. Re-registers the
        result callback (and the ``on_tokens`` stream subscriber) when
        given (cold restore into a fresh proxy); a live recovery keeps the
        existing registration. A weight-version
        mismatch between the snapshot and the target engine re-prefills
        the cache under the current weights at admission
        (``InferenceEngine._admit_handoff``), so restoring an old snapshot
        into a newer plane stays correct."""
        cands = self.decode_handles if self.pd_disagg else self.handles
        rid = handoff.request.request_id
        now = time.monotonic()
        with self._lock:
            dst = min(cands, key=lambda h: h.load())
            if callback is not None:
                self._callbacks[rid] = callback
            if on_tokens is not None:
                self._streams[rid] = on_tokens
            self._route[rid] = dst
            # a live recovery keeps the original lifecycle (latency is
            # measured from the user's submit); a cold restore into a
            # fresh proxy starts a new record at re-injection time
            if rid not in self._lifecycle:
                self._lifecycle[rid] = RequestLifecycle(
                    request_id=rid, t_submit=now,
                    tokens=len(handoff.new_tokens))
            self.recoveries += 1
            dst.engine.inject(handoff)
        return dst

    # ------------------------------------------------------------------
    # per-request lifecycle records (latency source of truth)
    # ------------------------------------------------------------------
    def lifecycle(self, request_id: str) -> Optional[RequestLifecycle]:
        """Snapshot copy of a LIVE request's lifecycle record (None once
        finished — drain the completed deque instead)."""
        with self._lock:
            lc = self._lifecycle.get(request_id)
            return None if lc is None else lc.snapshot()

    def drain_completed_lifecycles(self) -> List[RequestLifecycle]:
        """Pop every finished lifecycle record (each carries its final
        stamps; records are owned by the caller after the drain). The
        backing deque is bounded, so benchmarks that submit faster than
        they drain lose the OLDEST records, never block the hot path."""
        with self._lock:
            out = list(self._completed_lifecycles)
            self._completed_lifecycles.clear()
        return out

    # ------------------------------------------------------------------
    # weight-sync protocol hooks (steps (2)-(4))
    # ------------------------------------------------------------------
    def suspend(self):
        self.suspended = True
        for h in self.handles:
            h.engine.suspend()

    def resume(self):
        self.suspended = False
        for h in self.handles:
            h.engine.resume()

    def update_all(self, params, version: int, recompute_caches: bool = True):
        """Protocol steps (3) update + (5) KV-cache recomputation.
        Engines already at ``version`` no-op (see
        ``InferenceEngine.update_params``), so pulling an unchanged store
        version — always true on iteration 0 — costs nothing instead of
        re-prefilling every in-flight KV cache."""
        for h in self.handles:
            h.engine.update_params(params, version,
                                   recompute_caches=recompute_caches)

    def update_all_chunks(self, chunks, version: int,
                          recompute_caches: bool = True):
        """Sharded weight sync fan-out: every engine assembles the new
        version from the store's per-shard chunks straight into its own
        placement (``InferenceEngine.update_from_chunks``) — a TP engine
        never materializes a full unsharded param copy; a single-device
        engine concatenates. Same no-op/recompute semantics as
        :meth:`update_all`."""
        for h in self.handles:
            h.engine.update_from_chunks(chunks, version,
                                        recompute_caches=recompute_caches)

    def max_group_size(self) -> int:
        """Largest TP group across engines (1 = all single-device). The
        runner keys its push format off this: >1 selects per-shard
        chunked publication (``weightstore.push_params_sharded``)."""
        return max(h.engine.tp_group for h in self.handles)

    # ------------------------------------------------------------------
    # dynamic rebalancing (prefill<->decode role switch)
    # ------------------------------------------------------------------
    def queue_depth_ratio(self) -> float:
        """Decode-side backlog over prefill-side backlog (+1 smoothing so
        an idle side doesn't divide by zero). Backlog is denominated in
        queued + in-flight REQUESTS (``EngineHandle.load``), never in jit
        dispatches, so the signal is invariant to the engines'
        ``steps_per_dispatch`` macro-step batching — a K=8 decode engine
        reports the same backlog as a K=1 engine serving the same work."""
        pre = sum(h.load() for h in self.prefill_handles)
        dec = sum(h.load() for h in self.decode_handles)
        return (dec + 1.0) / (pre + 1.0)

    def _maybe_rebalance(self):
        rb = self.rebalancer
        ratio = self.queue_depth_ratio()
        self._streak_high = self._streak_high + 1 if ratio >= rb.high else 0
        self._streak_low = self._streak_low + 1 if ratio <= rb.low else 0
        if (self._last_switch_pump is not None
                and self._pumps - self._last_switch_pump < rb.cooldown):
            return
        # a switch must leave at least one engine on each side
        if self._streak_high >= rb.window and len(self.prefill_handles) > 1:
            donor = min(self.prefill_handles, key=lambda h: h.load())
            self.switch_role(donor, "decode")
        elif self._streak_low >= rb.window and len(self.decode_handles) > 1:
            donor = min(self.decode_handles, key=lambda h: h.load())
            self.switch_role(donor, "prefill")

    def switch_role(self, handle: EngineHandle, new_role: str):
        """Flip one engine between prefill and decode roles: drain its
        queued commands and in-flight slots, release and re-bind its device
        group under the new role's hardware affinity (when a
        ResourceManager is attached), and re-dispatch the drained work —
        in-flight KV migrates to the remaining engines of the old role via
        the same KVHandoff path the PD split uses."""
        if not self.pd_disagg:
            raise RuntimeError("role switching requires a PD-disaggregated "
                               "proxy")
        if new_role not in ("prefill", "decode") or handle.role == new_role:
            raise ValueError(f"cannot switch {handle.role} -> {new_role}")
        donors = (self.prefill_handles if handle.role == "prefill"
                  else self.decode_handles)
        if len(donors) <= 1:
            raise ValueError(
                f"cannot switch the last {handle.role}-role engine: the "
                "proxy must keep at least one engine on each side")
        old_role, old_pool = handle.role, handle.pool
        eng = handle.engine
        pending = eng.extract_pending()
        # only a decode-role donor can hold in-flight slots (a prefill
        # engine's slots free the moment its handoff is emitted)
        migrated = eng.drain_active_handoffs()
        eng.set_role(new_role)
        if self.rm is not None and handle.binding is not None:
            b = self.rm.rebind(handle.binding.worker_id, new_role)
            if b is not None:
                handle.binding = b
                handle.pool = b.group.pool
        self._refresh_roles()
        # in-flight KV continues on the remaining old-role engines
        for handoff in migrated:
            if self._route_handoff(handoff, old_pool, eng.weight_version):
                self.switch_migrations += 1
        # queued commands re-enter through the proxy's normal routing
        for kind, payload in pending:
            if kind == "add":
                dst = self._select(payload.tag)
                with self._lock:
                    if payload.request_id in self._route:
                        self._route[payload.request_id] = dst
                dst.engine.add_request(payload)
            elif kind == "inject":
                self._route_handoff(payload, payload.source,
                                    payload.weight_version)
            else:                            # abort: follow current route
                with self._lock:
                    dst = self._route.get(payload)
                if dst is not None:
                    dst.engine.abort(payload)
        self.role_switches += 1
        self._last_switch_pump = self._pumps
        self._streak_high = self._streak_low = 0
        self.switch_log.append({
            "pump": self._pumps, "engine": handle.name,
            "from_role": old_role, "to_role": new_role,
            "from_pool": old_pool, "to_pool": handle.pool,
            "migrated": len(migrated), "requeued": len(pending)})

    # ------------------------------------------------------------------
    def placement_report(self, *, prompt_tokens: int = 512,
                         new_tokens: int = 128) -> List[Dict]:
        """Modeled placement pricing per engine: prefill/decode latency of
        its pool's HardwareSpec under the PerfModel, whether the engine's
        role matches its pool's hardware class (affine), and the pool's
        normalized cost. Pools not in the hardware registry (e.g. "local")
        are reported without pricing."""
        cfg = self.handles[0].engine.model.cfg
        out = []
        for h in self.handles:
            hw = REGISTRY.get(h.pool)
            # a live TP group prices as a GROUP: tp-degree speedup in the
            # PerfModel, group-size multiplier on normalized cost
            tp = h.engine.tp_group
            devices = (tp if tp > 1
                       else (h.binding.group.size if h.binding else 1))
            row = {"name": h.name, "pool": h.pool, "role": h.role,
                   "devices": devices, "tp_group": tp}
            if hw is not None:
                conc = max(h.engine.max_slots, 1)
                row.update({
                    "klass": hw.klass,
                    "affine": ROLE_CLASS_AFFINITY.get(h.role) == hw.klass,
                    "modeled_prefill_s": PERF.prefill_time(
                        cfg, prompt_tokens, hw, tp),
                    "modeled_decode_s": PERF.decode_time(
                        cfg, new_tokens, hw, tp,
                        context=prompt_tokens + new_tokens,
                        concurrency=conc),
                    "norm_cost": hw.norm_cost * row["devices"],
                })
            out.append(row)
        return out

    def release_bindings(self):
        """Return every managed device group to the ResourceManager."""
        if self.rm is None:
            return
        for h in self.handles:
            if h.binding is not None:
                self.rm.release(h.binding.worker_id)
                h.binding = None

    # ------------------------------------------------------------------
    def pump(self) -> int:
        """Advance every engine by one macro-step; returns the number of
        decode tokens emitted across engines (token-denominated activity:
        with ``steps_per_dispatch=K`` one pump can emit up to K tokens per
        active slot from a single dispatch each). In PD mode prefill
        engines step before decode engines so a fresh handoff starts
        decoding in the same pump; afterwards the dynamic rebalancer (if
        configured) checks the queue-depth ratio."""
        n = sum(h.engine.step() for h in self._pump_order)
        self._pumps += 1
        if self.rebalancer is not None and self.pd_disagg:
            self._maybe_rebalance()
        return n

    @property
    def busy(self) -> bool:
        return any(h.engine.has_pending for h in self.handles)

    def stats(self) -> Dict:
        # Engine counters are collected FIRST, outside the routing lock:
        # InferenceEngine.stats() takes its _step_lock, and engines call
        # our finish/handoff hooks (which take _lock) while holding
        # _step_lock — taking _step_lock under _lock here would complete
        # that cycle into a deadlock (see the engine module docstring).
        engines = []
        for h in self.handles:
            row = {"pool": h.pool, "name": h.name, "role": h.role,
                   "steps_per_dispatch": h.engine.steps_per_dispatch,
                   # occupancy/backlog gauges (advisory lock-free reads
                   # plus the _lock-guarded queue length) — what the
                   # obs plane exports per role for the autoscaler
                   "queue_len": h.engine.queue_len,
                   "active_slots": h.engine.num_active,
                   "max_slots": h.engine.max_slots}
            row.update(h.engine.stats())
            engines.append(row)
        with self._lock:
            return {
                "requests": self.requests,
                "aborted": self.aborted,
                "pd_disagg": self.pd_disagg,
                "handoffs": self.handoffs,
                "recoveries": self.recoveries,
                "routed_by_pool": dict(self.routed_by_pool),
                "routed_requests": len(self._route),
                "role_switches": self.role_switches,
                "switch_migrations": self.switch_migrations,
                # snapshot COPIES down to the entry dicts: a scraper
                # mutating (or iterating) its snapshot must never touch
                # the live rebalancer log
                "switch_log": [dict(e) for e in self.switch_log],
                "engines": engines,
            }


def format_placement_row(row: Dict) -> str:
    """One-line rendering of a ``placement_report`` row (launchers)."""
    out = (f"{row['name']:>10} pool={row['pool']:<5} "
           f"role={row['role']:<7}")
    if "affine" in row:
        out += (f" affine={row['affine']} "
                f"prefill_s={row['modeled_prefill_s']:.2e} "
                f"decode_s={row['modeled_decode_s']:.2e} "
                f"cost={row['norm_cost']}")
    return out


def format_switch_event(ev: Dict) -> str:
    """One-line rendering of a ``switch_log`` entry (launchers)."""
    return (f"rebalance@pump{ev['pump']}: {ev['engine']} "
            f"{ev['from_role']}->{ev['to_role']} "
            f"pool {ev['from_pool']}->{ev['to_pool']} "
            f"(migrated {ev['migrated']} in-flight)")


def build_pd_proxy(model, params, *, prefill_pool: str = "H800",
                   decode_pool: str = "H20", n_prefill: int = 1,
                   n_decode: int = 1, max_slots: int = 8,
                   max_len: int = 512, seed: int = 0,
                   hw_affinity: Optional[Dict[str, str]] = None,
                   resource_manager: Optional[ResourceManager] = None,
                   devices_per_engine: int = 1,
                   prefill_devices_per_engine: Optional[int] = None,
                   decode_devices_per_engine: Optional[int] = None,
                   shard_rules: Optional[Dict] = None,
                   rebalancer: Optional[RebalancerConfig] = None,
                   steps_per_dispatch: int = 8,
                   donate: bool = True,
                   paged: bool = False,
                   page_size: int = 16) -> LLMProxy:
    """Build a PD-disaggregated proxy: ``n_prefill`` prefill-role engines on
    the compute pool and ``n_decode`` decode-role engines on the bandwidth
    pool (the live analogue of the simulator's ``gen_pools`` +
    ``pd_disagg=True`` configuration).

    With a ``resource_manager``, each engine acquires a real device group
    through ``ResourceManager.bind_affine`` — prefill engines land on
    compute-class pools, decode engines on bandwidth-class pools, with
    opportunistic fallback when the preferred class is exhausted — and the
    ``prefill_pool``/``decode_pool`` names are superseded by the bound
    pools. Pass a ``RebalancerConfig`` to enable the dynamic
    prefill<->decode role switch (which releases/re-binds those groups).

    ``devices_per_engine`` > 1 makes every engine a LIVE TP group: each
    engine claims a disjoint slice of ``jax.devices()``, builds a
    (1, n) group mesh, and executes sharded over it (see
    ``InferenceEngine`` with ``mesh=``). Prefill and decode sizes can
    differ (``prefill_devices_per_engine`` / ``decode_devices_per_engine``
    override the common value — the §6.3 heterogeneous split, e.g. 2-way
    prefill feeding 4-way decode; KV handoffs re-shard across the size
    change). Whenever ANY group exceeds 1, every engine gets a disjoint
    group (a size-1 group mesh for the others) so no two engines contend
    for the same device. Too few visible devices or a group size that
    shards nothing raises instead of silently degrading to one device —
    the no-op ``devices_per_engine`` trap this replaces. On CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before
    importing jax.

    ``steps_per_dispatch``/``donate`` configure the decode hot path of
    every engine (K scanned decode steps per jit dispatch / in-place
    donated KV caches; see ``InferenceEngine``). The shared ``params``
    pytree is exactly why engines never donate their params argument
    (TP engines place a private SHARDED copy of it per group).

    ``paged=True`` switches EVERY engine of the pool to the paged KV
    plane (shared page pool + prefix cache + compacted decode dispatch;
    see ``InferenceEngine``). The KVHandoff interchange format is
    unchanged, so mixed paged/dense pools also interoperate — but a
    uniform setting keeps capacity accounting comparable across the
    pool."""
    pre_n = (prefill_devices_per_engine
             if prefill_devices_per_engine is not None
             else devices_per_engine)
    dec_n = (decode_devices_per_engine
             if decode_devices_per_engine is not None
             else devices_per_engine)
    if pre_n < 1 or dec_n < 1:
        raise ValueError("devices_per_engine must be >= 1, got "
                         f"prefill={pre_n} decode={dec_n}")
    bound = []

    def _bind(wid, role, n_devices):
        if resource_manager is None:
            return None
        b = resource_manager.bind_affine(wid, role, n_devices=n_devices)
        if b is None:
            for w in bound:                  # no partial-placement leak
                resource_manager.release(w)
            raise RuntimeError(
                f"resource manager cannot bind {wid} ({role}) (snapshot: "
                f"{resource_manager.snapshot()['free']})")
        bound.append(wid)
        return b

    # bind the whole placement BEFORE claiming live devices: an RM
    # inventory shortfall reports as "cannot bind" (with partial release)
    # rather than a live-device error, and a live-device shortfall never
    # leaks RM bindings either
    plan = ([(f"prefill-{i}", "prefill", pre_n, seed + i, prefill_pool)
             for i in range(n_prefill)]
            + [(f"decode-{i}", "decode", dec_n, seed + 1000 + i,
                decode_pool)
               for i in range(n_decode)])
    bindings = [_bind(name, role, n) for name, role, n, _, _ in plan]
    meshes = [None] * len(plan)
    if max(pre_n, dec_n) > 1:
        from repro.launch.mesh import (allocate_engine_devices,
                                       make_group_mesh)
        try:
            groups = allocate_engine_devices([n for _, _, n, _, _ in plan])
        except RuntimeError:
            if resource_manager is not None:
                for w in bound:
                    resource_manager.release(w)
            raise
        meshes = [make_group_mesh(g) for g in groups]
    handles = []
    for (name, role, _, eng_seed, pool), b, mesh in zip(plan, bindings,
                                                        meshes):
        eng = InferenceEngine(model, params, max_slots=max_slots,
                              max_len=max_len, seed=eng_seed, role=role,
                              steps_per_dispatch=steps_per_dispatch,
                              donate=donate, mesh=mesh,
                              shard_rules=shard_rules, paged=paged,
                              page_size=page_size)
        handles.append(EngineHandle(eng, b.group.pool if b else pool,
                                    name, binding=b))
    return LLMProxy(handles, hw_affinity=hw_affinity, pd_disagg=True,
                    resource_manager=resource_manager, rebalancer=rebalancer)

"""LLMProxy (paper §6.1): the gateway between EnvManagers and inference
workers. Dispatches generation requests at per-trajectory granularity,
routing each request to the hardware class preferred for its task-domain
tag (R1), and forwards ADD/ABORT commands so trajectory admission or
cancellation never stalls ongoing generation. Also implements the
suspend/resume half of the weight-sync protocol (R4).
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.rl.engine import GenRequest, GenResult, InferenceEngine


@dataclass
class EngineHandle:
    engine: InferenceEngine
    pool: str                   # hardware pool name ("H800"/"H20"/...)
    name: str = ""

    def load(self) -> int:
        return self.engine.num_active + len(self.engine._commands)


class LLMProxy:
    def __init__(self, handles: List[EngineHandle],
                 hw_affinity: Optional[Dict[str, str]] = None):
        """hw_affinity: task tag -> pool name, must include "default"."""
        if not handles:
            raise ValueError("LLMProxy needs at least one engine")
        self.handles = handles
        self.hw_affinity = dict(hw_affinity or {"default": handles[0].pool})
        self.hw_affinity.setdefault("default", handles[0].pool)
        self._route: Dict[str, EngineHandle] = {}
        self._callbacks: Dict[str, Callable[[GenResult], None]] = {}
        self._lock = threading.Lock()
        self.suspended = False
        for h in handles:
            h.engine.on_finish = self._make_finish_hook(h)
        # stats
        self.requests = 0
        self.aborted = 0
        self.routed_by_pool: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def _make_finish_hook(self, handle: EngineHandle):
        def hook(result: GenResult):
            with self._lock:
                cb = self._callbacks.pop(result.request_id, None)
                self._route.pop(result.request_id, None)
            if cb:
                cb(result)
        return hook

    def _select(self, tag: str) -> EngineHandle:
        pool = self.hw_affinity.get(tag, self.hw_affinity["default"])
        matched = [h for h in self.handles if h.pool == pool]
        if not matched:
            matched = self.handles           # fallback: forward progress
        return min(matched, key=lambda h: h.load())

    # ------------------------------------------------------------------
    def submit(self, req: GenRequest,
               callback: Callable[[GenResult], None]):
        """Trajectory-level dispatch (ADD command)."""
        h = self._select(req.tag)
        with self._lock:
            self._callbacks[req.request_id] = callback
            self._route[req.request_id] = h
            self.requests += 1
            self.routed_by_pool[h.pool] = \
                self.routed_by_pool.get(h.pool, 0) + 1
        h.engine.add_request(req)

    def abort(self, request_id: str):
        """ABORT command: cancel one trajectory's generation."""
        with self._lock:
            h = self._route.get(request_id)
            self.aborted += 1
        if h is not None:
            h.engine.abort(request_id)

    # ------------------------------------------------------------------
    # weight-sync protocol hooks (steps (2)-(4))
    # ------------------------------------------------------------------
    def suspend(self):
        self.suspended = True
        for h in self.handles:
            h.engine.suspend()

    def resume(self):
        self.suspended = False
        for h in self.handles:
            h.engine.resume()

    def update_all(self, params, version: int, recompute_caches: bool = True):
        """Protocol steps (3) update + (5) KV-cache recomputation."""
        for h in self.handles:
            h.engine.update_params(params, version,
                                   recompute_caches=recompute_caches)

    # ------------------------------------------------------------------
    def pump(self) -> int:
        """Advance every engine by one step; returns active slot count."""
        return sum(h.engine.step() for h in self.handles)

    @property
    def busy(self) -> bool:
        return any(h.engine.has_pending for h in self.handles)

    def stats(self) -> Dict:
        return {
            "requests": self.requests,
            "aborted": self.aborted,
            "routed_by_pool": dict(self.routed_by_pool),
            "engines": [
                {"pool": h.pool, "steps": h.engine.steps,
                 "busy_steps": h.engine.busy_steps,
                 "prefill_tokens": h.engine.prefill_tokens,
                 "decode_tokens": h.engine.decode_tokens}
                for h in self.handles],
        }

from repro.core.buffer import SampleBuffer
from repro.core.cluster import Cluster
from repro.core.envmanager import EMState, EnvManager, RolloutPolicy
from repro.core.hardware import (H20, H800, PERF, REGISTRY,
                                 ROLE_CLASS_AFFINITY, SERVERLESS,
                                 TPU_V5E, TPU_V5P, HardwareSpec, PerfModel)
from repro.core.proxy import (EngineHandle, LLMProxy, RebalancerConfig,
                              build_pd_proxy)
from repro.core.resource import (Binding, DeviceGroup, ResourceManager,
                                 parse_pools)
from repro.core.scheduler import (DEFAULT_TASK_WEIGHTS, DEFAULT_TASKS,
                                  LiveRLRunner, RunnerConfig)
from repro.core.serverless import ServerlessConfig, ServerlessPlatform
from repro.core.simclock import Event, Resource, Simulator, Timeout
from repro.core.weightstore import (MooncakeStore, pull_params, push_params)
from repro.core.worker import (ActorGenCls, ActorTrainCls, EnvironmentCls,
                               RewardCls, Worker, hw_mapping, register,
                               register_serverless)
from repro.core.profiler import AffinityProfiler, DomainProfile

"""Online hardware-affinity profiler — the paper's §9 extension, built.

RollArt ships with static, per-task-domain ``hw_mapping`` declarations and
discusses (but does not implement) "an online profiler integrated with the
resource manager: per-domain prefill/decode latency would let ROLLART
re-route requests when within-domain shifts occur".

``AffinityProfiler`` implements exactly that: it ingests per-trajectory
generation statistics (prefill vs decode tokens, turns), maintains
exponentially-weighted per-domain profiles, classifies each domain as
prefill- or decode-heavy with hysteresis (profiles must be stable over a
window before a re-route, per §9: "profiling decisions stabilize over a
few iterations"), and emits an ``hw_affinity`` mapping that LLMProxy /
the sim's router consume live.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.rl.engine import GenResult


@dataclass
class DomainProfile:
    prefill_tokens: float = 0.0      # EWMA per trajectory
    decode_tokens: float = 0.0
    turns: float = 0.0
    samples: int = 0
    klass: str = "unknown"           # "prefill_heavy" | "decode_heavy"
    stable_for: int = 0              # consecutive windows with same class

    @property
    def decode_ratio(self) -> float:
        total = self.prefill_tokens + self.decode_tokens
        return self.decode_tokens / total if total else 0.5


@dataclass
class AffinityProfiler:
    """Derives task-domain -> hardware-pool routing from live stats."""
    compute_pool: str = "H800"
    bandwidth_pool: str = "H20"
    decode_heavy_threshold: float = 0.75   # decode fraction of gen tokens
    turns_threshold: float = 8.0           # many turns => prefill-heavy
    ewma: float = 0.2
    min_samples: int = 8
    stability_windows: int = 2             # hysteresis before re-routing
    profiles: Dict[str, DomainProfile] = field(default_factory=dict)

    # ------------------------------------------------------------------
    def observe(self, tag: str, prefill_tokens: int, decode_tokens: int,
                turns: int = 1):
        p = self.profiles.setdefault(tag, DomainProfile())
        a = self.ewma if p.samples else 1.0
        p.prefill_tokens = (1 - a) * p.prefill_tokens + a * prefill_tokens
        p.decode_tokens = (1 - a) * p.decode_tokens + a * decode_tokens
        p.turns = (1 - a) * p.turns + a * turns
        p.samples += 1
        self._reclassify(p)

    def observe_result(self, tag: str, result: GenResult, turns: int = 1):
        self.observe(tag, result.prefill_tokens, result.decode_tokens, turns)

    def _reclassify(self, p: DomainProfile):
        if p.samples < self.min_samples:
            return
        decode_heavy = (p.decode_ratio >= self.decode_heavy_threshold
                        and p.turns < self.turns_threshold)
        new = "decode_heavy" if decode_heavy else "prefill_heavy"
        if new == p.klass:
            p.stable_for += 1
        else:
            p.klass = new
            p.stable_for = 0

    # ------------------------------------------------------------------
    def pool_for(self, tag: str) -> Optional[str]:
        p = self.profiles.get(tag)
        if not p or p.samples < self.min_samples \
                or p.stable_for < self.stability_windows:
            return None                          # not confident yet
        return (self.bandwidth_pool if p.klass == "decode_heavy"
                else self.compute_pool)

    def hw_affinity(self, default: Optional[str] = None) -> Dict[str, str]:
        """The mapping LLMProxy consumes (only confident domains appear)."""
        out = {"default": default or self.compute_pool}
        for tag in self.profiles:
            pool = self.pool_for(tag)
            if pool is not None:
                out[tag] = pool
        return out

    def apply_to(self, proxy) -> Dict[str, str]:
        """Refresh an LLMProxy's routing in place; returns the mapping."""
        mapping = self.hw_affinity(default=proxy.hw_affinity.get("default"))
        proxy.hw_affinity.update(mapping)
        return mapping

    def summary(self) -> Dict[str, Dict]:
        return {tag: {"decode_ratio": round(p.decode_ratio, 3),
                      "turns": round(p.turns, 1), "class": p.klass,
                      "samples": p.samples, "stable_for": p.stable_for}
                for tag, p in self.profiles.items()}

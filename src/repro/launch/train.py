"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Live mode (default): runs the full RollArt agentic-RL pipeline with real
compute on the local device — use reduced/smoke variants on CPU
(``--reduced``). With ``--lm`` it runs plain LM pretraining instead.
On a real TPU slice the same entry point builds the production mesh and
pjit-shards the train step (``--mesh single|pod2``).
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint import checkpointer as CK
from repro.configs import get_config
from repro.core import (DEFAULT_TASKS, EngineHandle, LiveRLRunner, LLMProxy,
                        RebalancerConfig, ResourceManager, RunnerConfig,
                        ServerlessPlatform, build_pd_proxy, parse_pools)
from repro.core.proxy import format_placement_row, format_switch_event
from repro.ft import FTConfig, FTSupervisor, restore_latest
from repro.models import Model
from repro.rewards.rule_based import REWARD_FNS
from repro.rl.engine import InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step, make_lm_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of the arch")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--group", type=int, default=4)
    ap.add_argument("--alpha", type=int, default=1)
    ap.add_argument("--mode", default="rollart",
                    choices=["rollart", "areal", "one_off", "sync",
                             "sync_plus"],
                    help="rollart/areal/one_off run rollout on a "
                         "background worker thread, overlapping train_step")
    ap.add_argument("--tasks", default=",".join(DEFAULT_TASKS),
                    help="comma-separated multi-task mix (default includes "
                         "the long-tail swe/webshop environments)")
    ap.add_argument("--task-weights", default=None,
                    help="comma-separated sampling weights matching --tasks "
                         "(default: weighted mix for the default tasks, "
                         "uniform for a custom task set)")
    ap.add_argument("--reward", default="format_bonus",
                    choices=sorted(REWARD_FNS))
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lm", action="store_true", help="LM pretrain instead "
                    "of agentic RL")
    ap.add_argument("--pd-disagg", action="store_true",
                    help="rollout on disaggregated prefill/decode engine "
                         "pools with live KV handoff (§6.3)")
    ap.add_argument("--pools", default=None, metavar="SPEC",
                    help="heterogeneous rollout device inventory, e.g. "
                         "'H800:8,H20:8' (ResourceManager-backed)")
    ap.add_argument("--affinity", action="store_true",
                    help="role-affine placement (prefill -> compute-class, "
                         "decode -> bandwidth-class, §5.2) plus the dynamic "
                         "prefill<->decode rebalancer; implies --pd-disagg "
                         "and requires --pools")
    ap.add_argument("--n-prefill", type=int, default=None,
                    help="prefill-role engines when disaggregated "
                         "(default 1; 2 with --affinity, so the "
                         "rebalancer has room to switch one)")
    ap.add_argument("--n-decode", type=int, default=1)
    ap.add_argument("--devices-per-engine", type=int, default=1,
                    metavar="N",
                    help="TP group size: each rollout engine runs sharded "
                         "over a disjoint group of N local devices; weight "
                         "sync then moves per-shard chunks through the "
                         "store (on CPU expose devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--prefill-devices-per-engine", type=int, default=None,
                    metavar="N",
                    help="per-role override of --devices-per-engine for "
                         "prefill engines on the disaggregated plane")
    ap.add_argument("--decode-devices-per-engine", type=int, default=None,
                    metavar="N",
                    help="per-role override of --devices-per-engine for "
                         "decode engines on the disaggregated plane")
    ap.add_argument("--steps-per-dispatch", type=int, default=8,
                    metavar="K",
                    help="decode macro-step size: K scanned decode steps "
                         "per jit dispatch (device-resident decode; abort/"
                         "staleness enforcement latency is bounded by one "
                         "macro-step — lower K to tighten it, 1 = legacy "
                         "single-step dispatch)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-rollouts", action="store_true",
                    help="fault tolerance (§8): snapshot the FULL rollout "
                         "plane (env managers, engine KV slots, buffered "
                         "samples, pending rewards) alongside the train "
                         "state at every weight-sync barrier; requires "
                         "--ckpt")
    ap.add_argument("--snapshot-every", type=int, default=1, metavar="N",
                    help="barrier cadence of the rollout snapshots")
    ap.add_argument("--keep-last", type=int, default=3,
                    help="retained checkpoint/snapshot pairs")
    ap.add_argument("--failure-rate", type=float, default=0.0,
                    metavar="P",
                    help="inject a random env/engine/reward failure with "
                         "probability P per iteration (paper §8 observes "
                         "~0.1) and recover it under the FT supervisor")
    ap.add_argument("--restore", action="store_true",
                    help="resume from the latest intact train+rollout "
                         "checkpoint pair under --ckpt (trainer-failure "
                         "restart; corrupt pairs fall back to step N-1)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="serve Prometheus text metrics for the whole "
                         "training stack at http://127.0.0.1:PORT/metrics "
                         "(0 = ephemeral port; watch live with "
                         "python -m repro.obs.dashboard --url ...)")
    ap.add_argument("--watchdog", action="store_true",
                    help="heartbeat watchdog (§8): detect silently hung "
                         "engines / pump loop (beat silent past the "
                         "deadline while work is queued) and recover them "
                         "through the FT supervisor")
    ap.add_argument("--watchdog-deadline", type=float, default=5.0,
                    metavar="S", help="stall deadline in seconds")
    args = ap.parse_args(argv)
    if (args.ckpt_rollouts or args.restore) and not args.ckpt:
        ap.error("--ckpt-rollouts/--restore need --ckpt DIR")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, remat=False)
    opt = default_optimizer(args.lr)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)

    if args.lm:
        from repro.data.pipeline import lm_batches
        from repro.data.tokenizer import TOKENIZER
        import jax.numpy as jnp
        step = jax.jit(make_lm_train_step(model, opt))
        for i, batch in enumerate(lm_batches(TOKENIZER, 128, args.batch,
                                             args.steps)):
            state, m = step(state, {k: jnp.asarray(v)
                                    for k, v in batch.items()})
            print(f"step {i} loss {float(m['loss']):.4f}")
    else:
        step = jax.jit(make_grpo_train_step(model, opt))
        if args.affinity and not args.pools:
            ap.error("--affinity requires --pools "
                     "(e.g. --pools H800:2,H20:2)")
        pd = args.pd_disagg or args.affinity
        if args.pools and not pd:
            ap.error("--pools only takes effect on the disaggregated "
                     "plane; add --pd-disagg or --affinity")
        pools = parse_pools(args.pools) if args.pools else None
        n_prefill = args.n_prefill or (2 if args.affinity else 1)
        weights = (tuple(float(w) for w in args.task_weights.split(","))
                   if args.task_weights else None)

        dpe = args.devices_per_engine
        pre_dpe = args.prefill_devices_per_engine or dpe
        dec_dpe = args.decode_devices_per_engine or dpe

        def build_runner(st):
            """Fresh runner over ``st`` — also the trainer-restart hook
            (``restore_latest`` rebuilds the plane through it)."""
            rm = ResourceManager(pools) if pools else None
            if pd:
                proxy = build_pd_proxy(
                    model, st.params, max_slots=8, max_len=640,
                    n_prefill=n_prefill, n_decode=args.n_decode,
                    resource_manager=rm,
                    rebalancer=RebalancerConfig() if args.affinity
                    else None,
                    steps_per_dispatch=args.steps_per_dispatch,
                    prefill_devices_per_engine=pre_dpe,
                    decode_devices_per_engine=dec_dpe)
            else:
                mesh = None
                if dpe > 1:
                    from repro.launch.mesh import (allocate_engine_devices,
                                                   make_group_mesh)
                    mesh = make_group_mesh(
                        allocate_engine_devices([dpe])[0])
                eng = InferenceEngine(
                    model, st.params, max_slots=8, max_len=640,
                    steps_per_dispatch=args.steps_per_dispatch,
                    mesh=mesh)
                proxy = LLMProxy([EngineHandle(eng, "H20")])
            return LiveRLRunner(
                RunnerConfig(batch_size=args.batch, group_size=args.group,
                             alpha=args.alpha, mode=args.mode,
                             tasks=tuple(args.tasks.split(",")),
                             task_weights=weights,
                             pd_disagg=pd, pools=pools,
                             affinity=args.affinity,
                             steps_per_dispatch=args.steps_per_dispatch),
                proxy, st, step, ServerlessPlatform(),
                REWARD_FNS[args.reward], seq_len=640)

        if args.restore:
            runner, start = restore_latest(args.ckpt, state, build_runner)
            print(f"restored paired checkpoint at step {start}")
        else:
            runner = build_runner(state)
        # --watchdog needs an FT supervisor to recover through, even
        # without checkpointing/injection configured
        use_ft = (args.ckpt_rollouts or args.failure_rate > 0
                  or args.watchdog)
        sup = None
        mserver = wdog = reg = None
        with runner:
            if args.affinity:
                for row in runner.placement_report():
                    print("placement: " + format_placement_row(row))
            if use_ft:
                sup = FTSupervisor(
                    runner,
                    FTConfig(snapshot_every=args.snapshot_every,
                             failure_rate=args.failure_rate,
                             keep_last=args.keep_last),
                    ckpt_dir=args.ckpt if args.ckpt_rollouts else None)
            if args.metrics_port is not None:
                from repro.obs import (MetricsRegistry, MetricsServer,
                                       instrument_runner)
                reg = MetricsRegistry()
                instrument_runner(reg, runner)
                mserver = MetricsServer(reg,
                                        port=args.metrics_port).start()
                print(f"metrics: {mserver.url}")
            if args.watchdog:
                from repro.obs import (Watchdog, watch_engines,
                                       watch_env_managers, watch_service)
                wdog = Watchdog(deadline_s=args.watchdog_deadline,
                                registry=reg)
                watch_engines(wdog, runner.proxy,
                              recover=sup.recover_hung_engine)
                watch_service(wdog, runner.service)
                watch_env_managers(wdog, runner,
                                   recover=sup.recover_stalled_ems)
                wdog.start()
            try:
                if sup is not None:
                    hist = sup.run_steps(args.steps)
                else:
                    hist = runner.run_steps(args.steps)
            finally:
                if wdog is not None:
                    wdog.close()
                if mserver is not None:
                    mserver.close()
            for h in hist:
                d = h.to_dict()   # the stable export schema, verbatim
                print(f"step {d['step']} loss {d['loss']:.4f} "
                      f"reward {d['reward_mean']:.3f} "
                      f"wall {d['wall_s']:.1f}s "
                      f"(fetch {d['fetch_s']:.1f} "
                      f"barrier {d['barrier_s']:.2f} "
                      f"train {d['train_s']:.1f}) "
                      f"stale {d['staleness']} "
                      f"ovl_decode_toks {d['decode_during_train']}"
                      + (f" role_switches {d['role_switches']}"
                         if args.affinity else "")
                      + (f" deduped {d['deduped']}" if d['deduped']
                         else ""))
            if args.affinity:
                for ev in runner.proxy.switch_log:
                    print(format_switch_event(ev))
            state = runner.state
        if sup is not None:
            sup.close()
            for line in sup.log:
                print("ft: " + line)
        runner.proxy.release_bindings()
    if args.ckpt and not args.ckpt_rollouts:
        # with --ckpt-rollouts the supervisor already persisted paired
        # full-state checkpoints; a trailing params-only save would mix
        # tree structures in the same directory
        print("saved:", CK.save(args.ckpt, state.params,
                                step=int(state.version)))


if __name__ == "__main__":
    main()

"""Extract roofline terms from a compiled SPMD executable.

- ``cost_analysis()`` gives **per-device** FLOPs and bytes-accessed (verified
  empirically: sharded operand sizes).
- Collective bytes are not in cost_analysis; we parse the post-optimization
  HLO for all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute result shapes and replica groups, converting each to
  per-device link traffic with standard ring-algorithm factors:
      all-gather        bytes * (g-1)/g
      reduce-scatter    bytes * (g-1)        (operand = g * result)
      all-reduce        2 * bytes * (g-1)/g  (RS + AG)
      all-to-all        bytes * (g-1)/g
      collective-permute bytes
- NOTE (methodology): XLA counts a while/scan body ONCE. The roofline harness
  therefore extracts costs from *unrolled* depth-1/depth-2 builds and
  linearly extrapolates to full depth; full-depth scanned builds are used
  for the lowering/memory proof. See EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(?P<shape>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)
    foldable_bytes: float = 0.0    # AR/AG immediately re-sliced (see below)
    adjusted_bytes_value: float = 0.0

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())

    @property
    def adjusted_bytes(self) -> float:
        return self.adjusted_bytes_value


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=")


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Per-device collective link bytes from post-optimization HLO text.

    Also computes an ADJUSTED total: XLA:CPU's SPMD pipeline lacks the
    ReduceScatterCreator / resharding folds that TPU applies, so it emits
    (a) all-reduce immediately followed by a dynamic-slice (= reduce-
    scatter on TPU: 1/shards of the traffic) and (b) all-gather whose only
    consumers re-slice the shard back out (an identity reshard that is a
    local copy / collective-permute on TPU). Both patterns are detected
    textually and discounted by the group size in ``adjusted_bytes``; raw
    totals are always reported alongside (EXPERIMENTS.md §Roofline).
    """
    stats = CollectiveStats()
    lines = hlo_text.splitlines()
    # consumers: collective result name -> set of consuming op kinds
    coll_names = {}
    for line in lines:
        m = _COLL_RE.search(line)
        if m:
            nm = _NAME_RE.match(line)
            if nm:
                coll_names[nm.group(1)] = []
    if coll_names:
        # longest-first: avoids prefix shadowing ("all-gather" must not
        # swallow "all-gather.1")
        pat = re.compile(r"%(" + "|".join(
            re.escape(n) for n in sorted(coll_names, key=len,
                                         reverse=True)) + r")\b")
        for line in lines:
            nm = _NAME_RE.match(line)
            if not nm or nm.group(1) in coll_names:
                continue
            hits = pat.findall(line)
            if not hits:
                continue
            rhs = line.split("=", 1)[1].lstrip()
            if rhs.startswith("(") or " tuple(" in rhs[:80]:
                continue          # output tuple aliasing, not a compute use
            out_bytes = _shape_bytes(rhs.split("(")[0])
            for used in hits:
                coll_names[used].append(out_bytes)

    adjusted = 0.0
    for line in lines:
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if "-done" in line.split("=")[1][:40]:
            continue
        size = _shape_bytes(m.group("shape"))
        g = _group_size(line)
        if op == "all-gather":
            b = size * (g - 1) / max(g, 1)
        elif op == "all-reduce":
            b = 2.0 * size * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            b = size * (g - 1)
        elif op == "all-to-all":
            b = size * (g - 1) / max(g, 1)
        else:  # collective-permute
            b = float(size)
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) + b
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
        nm = _NAME_RE.match(line)
        consumers = coll_names.get(nm.group(1), []) if nm else []
        # shape test: every consumer's output is at most ~one shard of the
        # collective's result => the full result was never needed (TPU folds
        # this to reduce-scatter / a local copy)
        shard_budget = (size / max(g, 1)) * 2.5
        foldable = (op in ("all-reduce", "all-gather") and consumers
                    and all(cb <= shard_budget for cb in consumers))
        if foldable:
            stats.foldable_bytes += b
            adjusted += b / max(g, 1)
        else:
            adjusted += b
    stats.adjusted_bytes_value = adjusted
    return stats


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_OLD_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def extract_costs(compiled) -> Dict:
    """All roofline raw terms from one compiled executable (per-device)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = parse_collectives(compiled.as_text())
    ma = compiled.memory_analysis()
    out = {
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": coll.total_bytes,
        "collective_bytes_adjusted": coll.adjusted_bytes,
        "collective_foldable_bytes": coll.foldable_bytes,
        "collective_bytes_by_op": coll.bytes_by_op,
        "collective_count_by_op": coll.count_by_op,
    }
    if ma is not None:
        out["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes),
        }
    return out


# --- TPU v5e-class hardware constants (assignment §Roofline) ---------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
HBM_CAP = 16 * 1024 ** 3          # 16 GiB per chip


def roofline_terms(costs: Dict) -> Dict:
    """Three roofline terms in seconds (per-device program)."""
    return {
        "t_compute": costs["flops_per_device"] / PEAK_FLOPS_BF16,
        "t_memory": costs["bytes_per_device"] / HBM_BW,
        "t_collective": costs["collective_bytes_per_device"] / ICI_BW,
    }

"""Production mesh builders (DESIGN.md §5).

A function, not a module-level constant: importing this module must never
touch jax device state.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    DCN axis. Requires xla_force_host_platform_device_count >= 256/512 when
    run without real TPUs (the dry-run sets this before importing jax)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} exist; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_host_mesh():
    """1x1 mesh over the single real device (live mode / smoke tests)."""
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(dev, ("data", "model"))

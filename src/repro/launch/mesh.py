"""Production mesh builders (DESIGN.md §5).

A function, not a module-level constant: importing this module must never
touch jax device state.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    DCN axis. Requires xla_force_host_platform_device_count >= 256/512 when
    run without real TPUs (the dry-run sets this before importing jax)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} exist; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_host_mesh():
    """1x1 mesh over the single real device (live mode / smoke tests)."""
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(dev, ("data", "model"))


def make_group_mesh(devices):
    """(1, n) ("data", "model") mesh over one engine's device group: the
    whole group is the TP ("model") axis, matching the engine-group
    helpers in ``repro.distributed.sharding``."""
    devices = list(devices)
    dev = np.asarray(devices).reshape(1, len(devices))
    return jax.sharding.Mesh(dev, ("data", "model"))


def allocate_engine_devices(group_sizes):
    """Disjoint jax-device groups for a list of engines (one entry per
    engine, in order). Raises with the XLA_FLAGS recipe when the process
    does not expose enough devices — the silent fall-back-to-one-device
    behavior is exactly the bug this replaces."""
    need = sum(group_sizes)
    devices = jax.devices()
    if need > len(devices):
        raise RuntimeError(
            f"engine groups need {need} devices "
            f"({'+'.join(map(str, group_sizes))}) but the process exposes "
            f"{len(devices)}; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            "BEFORE importing jax")
    groups, off = [], 0
    for n in group_sizes:
        groups.append(list(devices[off:off + n]))
        off += n
    return groups

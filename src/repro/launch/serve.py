"""Serving launcher: ``python -m repro.launch.serve --arch tiny --prompt ...``

Runs the continuous-batching engine on the local device, optionally with two
affinity-routed pools. With ``--pd-disagg`` the data plane is split into a
prefill-role engine (compute pool) and a decode-role engine (bandwidth
pool) with a live KV-cache handoff between them (§6.3). On TPU the same
serve_step lowers against the production mesh (see launch/dryrun.py for the
multi-pod proof).
"""
from __future__ import annotations

import argparse
import threading
import time

import jax

from repro.configs import get_config
from repro.core import EngineHandle, LLMProxy, build_pd_proxy
from repro.data.tokenizer import TOKENIZER
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompt", action="append", default=None)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--pd-disagg", action="store_true",
                    help="split prefill/decode across two engine pools "
                         "with live KV-cache handoff (§6.3)")
    ap.add_argument("--async-pump", action="store_true",
                    help="pump the engines from a background thread while "
                         "requests are submitted concurrently (the live "
                         "runner's producer/consumer shape)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    if args.pd_disagg:
        proxy = build_pd_proxy(model, params, max_slots=args.slots,
                               max_len=1024)
    else:
        eng = InferenceEngine(model, params, max_slots=args.slots,
                              max_len=1024)
        proxy = LLMProxy([EngineHandle(eng, "local")])

    prompts = args.prompt or ["the agent moves ", "reward comes from "]
    results = []
    if args.async_pump:
        # producer/consumer serving: a dedicated thread pumps while this
        # thread keeps submitting — the engine command queues and the
        # proxy route table absorb the concurrency
        stop = threading.Event()
        pump_error = []

        def pump_loop():
            try:
                while not stop.is_set():
                    if proxy.pump() == 0:
                        time.sleep(0.001)
            except BaseException as e:      # surfaced by the wait loop
                pump_error.append(e)

        pump_thread = threading.Thread(target=pump_loop, daemon=True)
        pump_thread.start()
    for i, p in enumerate(prompts):
        proxy.submit(GenRequest(request_id=f"r{i}",
                                prompt=TOKENIZER.encode(p, bos=True),
                                max_new_tokens=args.max_new_tokens,
                                temperature=args.temperature),
                     callback=results.append)
    if args.async_pump:
        while len(results) < len(prompts):
            if pump_error:
                raise RuntimeError("pump thread died") from pump_error[0]
            time.sleep(0.005)
        stop.set()
        pump_thread.join()
    else:
        while proxy.busy:
            proxy.pump()
    for r in sorted(results, key=lambda r: r.request_id):
        i = int(r.request_id[1:])
        print(f"[{r.request_id}] {prompts[i]!r} -> "
              f"{TOKENIZER.decode(r.tokens)!r}")
    if args.pd_disagg:
        for e in proxy.stats()["engines"]:
            print(f"pool={e['pool']} role={e['role']} "
                  f"prefill_tokens={e['prefill_tokens']} "
                  f"decode_tokens={e['decode_tokens']}")


if __name__ == "__main__":
    main()

"""Serving launcher: ``python -m repro.launch.serve --arch tiny --prompt ...``

Runs the continuous-batching engine on the local device, optionally with two
affinity-routed pools. With ``--pd-disagg`` the data plane is split into a
prefill-role engine (compute pool) and a decode-role engine (bandwidth
pool) with a live KV-cache handoff between them (§6.3). On TPU the same
serve_step lowers against the production mesh (see launch/dryrun.py for the
multi-pod proof).
"""
from __future__ import annotations

import argparse
import threading
import time

import jax

from repro.configs import get_config
from repro.core import (EngineHandle, LLMProxy, RebalancerConfig,
                        ResourceManager, build_pd_proxy, parse_pools)
from repro.core.proxy import format_placement_row
from repro.data.tokenizer import TOKENIZER
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompt", action="append", default=None)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--pd-disagg", action="store_true",
                    help="split prefill/decode across two engine pools "
                         "with live KV-cache handoff (§6.3)")
    ap.add_argument("--pools", default=None, metavar="SPEC",
                    help="heterogeneous device inventory, e.g. "
                         "'H800:8,H20:8'; engines acquire device groups "
                         "through the ResourceManager")
    ap.add_argument("--affinity", action="store_true",
                    help="role-affine placement (prefill -> compute-class, "
                         "decode -> bandwidth-class pools, §5.2) plus the "
                         "dynamic prefill<->decode rebalancer; implies "
                         "--pd-disagg and requires --pools")
    ap.add_argument("--n-prefill", type=int, default=1)
    ap.add_argument("--n-decode", type=int, default=1)
    ap.add_argument("--devices-per-engine", type=int, default=1,
                    metavar="N",
                    help="TP group size: each engine runs sharded over a "
                         "disjoint group of N local devices (on CPU, "
                         "expose devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--prefill-devices-per-engine", type=int, default=None,
                    metavar="N",
                    help="per-role override of --devices-per-engine for "
                         "prefill engines (PD planes can size roles "
                         "independently, e.g. prefill 2 / decode 4)")
    ap.add_argument("--decode-devices-per-engine", type=int, default=None,
                    metavar="N",
                    help="per-role override of --devices-per-engine for "
                         "decode engines")
    ap.add_argument("--steps-per-dispatch", type=int, default=8,
                    metavar="K",
                    help="decode macro-step size: K scanned decode steps "
                         "per jit dispatch with on-device stop masking "
                         "(amortizes dispatch overhead K-fold; ADD/ABORT "
                         "latency is bounded by one macro-step, so lower "
                         "K for latency-sensitive serving; 1 = legacy "
                         "single-step dispatch)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV decode plane: shared page pool + "
                         "radix prefix cache (redundant prompts fork "
                         "their prefix instead of re-prefilling) + "
                         "compacted decode dispatch that skips idle "
                         "slots; greedy output is byte-identical to the "
                         "dense cache")
    ap.add_argument("--page-size", type=int, default=16, metavar="T",
                    help="tokens per KV page under --paged")
    ap.add_argument("--service", action="store_true",
                    help="serve through the multi-tenant RolloutService "
                         "(Rollout-as-a-Service): prompts are submitted "
                         "as streaming jobs and tokens print as the "
                         "engines emit them, while the service thread "
                         "owns the pump loop")
    ap.add_argument("--async-pump", action="store_true",
                    help="pump the engines from a background thread while "
                         "requests are submitted concurrently (the live "
                         "runner's producer/consumer shape)")
    ap.add_argument("--failure-rate", type=float, default=0.0, metavar="P",
                    help="fault-tolerance demo (§8): crash the busiest "
                         "engine after ~1/P pumps and recover its "
                         "in-flight requests from the periodic KV-slot "
                         "snapshot (snapshot-covered requests resume "
                         "mid-decode; the rest re-prefill)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="serve Prometheus text metrics at "
                         "http://127.0.0.1:PORT/metrics (0 = ephemeral "
                         "port; watch live with "
                         "python -m repro.obs.dashboard --url ...)")
    ap.add_argument("--watchdog", action="store_true",
                    help="heartbeat watchdog (§8): hard-kill an engine "
                         "whose beat goes silent while work is queued, "
                         "then resume its requests from the periodic "
                         "KV-slot snapshot (uncovered ones re-prefill); "
                         "requires --async-pump")
    ap.add_argument("--watchdog-deadline", type=float, default=2.0,
                    metavar="S", help="stall deadline in seconds")
    args = ap.parse_args(argv)
    if args.failure_rate > 0 and args.async_pump:
        ap.error("--failure-rate drives the synchronous pump loop; drop "
                 "--async-pump")
    if args.service and (args.async_pump or args.failure_rate > 0):
        ap.error("--service owns the pump loop; drop --async-pump / "
                 "--failure-rate")
    if args.watchdog and not args.async_pump:
        ap.error("--watchdog recovers the background pump path; add "
                 "--async-pump (training uses repro.launch.train "
                 "--watchdog)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    if args.affinity and not args.pools:
        ap.error("--affinity requires --pools (e.g. --pools H800:2,H20:2)")
    if args.pools and not (args.pd_disagg or args.affinity):
        ap.error("--pools only takes effect on the disaggregated plane; "
                 "add --pd-disagg or --affinity")
    rm = ResourceManager(parse_pools(args.pools)) if args.pools else None
    dpe = args.devices_per_engine
    pre_dpe = args.prefill_devices_per_engine or dpe
    dec_dpe = args.decode_devices_per_engine or dpe
    if args.pd_disagg or args.affinity:
        proxy = build_pd_proxy(
            model, params, max_slots=args.slots, max_len=1024,
            n_prefill=args.n_prefill, n_decode=args.n_decode,
            resource_manager=rm,
            rebalancer=RebalancerConfig() if args.affinity else None,
            steps_per_dispatch=args.steps_per_dispatch,
            prefill_devices_per_engine=pre_dpe,
            decode_devices_per_engine=dec_dpe,
            paged=args.paged, page_size=args.page_size)
        if args.affinity:
            for row in proxy.placement_report():
                print("placement: " + format_placement_row(row))
    else:
        mesh = None
        if dpe > 1:
            from repro.launch.mesh import (allocate_engine_devices,
                                           make_group_mesh)
            mesh = make_group_mesh(allocate_engine_devices([dpe])[0])
        eng = InferenceEngine(model, params, max_slots=args.slots,
                              max_len=1024,
                              steps_per_dispatch=args.steps_per_dispatch,
                              mesh=mesh, paged=args.paged,
                              page_size=args.page_size)
        proxy = LLMProxy([EngineHandle(eng, "local")])

    prompts = args.prompt or ["the agent moves ", "reward comes from "]
    reg = mserver = None
    if args.metrics_port is not None:
        from repro.obs import (MetricsRegistry, MetricsServer,
                               instrument_proxy)
        reg = MetricsRegistry()
        instrument_proxy(reg, proxy)
        mserver = MetricsServer(reg, port=args.metrics_port).start()
        print(f"metrics: {mserver.url}")
    if args.service:
        # Rollout-as-a-Service: the service thread owns the pump loop;
        # this thread is an ordinary streaming client
        from repro.serve import RolloutJob, RolloutService
        with RolloutService(proxy) as svc:
            svc.register_tenant("cli")
            if reg is not None:
                from repro.obs import instrument_service
                instrument_service(reg, svc)
            svc.start()
            tickets = [
                (p, svc.submit("cli", RolloutJob(
                    kind="prompt",
                    prompt=TOKENIZER.encode(p, bos=True),
                    max_new_tokens=args.max_new_tokens,
                    temperature=args.temperature)))
                for p in prompts]
            for p, tk in tickets:
                print(f"[{tk.job_id}] {p!r} -> ", end="", flush=True)
                for chunk in tk.stream:      # prints as the engines emit
                    print(TOKENIZER.decode(chunk.tokens), end="",
                          flush=True)
                print(f"  ({tk.wait(timeout=60)})")
        if mserver is not None:
            mserver.close()
        proxy.release_bindings()
        return
    results = []
    requests = {}
    if args.failure_rate > 0:
        # synchronous pump loop with one injected engine crash + recovery
        for i, p in enumerate(prompts):
            req = GenRequest(request_id=f"r{i}",
                             prompt=TOKENIZER.encode(p, bos=True),
                             max_new_tokens=args.max_new_tokens,
                             temperature=args.temperature)
            requests[req.request_id] = req
            proxy.submit(req, callback=results.append)
        kill_after = max(3, int(round(1.0 / args.failure_rate)))
        snap_slots = {}
        pumps, killed = 0, False
        while proxy.busy:
            if not killed and pumps % 2 == 0:
                # periodic KV-slot snapshot (the serving-side analogue of
                # the runner's barrier snapshot); requests the snapshot
                # misses simply re-prefill at recovery
                snap_slots = {hf.request.request_id: hf
                              for h in proxy.handles
                              for hf in h.engine.snapshot_slots()}
            proxy.pump()
            pumps += 1
            if not killed and pumps >= kill_after:
                victim = max(proxy.handles,
                             key=lambda h: h.engine.inflight_decode_tokens)
                lost = proxy.requests_on(victim)
                victim.engine.crash()
                resumed = resubmitted = 0
                for rid in lost:
                    hf = snap_slots.get(rid)
                    if hf is not None:
                        proxy.reinject(hf)     # callback still registered
                        resumed += 1
                    else:
                        proxy.drop_routes([rid])
                        proxy.submit(requests[rid],
                                     callback=results.append)
                        resubmitted += 1
                print(f"ft: crashed engine {victim.name or victim.pool} "
                      f"after {pumps} pumps — {len(lost)} in-flight lost, "
                      f"{resumed} resumed from snapshot, "
                      f"{resubmitted} re-prefilled")
                killed = True
    elif args.async_pump:
        # producer/consumer serving: a dedicated thread pumps while this
        # thread keeps submitting — the engine command queues and the
        # proxy route table absorb the concurrency
        stop = threading.Event()
        pump_error = []
        snap_lock = threading.Lock()
        snap_slots = {}                 # guarded by snap_lock

        def pump_loop():
            try:
                pumps = 0
                while not stop.is_set():
                    if args.watchdog and pumps % 2 == 0:
                        # periodic KV-slot snapshot, same idiom as the
                        # --failure-rate demo: watchdog-recovered
                        # requests resume mid-decode when covered
                        snap = {hf.request.request_id: hf
                                for h in proxy.handles
                                for hf in h.engine.snapshot_slots()}
                        with snap_lock:
                            snap_slots.clear()
                            snap_slots.update(snap)
                    if proxy.pump() == 0:
                        time.sleep(0.001)
                    pumps += 1
            except BaseException as e:      # surfaced by the wait loop
                pump_error.append(e)

        pump_thread = threading.Thread(target=pump_loop, daemon=True)
        pump_thread.start()
    if args.failure_rate <= 0:
        for i, p in enumerate(prompts):
            req = GenRequest(request_id=f"r{i}",
                             prompt=TOKENIZER.encode(p, bos=True),
                             max_new_tokens=args.max_new_tokens,
                             temperature=args.temperature)
            requests[req.request_id] = req
            proxy.submit(req, callback=results.append)
    wdog = None
    if args.async_pump:
        if args.watchdog:
            from repro.obs import Watchdog, watch_engines

            def recover(handle):
                """Serving-side hung-engine recovery: hard-kill (the
                lock-free SIGKILL analogue, honored as the wedged step
                unwinds), wait for the replacement process, then resume
                snapshot-covered requests and re-prefill the rest."""
                eng = handle.engine
                lost = proxy.requests_on(handle)
                c0 = eng.crashes
                eng.hard_kill()
                deadline = time.monotonic() + 30
                while eng.crashes == c0:
                    if time.monotonic() > deadline:
                        raise RuntimeError("hard-killed engine never "
                                           "came back")
                    time.sleep(0.005)
                with snap_lock:
                    snap = dict(snap_slots)
                resumed = resubmitted = 0
                for rid in lost:
                    hf = snap.get(rid)
                    if hf is not None:
                        proxy.reinject(hf)   # callback still registered
                        resumed += 1
                    else:
                        proxy.drop_routes([rid])
                        proxy.submit(requests[rid],
                                     callback=results.append)
                        resubmitted += 1
                print(f"watchdog: killed hung engine "
                      f"{handle.name or handle.pool} — {len(lost)} "
                      f"in-flight, {resumed} resumed from snapshot, "
                      f"{resubmitted} re-prefilled")

            wdog = Watchdog(deadline_s=args.watchdog_deadline,
                            registry=reg)
            watch_engines(wdog, proxy, recover=recover)
            wdog.start()
        while len(results) < len(prompts):
            if pump_error:
                raise RuntimeError("pump thread died") from pump_error[0]
            time.sleep(0.005)
        stop.set()
        pump_thread.join()
        if wdog is not None:
            wdog.close()
    else:
        while proxy.busy:
            proxy.pump()
    for r in sorted(results, key=lambda r: r.request_id):
        i = int(r.request_id[1:])
        print(f"[{r.request_id}] {prompts[i]!r} -> "
              f"{TOKENIZER.decode(r.tokens)!r}")
    if args.pd_disagg or args.affinity:
        stats = proxy.stats()
        for e in stats["engines"]:
            print(f"pool={e['pool']} role={e['role']} "
                  f"prefill_tokens={e['prefill_tokens']} "
                  f"decode_tokens={e['decode_tokens']}")
        if args.affinity:
            print(f"role_switches={stats['role_switches']} "
                  f"switch_migrations={stats['switch_migrations']}")
    if mserver is not None:
        mserver.close()
    proxy.release_bindings()


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct input specs + shardings for every (arch x input-shape)
combination — the shannon/kernels pattern: weak-type-correct, shardable, no
device allocation. Used by the dry-run and the roofline harness.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed import sharding as SH
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.rl import trainer as TR

LONG_CONTEXT_WINDOW = 8192   # sliding-window size for long_500k on attn archs


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _named(mesh, rules, shape, *logical):
    spec = SH.resolve_spec(logical, rules, mesh)
    spec = SH.fit_spec(shape, spec, mesh)
    return NamedSharding(mesh, spec)


def cond_spec(cfg: ModelConfig, batch: int) -> Optional[jax.ShapeDtypeStruct]:
    lc = max(cfg.cond_len, cfg.vision_patches)
    if lc <= 0:
        return None
    return sds((batch, lc, cfg.d_model), cfg.dtype)


def serve_param_specs(model: Model):
    """bf16 parameter ShapeDtypeStructs (serving keeps weights in bf16)."""
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    def cast(s):
        d = jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype
        return sds(s.shape, d)

    return jax.tree.map(cast, shapes)


def cache_specs(model: Model, batch: int, cache_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, cache_len))


def cache_sharding(cache_shapes, mesh, rules):
    """Logical axes per cache leaf (keyed by leaf name)."""
    logical = {
        "k": (None, "batch", "cache_kv_heads", "cache_seq", None),
        "v": (None, "batch", "cache_kv_heads", "cache_seq", None),
        "h": (None, "batch", "mamba_inner", None),
        "conv": (None, "batch", None, "mamba_inner"),
        "prev_x": (None, "batch", None),
        "S": (None, "batch", "rwkv_heads", None, None),
    }

    def one(path, leaf):
        name = SH._path_str(path).split("/")[-1]
        axes = logical[name]
        return _named(mesh, rules, np.shape(leaf), *axes)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# ---------------------------------------------------------------------------
# step functions to lower
# ---------------------------------------------------------------------------

def make_serve_decode(model: Model):
    def serve_step(params, tokens, cache, positions):
        logits, cache = model.decode_step(params, tokens, cache, positions)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, cache
    return serve_step


def make_serve_prefill(model: Model, with_cond: bool):
    if with_cond:
        def prefill_step(params, tokens, cache, cond):
            return model.prefill(params, tokens, cache, cond=cond)
    else:
        def prefill_step(params, tokens, cache):
            return model.prefill(params, tokens, cache)
    return prefill_step


# ---------------------------------------------------------------------------
# bundles: (fn, arg_specs, arg_shardings, donate) per kind
# ---------------------------------------------------------------------------

# PERF(iter 5): gradient accumulation for activation-bound archs — jamba's
# mamba chunk working set exceeds HBM at full batch; 2 microbatches halve it
TRAIN_MICROBATCHES = {"jamba-v0.1-52b": 2}


def train_bundle(cfg: ModelConfig, shape: InputShape, mesh,
                 scan_layers: bool = True) -> Tuple:
    rules = SH.TRAIN_RULES
    model = Model(cfg, scan_layers=scan_layers, remat=True)
    opt = TR.default_optimizer()
    step = TR.make_grpo_train_step(
        model, opt,
        num_microbatches=TRAIN_MICROBATCHES.get(cfg.name, 1))
    state_shapes = jax.eval_shape(
        lambda key: TR.init_train_state(model, key, opt),
        jax.random.PRNGKey(0))
    state_sh = SH.param_sharding(state_shapes, mesh, rules)
    B, S = shape.global_batch, shape.seq_len
    batch = TR.grpo_batch_spec(cfg, B, S)
    batch_sh = {
        "tokens": _named(mesh, rules, (B, S), "batch", None),
        "loss_mask": _named(mesh, rules, (B, S), "batch", None),
        "advantages": _named(mesh, rules, (B,), "batch"),
        "behavior_logprobs": _named(mesh, rules, (B, S - 1), "batch", None),
    }
    c = cond_spec(cfg, B)
    if c is not None:
        batch["cond"] = c
        batch_sh["cond"] = _named(mesh, rules, c.shape, "batch", None, None)
    # PERF(iter 2): pin output shardings (new state == input state layout);
    # without this XLA may materialize gathered outputs
    out_sh = (state_sh, None)
    return (step, (state_shapes, batch), (state_sh, batch_sh), (0,), rules,
            model, out_sh)


def decode_bundle(cfg: ModelConfig, shape: InputShape, mesh,
                  scan_layers: bool = True) -> Tuple:
    rules = SH.SERVE_RULES
    window = (LONG_CONTEXT_WINDOW
              if shape.name == "long_500k" and cfg.uses_attention else None)
    model = Model(cfg, scan_layers=scan_layers, remat=False, window=window)
    fn = make_serve_decode(model)
    B = shape.global_batch
    params = serve_param_specs(model)
    params_sh = SH.param_sharding(params, mesh, rules)
    cache = cache_specs(model, B, shape.seq_len)
    cache_sh = cache_sharding(cache, mesh, rules)
    tokens = sds((B, 1), jnp.int32)
    positions = sds((B,), jnp.int32)
    arg_sh = (params_sh,
              _named(mesh, rules, (B, 1), "batch", None),
              cache_sh,
              _named(mesh, rules, (B,), "batch"))
    out_sh = (_named(mesh, rules, (B,), "batch"), cache_sh)
    return (fn, (params, tokens, cache, positions), arg_sh, (2,), rules,
            model, out_sh)


def prefill_bundle(cfg: ModelConfig, shape: InputShape, mesh,
                   scan_layers: bool = True) -> Tuple:
    rules = SH.SERVE_RULES
    model = Model(cfg, scan_layers=scan_layers, remat=False)
    B, S = shape.global_batch, shape.seq_len
    c = cond_spec(cfg, B)
    fn = make_serve_prefill(model, with_cond=c is not None)
    params = serve_param_specs(model)
    params_sh = SH.param_sharding(params, mesh, rules)
    cache = cache_specs(model, B, S)
    cache_sh = cache_sharding(cache, mesh, rules)
    tokens = sds((B, S), jnp.int32)
    args = [params, tokens, cache]
    arg_sh = [params_sh, _named(mesh, rules, (B, S), "batch", None), cache_sh]
    if c is not None:
        args.append(c)
        arg_sh.append(_named(mesh, rules, c.shape, "batch", None, None))
    out_sh = (_named(mesh, rules, (B, cfg.vocab_size), "batch", "vocab"),
              cache_sh)
    return fn, tuple(args), tuple(arg_sh), (2,), rules, model, out_sh


def bundle_for(cfg: ModelConfig, shape: InputShape, mesh,
               scan_layers: bool = True) -> Tuple:
    if shape.kind == "train":
        return train_bundle(cfg, shape, mesh, scan_layers)
    if shape.kind == "prefill":
        return prefill_bundle(cfg, shape, mesh, scan_layers)
    return decode_bundle(cfg, shape, mesh, scan_layers)

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run (deliverable (e)).

For every (architecture x input shape x mesh) combination this lowers the
appropriate step function (train_step for train shapes, serve prefill/decode
for inference shapes) against the production mesh, compiles it, and records
memory/cost/collective analysis to results/dryrun/*.json.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first initialization. Nothing else in the repo sets this
flag (smoke tests and benches see 1 device).

Usage:
    python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--jobs-file f.json]
    python -m repro.launch.dryrun --arch X --shape Y --depth 1 --unroll   # cost point
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config, get_shape
from repro.launch import specs as SP
from repro.launch.hlo_costs import extract_costs
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def result_path(arch: str, shape: str, mesh_kind: str, tag: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_kind}__{tag}.json")


def dryrun_one(arch: str, shape_name: str, mesh_kind: str = "single",
               depth: int = 0, unroll: bool = False,
               verbose: bool = True) -> dict:
    """Lower + compile one combination. depth=0 means full depth."""
    from repro.distributed.sharding import axis_rules

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if depth:
        cfg = cfg.with_(num_layers=len(cfg.block_pattern) * depth)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    t0 = time.time()
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "depth": depth or cfg.num_periods, "unroll": unroll,
        "num_layers": cfg.num_layers,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "kind": shape.kind, "ok": False,
    }
    try:
        (fn, args, arg_sh, donate, rules, model,
         out_sh) = SP.bundle_for(cfg, shape, mesh, scan_layers=not unroll)
        with mesh:
            with axis_rules(mesh, rules):
                jitted = jax.jit(fn, in_shardings=arg_sh,
                                 out_shardings=out_sh,
                                 donate_argnums=donate)
                lowered = jitted.lower(*args)
                t_lower = time.time()
                compiled = lowered.compile()
                t_compile = time.time()
        costs = extract_costs(compiled)
        out.update(costs)
        out.update(ok=True, lower_s=t_lower - t0,
                   compile_s=t_compile - t_lower)
        if verbose:
            mem = costs.get("memory", {})
            print(f"[ok] {arch} x {shape_name} x {mesh_kind} "
                  f"(depth={out['depth']}{' unrolled' if unroll else ''}): "
                  f"lower {out['lower_s']:.1f}s compile {out['compile_s']:.1f}s "
                  f"args {mem.get('argument_bytes', 0)/2**30:.2f}GiB "
                  f"temp {mem.get('temp_bytes', 0)/2**30:.2f}GiB "
                  f"flops/dev {costs['flops_per_device']:.3e} "
                  f"coll/dev {costs['collective_bytes_per_device']/2**20:.1f}MiB")
    except Exception as e:  # noqa: BLE001 - failures are data here
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {mesh_kind}: {out['error']}")
    return out


def run_and_save(arch, shape, mesh_kind, depth=0, unroll=False) -> dict:
    tag = "full" if not depth else f"d{depth}{'u' if unroll else ''}"
    res = dryrun_one(arch, shape, mesh_kind, depth=depth, unroll=unroll)
    with open(result_path(arch, shape, mesh_kind, tag), "w") as f:
        json.dump(res, f, indent=1, default=str)
    return res


def all_jobs(meshes=("single", "pod2"), include_cost_points: bool = True):
    jobs = []
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            for mesh_kind in meshes:
                jobs.append((arch, shape, mesh_kind, 0, False))
            if include_cost_points:
                # roofline cost extraction: unrolled depth-1/-2, single pod
                jobs.append((arch, shape, "single", 1, True))
                jobs.append((arch, shape, "single", 2, True))
    return jobs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "pod2", "both"])
    ap.add_argument("--depth", type=int, default=0)
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for j in all_jobs():
            print(j)
        return 0

    if args.all:
        meshes = ("single", "pod2") if args.mesh == "both" else (args.mesh,)
        failures = 0
        for arch, shape, mesh_kind, depth, unroll in all_jobs(meshes):
            tag = "full" if not depth else f"d{depth}{'u' if unroll else ''}"
            p = result_path(arch, shape, mesh_kind, tag)
            if args.skip_existing and os.path.exists(p):
                with open(p) as f:
                    if json.load(f).get("ok"):
                        continue
            res = run_and_save(arch, shape, mesh_kind, depth, unroll)
            failures += 0 if res["ok"] else 1
        print(f"done; failures={failures}")
        return 1 if failures else 0

    meshes = ("single", "pod2") if args.mesh == "both" else (args.mesh,)
    rc = 0
    for mesh_kind in meshes:
        res = run_and_save(args.arch, args.shape, mesh_kind,
                           depth=args.depth, unroll=args.unroll)
        rc |= 0 if res["ok"] else 1
    return rc


if __name__ == "__main__":
    sys.exit(main())

"""qwen3-14b — dense with qk_norm + GQA. [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    source="hf:Qwen/Qwen3-8B family (assignment: 40L d=5120 40H kv=8 ff=17408 v=151936)",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936, qk_norm=True, rope_theta=1000000.0,
    block_pattern=(("attn", "mlp"),),
)

"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave with 16-expert
top-2 MoE on every other layer. [arXiv:2403.19887]"""
from repro.configs.base import ModelConfig

# 8-layer Jamba period: attention at index 3, MoE every other layer.
_PERIOD = (
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("attn", "moe"),
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    source="arXiv:2403.19887 (32L d=4096 32H kv=8 ff=14336 v=65536, 16e top-2, 1:7 attn:mamba)",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, moe_d_ff=14336, vocab_size=65536,
    num_experts=16, top_k=2,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    block_pattern=_PERIOD,
)

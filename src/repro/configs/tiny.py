"""tiny — live-mode model for CPU RL training (examples/tests)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tiny", family="dense",
    source="this repo (live-mode CPU model)",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=384, vocab_size=512, rope_theta=10000.0,
    dtype="float32", param_dtype="float32",
    block_pattern=(("attn", "mlp"),),
)

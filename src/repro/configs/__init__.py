from repro.configs.base import (ARCH_IDS, EXTRA_IDS, INPUT_SHAPES, InputShape,
                                ModelConfig, get_config, get_shape, list_archs)

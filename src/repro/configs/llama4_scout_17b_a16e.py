"""llama4-scout-17b-a16e — 16-expert top-1 MoE with shared expert and
early-fusion vision patches (stubbed frontend). [hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (48L d=5120 40H kv=8 ff=8192 v=202048, 16e top-1)",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, moe_d_ff=8192, vocab_size=202048, rope_theta=500000.0,
    num_experts=16, top_k=1, num_shared_experts=1,
    vision_patches=144,   # stubbed ViT patch embeddings, early fusion
    block_pattern=(("attn", "moe"),),
)

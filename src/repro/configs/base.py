"""Config system: architecture configs + input shapes.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG``; the registry here resolves ``--arch <id>`` strings.

Layer structure is expressed as a ``block_pattern``: a tuple of
``(mixer, ffn)`` pairs that tiles the depth (``num_layers % len(pattern) == 0``).
``mixer`` in {"attn", "mamba", "rwkv"}; ``ffn`` in {"mlp", "moe"}.
The model builder scans over pattern periods so HLO size is depth-independent.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

MIXERS = ("attn", "mamba", "rwkv")
FFNS = ("mlp", "moe")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str                      # citation for the config
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 4096
    # layer pattern (tiled over depth)
    block_pattern: Tuple[Tuple[str, str], ...] = (("attn", "mlp"),)
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden size (0 -> d_ff)
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    # attention details
    qk_norm: bool = False
    rope_theta: float = 500000.0
    sliding_window: Optional[int] = None   # set for long-context variant
    # SSM (mamba) details
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # RWKV details
    rwkv_head_dim: int = 64
    # multimodal stub frontend
    cond_len: int = 0                # conditioning prefix length (audio/vlm)
    vision_patches: int = 0          # early-fusion patch embeddings (llama4)
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    def __post_init__(self):
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern length {len(self.block_pattern)}")
        for mixer, ffn in self.block_pattern:
            assert mixer in MIXERS and ffn in FFNS
        if self.uses_moe:
            assert self.num_experts > 0 and self.top_k > 0

    # ------------------------------------------------------------------
    @property
    def uses_moe(self) -> bool:
        return any(f == "moe" for _, f in self.block_pattern)

    @property
    def uses_attention(self) -> bool:
        return any(m == "attn" for m, _ in self.block_pattern)

    @property
    def attention_free(self) -> bool:
        return not self.uses_attention

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def num_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def blocks_per_period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.block_pattern)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 pattern periods, d_model<=512, <=4 experts."""
        pat = self.block_pattern
        n_layers = len(pat) * min(2, self.num_periods)
        # keep at most one period for long patterns (e.g. jamba's 8)
        if n_layers > 8:
            n_layers = len(pat)
        d_model = min(self.d_model, 256)
        head_dim = 32
        n_heads = max(2, min(4, self.num_heads))
        n_kv = max(1, min(n_heads, self.num_kv_heads))
        if n_heads % n_kv:
            n_kv = 1
        kw = dict(
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            rwkv_head_dim=32,
            mamba_d_state=8,
            cond_len=min(self.cond_len, 4),
            vision_patches=min(self.vision_patches, 4),
            param_dtype="float32",
            dtype="float32",
        )
        if self.uses_moe:
            kw.update(num_experts=min(4, self.num_experts),
                      top_k=min(2, self.top_k),
                      moe_d_ff=min(self.expert_d_ff, 256))
        if self.sliding_window:
            kw["sliding_window"] = 64
        return self.with_(**kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (matches the builder's shapes)."""
        d, hd = self.d_model, self.head_dim
        n_attn = sum(m == "attn" for m, _ in self.block_pattern) * self.num_periods
        n_mamba = sum(m == "mamba" for m, _ in self.block_pattern) * self.num_periods
        n_rwkv = sum(m == "rwkv" for m, _ in self.block_pattern) * self.num_periods
        n_moe = sum(f == "moe" for _, f in self.block_pattern) * self.num_periods
        n_mlp = sum(f == "mlp" for _, f in self.block_pattern) * self.num_periods
        p = 0
        # embeddings + head
        p += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        # attention
        q = d * self.num_heads * hd
        kv = d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        p += n_attn * (q + 2 * kv + o)
        # mamba
        di, ds = self.mamba_d_inner, self.mamba_d_state
        p += n_mamba * (d * 2 * di            # in_proj (x and z)
                        + di * self.mamba_d_conv
                        + di * (2 * ds + di // 16 + 1)  # x->B,C,dt(lowrank-ish)
                        + di * ds              # A
                        + di * d)              # out_proj
        # rwkv
        p += n_rwkv * (d * d * 5 + d * 64 * 2)  # r,k,v,g,o + decay lora
        # mlp
        p += n_mlp * (3 * d * self.d_ff)
        # moe
        e_ff = self.expert_d_ff
        p += n_moe * (self.num_experts * 3 * d * e_ff
                      + self.num_shared_experts * 3 * d * e_ff
                      + d * self.num_experts)
        # norms (negligible)
        p += self.num_layers * 2 * d + d
        return p

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts)."""
        if not self.uses_moe:
            return self.param_count()
        d = self.d_model
        e_ff = self.expert_d_ff
        n_moe = sum(f == "moe" for _, f in self.block_pattern) * self.num_periods
        dense = self.param_count() - n_moe * self.num_experts * 3 * d * e_ff
        return dense + n_moe * self.top_k * 3 * d * e_ff


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# architecture ids assigned to this paper (module names use underscores)
ARCH_IDS = [
    "llama3.2-3b",
    "qwen3-moe-30b-a3b",
    "granite-8b",
    "qwen3-14b",
    "musicgen-large",
    "llama4-scout-17b-a16e",
    "rwkv6-7b",
    "chameleon-34b",
    "jamba-v0.1-52b",
    "minitron-8b",
]
# paper's own models, usable with the same machinery
EXTRA_IDS = ["qwen3-8b", "qwen3-32b", "qwen2.5-7b", "tiny"]


def _modname(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    if arch.endswith("-smoke"):
        return get_config(arch[: -len("-smoke")]).reduced()
    if arch not in ARCH_IDS + EXTRA_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS + EXTRA_IDS}")
    mod = importlib.import_module(f"repro.configs.{_modname(arch)}")
    return mod.CONFIG


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def list_archs():
    return list(ARCH_IDS)

"""qwen2.5-7b — the paper's reward LLM (LLM-as-judge)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b", family="dense",
    source="hf:Qwen/Qwen2.5-7B (28L d=3584 28H kv=4 ff=18944 v=152064)",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064, rope_theta=1000000.0,
    block_pattern=(("attn", "mlp"),),
)

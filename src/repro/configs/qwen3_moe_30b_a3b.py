"""qwen3-moe-30b-a3b — 128-expert top-8 MoE. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    source="hf:Qwen/Qwen3-30B-A3B (48L d=2048 32H kv=4 moe_ff=768 v=151936, 128e top-8)",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, moe_d_ff=768, vocab_size=151936, qk_norm=True, rope_theta=1000000.0,
    num_experts=128, top_k=8,
    block_pattern=(("attn", "moe"),),
)

"""qwen3-32b — the paper's largest dense evaluation model."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    source="hf:Qwen/Qwen3-32B (64L d=5120 64H kv=8 ff=25600 v=151936)",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=25600, vocab_size=151936, qk_norm=True, rope_theta=1000000.0,
    block_pattern=(("attn", "mlp"),),
)

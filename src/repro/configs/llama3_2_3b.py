"""llama3.2-3b — dense Llama-3 family. [hf:meta-llama/Llama-3.2-1B scaled per assignment]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    source="hf:meta-llama/Llama-3.2-1B (assignment: 28L d=3072 24H kv=8 ff=8192 v=128256)",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128256, rope_theta=500000.0,
    block_pattern=(("attn", "mlp"),),
)

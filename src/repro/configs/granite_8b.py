"""granite-8b — llama-arch dense code model. [arXiv:2405.04324]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    source="arXiv:2405.04324 (36L d=4096 32H kv=8 ff=14336 v=49152)",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=49152, rope_theta=10000.0,
    block_pattern=(("attn", "mlp"),),
)

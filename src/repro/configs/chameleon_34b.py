"""chameleon-34b — early-fusion VLM over VQ image tokens (image tokens share
the text vocab, so the VQ tokenizer is the stubbed frontend and the backbone
is a standard token decoder). [arXiv:2405.09818]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    source="arXiv:2405.09818 (48L d=8192 64H kv=8 ff=22016 v=65536)",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=65536, qk_norm=True, rope_theta=10000.0,
    block_pattern=(("attn", "mlp"),),
)

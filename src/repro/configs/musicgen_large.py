"""musicgen-large — decoder-only over EnCodec tokens; text-conditioning
frontend is a stub that supplies precomputed conditioning embeddings
(cond_len prefix). [arXiv:2306.05284]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    source="arXiv:2306.05284 (48L d=2048 32H kv=32(MHA) ff=8192 v=2048)",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048, rope_theta=10000.0,
    cond_len=64,   # stubbed T5 text-conditioning prefix embeddings
    block_pattern=(("attn", "mlp"),),
)

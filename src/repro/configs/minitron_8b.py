"""minitron-8b — width-pruned Nemotron dense model. [arXiv:2407.14679]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    source="arXiv:2407.14679 (32L d=4096 32H kv=8 ff=16384 v=256000)",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=256000, rope_theta=10000.0,
    block_pattern=(("attn", "mlp"),),
)

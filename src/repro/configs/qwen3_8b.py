"""qwen3-8b — the paper's main evaluation model (RollArt Sec. 7)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    source="hf:Qwen/Qwen3-8B (36L d=4096 32H kv=8 ff=12288 v=151936)",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=12288, vocab_size=151936, qk_norm=True, rope_theta=1000000.0,
    block_pattern=(("attn", "mlp"),),
)

"""rwkv6-7b (Finch) — attention-free, data-dependent decay linear
recurrence. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    source="arXiv:2404.05892 (32L d=4096 attn-free ff=14336 v=65536)",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64, head_dim=64,
    d_ff=14336, vocab_size=65536, rwkv_head_dim=64,
    block_pattern=(("rwkv", "mlp"),),
)

"""GEM-game style environment (Table 1: game, 1 turn): single-turn guessing
game with chain-of-thought — pure decode-heavy workload.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.envs.base import LatencyProfile, TextEnv


class GameEnv(TextEnv):
    TASK = "game"
    MODALITY = "text"
    MAX_TURNS = 1
    LATENCY = LatencyProfile(reset_mean_s=0.3, step_mean_s=0.05,
                             reset_tail_prob=0.005, step_tail_prob=0.002)

    def __init__(self, seed: int = 0):
        super().__init__(seed)
        self.a = 0
        self.b = 0

    def _reset(self) -> str:
        self.a = self.rng.randint(2, 9)
        self.b = self.rng.randint(2, 9)
        return (f"Game: I multiply {self.a} by {self.b} then add {self.a}. "
                "Reply with 'answer: <number>'.")

    def _step(self, action: str) -> Tuple[str, float, bool, Dict]:
        target = self.a * self.b + self.a
        a = action.strip().lower()
        guess = None
        if "answer:" in a:
            tail = a.split("answer:", 1)[1].strip().split()
            try:
                guess = int(tail[0]) if tail else None
            except ValueError:
                guess = None
        return ("correct!" if guess == target else
                f"wrong, it was {target}."), \
            (1.0 if guess == target else 0.0), True, {}


ENV_CLASSES = None  # populated in envs/__init__.py

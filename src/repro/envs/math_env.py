"""GEM-math style environment (Table 1: math + tool use, <5 turns,
decode-heavy): the agent solves arithmetic chains, optionally calling a
calculator tool with ``calc: <expr>``; a final ``answer: <n>`` ends the
episode. Few turns + long chains of thought per action = decode-heavy.
"""
from __future__ import annotations

import random
from typing import Dict, Tuple

from repro.envs.base import LatencyProfile, TextEnv


def _gen_problem(rng: random.Random, depth: int = 3):
    val = rng.randint(1, 9)
    expr = str(val)
    for _ in range(depth):
        op = rng.choice(["+", "-", "*"])
        nxt = rng.randint(1, 9)
        expr = f"({expr} {op} {nxt})"
        val = {"+": val + nxt, "-": val - nxt, "*": val * nxt}[op]
    return expr, val


class MathEnv(TextEnv):
    TASK = "math"
    MODALITY = "text"
    MAX_TURNS = 5
    LATENCY = LatencyProfile(reset_mean_s=0.5, step_mean_s=0.1,
                             reset_tail_prob=0.01, step_tail_prob=0.005)

    def __init__(self, seed: int = 0, depth: int = 3):
        super().__init__(seed)
        self.depth = depth
        self.expr = ""
        self.answer = 0

    def _reset(self) -> str:
        self.expr, self.answer = _gen_problem(self.rng, self.depth)
        return (f"Compute {self.expr}. Use 'calc: <expr>' for a calculator "
                f"or finish with 'answer: <number>'.")

    def _safe_eval(self, expr: str):
        if not all(ch in "0123456789+-*() ." for ch in expr):
            return None
        try:
            return eval(expr, {"__builtins__": {}})  # noqa: S307 - filtered
        except Exception:
            return None

    def _step(self, action: str) -> Tuple[str, float, bool, Dict]:
        a = action.strip().lower()
        if "calc:" in a:
            # "calc:" with an empty payload must hit the malformed-action
            # path, not raise IndexError on splitlines()[0]
            lines = a.split("calc:", 1)[1].strip().splitlines()
            expr = lines[0] if lines else ""
            val = self._safe_eval(expr) if expr else None
            if val is None:
                return "calculator error.", -0.02, False, {"tool": "err"}
            return f"calculator: {expr} = {val}", 0.0, False, {"tool": "ok"}
        if "answer:" in a:
            tail = a.split("answer:", 1)[1].strip().split()
            try:
                guess = int(tail[0]) if tail else None
            except ValueError:
                guess = None
            if guess == self.answer:
                return "correct!", 1.0, True, {}
            return f"wrong (expected {self.answer}).", 0.0, True, {}
        return "use 'calc:' or 'answer:'.", -0.02, False, {"invalid": True}

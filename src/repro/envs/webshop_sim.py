"""WebShop-style environment (Table 1: web, 5-30 turns): navigate a small
product catalog with search/click/buy actions to satisfy an instruction.
"""
from __future__ import annotations

import random
from typing import Dict, List, Tuple

from repro.envs.base import LatencyProfile, TextEnv

CATEGORIES = ["shoes", "lamp", "mug", "jacket", "keyboard", "chair"]
COLORS = ["red", "blue", "black", "white", "green"]


class WebShopEnv(TextEnv):
    TASK = "webshop"
    MODALITY = "text"
    MAX_TURNS = 30
    LATENCY = LatencyProfile(reset_mean_s=5.0, step_mean_s=0.8,
                             step_tail_prob=0.02, step_tail_s=(2.0, 15.0),
                             reset_failure_prob=0.003,
                             step_failure_prob=0.0003)

    def __init__(self, seed: int = 0, catalog_size: int = 30):
        super().__init__(seed)
        self.catalog_size = catalog_size
        self.catalog: List[Dict] = []
        self.target: Dict = {}
        self.results: List[int] = []
        self.viewing = -1

    def _reset(self) -> str:
        self.catalog = [
            {"id": i,
             "cat": self.rng.choice(CATEGORIES),
             "color": self.rng.choice(COLORS),
             "price": self.rng.randint(5, 200)}
            for i in range(self.catalog_size)]
        self.target = self.rng.choice(self.catalog)
        self.results, self.viewing = [], -1
        return (f"Find and buy: a {self.target['color']} "
                f"{self.target['cat']} under ${self.target['price'] + 10}. "
                "Actions: 'search: <words>', 'click: <id>', 'buy'.")

    def _step(self, action: str) -> Tuple[str, float, bool, Dict]:
        a = action.strip().lower()
        if "search:" in a:
            q = a.split("search:", 1)[1].strip()
            self.results = [p["id"] for p in self.catalog
                            if p["cat"] in q or p["color"] in q][:5]
            if not self.results:
                return "no results.", -0.02, False, {}
            lines = [f"[{i}] {self.catalog[i]['color']} "
                     f"{self.catalog[i]['cat']} ${self.catalog[i]['price']}"
                     for i in self.results]
            return "results:\n" + "\n".join(lines), 0.0, False, {}
        if "click:" in a:
            try:
                pid = int(a.split("click:", 1)[1].strip().split()[0])
            except (ValueError, IndexError):
                return "bad id.", -0.02, False, {}
            if pid not in range(self.catalog_size):
                return "unknown product.", -0.02, False, {}
            self.viewing = pid
            p = self.catalog[pid]
            return (f"viewing [{pid}]: {p['color']} {p['cat']} "
                    f"${p['price']}. 'buy' to purchase."), 0.0, False, {}
        if "buy" in a:
            if self.viewing < 0:
                return "nothing selected.", -0.05, False, {}
            p = self.catalog[self.viewing]
            hit = (p["cat"] == self.target["cat"]
                   and p["color"] == self.target["color"])
            return ("purchased. " + ("correct item!" if hit else
                                     "wrong item."),
                    1.0 if hit else 0.1, True, {})
        return "unknown action.", -0.02, False, {"invalid": True}

"""FrozenLake (Table 1: game, 20-100 turns, prefill-heavy): a real 4x4/8x8
gridworld with slippery ice, rendered as text. The agent must reach G
avoiding holes H. Many short turns with a growing observation history make
this the paper's canonical prefill-heavy task.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.envs.base import LatencyProfile, TextEnv

MAPS = {
    4: ["SFFF", "FHFH", "FFFH", "HFFG"],
    8: ["SFFFFFFF", "FFFFFFFF", "FFFHFFFF", "FFFFFHFF",
        "FFFHFFFF", "FHHFFFHF", "FHFFHFHF", "FFFHFFFG"],
}
ACTIONS = {"left": (0, -1), "down": (1, 0), "right": (0, 1), "up": (-1, 0)}


class FrozenLakeEnv(TextEnv):
    TASK = "frozenlake"
    MODALITY = "text+visual"
    MAX_TURNS = 100
    LATENCY = LatencyProfile(reset_mean_s=3.0, step_mean_s=0.2,
                             step_tail_prob=0.005, step_tail_s=(2.0, 10.0),
                             reset_failure_prob=0.002,
                             step_failure_prob=0.0002)

    def __init__(self, seed: int = 0, size: int = 4, slippery: bool = False):
        super().__init__(seed)
        self.size = size
        self.grid = MAPS[size]
        self.slippery = slippery
        self.pos = (0, 0)

    def _render(self) -> str:
        rows = []
        for r, row in enumerate(self.grid):
            line = "".join("A" if (r, c) == self.pos else ch
                           for c, ch in enumerate(row))
            rows.append(line)
        return "\n".join(rows)

    def _reset(self) -> str:
        self.pos = (0, 0)
        return (f"FrozenLake {self.size}x{self.size}. Reach G, avoid H. "
                f"Actions: left/down/right/up.\n{self._render()}\nmove:")

    def _parse(self, action: str):
        a = action.strip().lower()
        for name in ACTIONS:
            if name in a:
                return name
        return None

    def _step(self, action: str) -> Tuple[str, float, bool, Dict]:
        name = self._parse(action)
        if name is None:
            return (f"invalid action.\n{self._render()}\nmove:",
                    -0.05, False, {"invalid": True})
        dr, dc = ACTIONS[name]
        if self.slippery and self.rng.random() < 0.2:
            dr, dc = self.rng.choice(list(ACTIONS.values()))
        r = min(max(self.pos[0] + dr, 0), self.size - 1)
        c = min(max(self.pos[1] + dc, 0), self.size - 1)
        self.pos = (r, c)
        cell = self.grid[r][c]
        if cell == "H":
            return f"fell in a hole at {r},{c}.", -1.0, True, {}
        if cell == "G":
            return "reached the goal!", 1.0, True, {}
        return f"{self._render()}\nmove:", -0.01, False, {}

"""Agentic environment interface + latency/failure injection.

Environments are real, stateful Python processes (the paper's Table 1
taxonomy). ``LatencyProfile`` models the §3 characterization — heavy-tailed
env.reset (Docker pulls, host contention) and env.step, plus outright
failures (~1/10 iterations in production) — and is used by both the live
runner (as bookkeeping) and the discrete-event simulator (as virtual time).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, Optional, Tuple


@dataclasses.dataclass
class LatencyProfile:
    """Long-tail latency + failure model for env.reset / env.step."""
    reset_mean_s: float = 8.0
    reset_tail_prob: float = 0.05
    reset_tail_s: Tuple[float, float] = (60.0, 300.0)   # uniform range
    step_mean_s: float = 1.0
    step_tail_prob: float = 0.03
    step_tail_s: Tuple[float, float] = (5.0, 60.0)
    reset_failure_prob: float = 0.01
    step_failure_prob: float = 0.002

    def sample_reset(self, rng: random.Random) -> Tuple[float, bool]:
        """Returns (latency_s, failed)."""
        if rng.random() < self.reset_failure_prob:
            return rng.uniform(*self.reset_tail_s), True
        if rng.random() < self.reset_tail_prob:
            return rng.uniform(*self.reset_tail_s), False
        return max(0.1, rng.expovariate(1.0 / self.reset_mean_s)), False

    def sample_step(self, rng: random.Random) -> Tuple[float, bool]:
        if rng.random() < self.step_failure_prob:
            return rng.uniform(*self.step_tail_s), True
        if rng.random() < self.step_tail_prob:
            return rng.uniform(*self.step_tail_s), False
        return max(0.01, rng.expovariate(1.0 / self.step_mean_s)), False


class EnvError(RuntimeError):
    """Environment failure (timeout, container crash, ...)."""


class TextEnv:
    """Multi-turn text environment: observations and actions are strings."""

    TASK = "generic"
    MODALITY = "text"
    MAX_TURNS = 10
    LATENCY = LatencyProfile()

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self.turns = 0
        self.done = False
        self.total_reward = 0.0

    # -- API -----------------------------------------------------------
    def reset(self, seed: Optional[int] = None) -> str:
        """Initialize; returns the first observation (prompt)."""
        if seed is not None:
            self.rng = random.Random(seed)
        self.turns = 0
        self.done = False
        self.total_reward = 0.0
        return self._reset()

    def step(self, action: str) -> Tuple[str, float, bool, Dict]:
        """Apply an action; returns (observation, reward, done, info)."""
        if self.done:
            raise EnvError("step() on finished environment")
        self.turns += 1
        obs, reward, done, info = self._step(action)
        self.total_reward += reward
        if self.turns >= self.MAX_TURNS:
            done = True
        self.done = done
        return obs, reward, done, info

    # -- to implement ----------------------------------------------------
    def _reset(self) -> str:
        raise NotImplementedError

    def _step(self, action: str) -> Tuple[str, float, bool, Dict]:
        raise NotImplementedError

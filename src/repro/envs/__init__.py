from repro.envs.base import EnvError, LatencyProfile, TextEnv
from repro.envs.frozen_lake import FrozenLakeEnv
from repro.envs.game_env import GameEnv
from repro.envs.math_env import MathEnv
from repro.envs.swe_sim import SWEEnv
from repro.envs.webshop_sim import WebShopEnv

ENV_CLASSES = {
    "frozenlake": FrozenLakeEnv,
    "math": MathEnv,
    "webshop": WebShopEnv,
    "swe": SWEEnv,
    "game": GameEnv,
}


def make_env(task: str, seed: int = 0) -> TextEnv:
    return ENV_CLASSES[task](seed=seed)

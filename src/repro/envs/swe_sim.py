"""SWE-bench-style environment (Table 1: SWE, 30-50 turns): the agent
explores a tiny repository (ls/cat/grep), then submits a patch fixing an
injected single-line bug. Containerized-sandbox behavior — the heaviest
reset latency and the highest failure rates of the taxonomy — is modeled by
its LatencyProfile (env.reset tails of hundreds of seconds, §3 Fig. 3/5).
"""
from __future__ import annotations

import random
from typing import Dict, List, Tuple

from repro.envs.base import LatencyProfile, TextEnv

_FILES = {
    "calc.py": [
        "def add(a, b):",
        "    return a + b",
        "",
        "def mul(a, b):",
        "    return a * b",
    ],
    "utils.py": [
        "def clamp(x, lo, hi):",
        "    return max(lo, min(x, hi))",
        "",
        "def mean(xs):",
        "    return sum(xs) / len(xs)",
    ],
}
_BUGS = [
    ("calc.py", 1, "    return a - b", "    return a + b"),
    ("calc.py", 4, "    return a + b", "    return a * b"),
    ("utils.py", 1, "    return min(lo, max(x, hi))",
     "    return max(lo, min(x, hi))"),
    ("utils.py", 4, "    return sum(xs) * len(xs)",
     "    return sum(xs) / len(xs)"),
]


class SWEEnv(TextEnv):
    TASK = "swe"
    MODALITY = "text"
    MAX_TURNS = 50
    LATENCY = LatencyProfile(reset_mean_s=25.0, reset_tail_prob=0.08,
                             reset_tail_s=(60.0, 200.0),
                             step_mean_s=2.0, step_tail_prob=0.02,
                             step_tail_s=(5.0, 30.0),
                             reset_failure_prob=0.01,
                             step_failure_prob=0.0005)

    def __init__(self, seed: int = 0):
        super().__init__(seed)
        self.files: Dict[str, List[str]] = {}
        self.bug = _BUGS[0]

    def _reset(self) -> str:
        self.files = {k: list(v) for k, v in _FILES.items()}
        self.bug = self.rng.choice(_BUGS)
        fname, line, broken, _ = self.bug
        self.files[fname][line] = broken
        return ("A test is failing in this repo. Find and fix the bug.\n"
                "Actions: 'ls', 'cat: <file>', "
                "'patch: <file>:<line>:<new code>', 'submit'.")

    def _step(self, action: str) -> Tuple[str, float, bool, Dict]:
        a = action.strip()
        low = a.lower()
        if low.startswith("ls") or " ls" in low[:6]:
            return " ".join(sorted(self.files)), 0.0, False, {}
        if "cat:" in low:
            # empty payload ("cat:" with no filename) is a malformed
            # action, not a crash
            words = a.split(":", 1)[1].strip().split()
            if not words:
                return "cat needs a filename.", -0.02, False, {}
            fname = words[0]
            if fname not in self.files:
                return f"no such file {fname}.", -0.02, False, {}
            body = "\n".join(f"{i}: {l}"
                             for i, l in enumerate(self.files[fname]))
            return body, 0.0, False, {}
        if "patch:" in low:
            try:
                payload = a.split("patch:", 1)[1]
                fname, line_s, code = payload.split(":", 2)
                fname, line = fname.strip(), int(line_s)
                self.files[fname][line] = code.rstrip("\n")
                return f"patched {fname}:{line}.", 0.0, False, {}
            except Exception:
                return "malformed patch.", -0.05, False, {}
        if "submit" in low:
            fname, line, _, fixed = self.bug
            ok = self.files[fname][line].strip() == fixed.strip()
            return ("tests pass!" if ok else "tests still fail."), \
                (1.0 if ok else 0.0), True, {}
        return "unknown command.", -0.02, False, {"invalid": True}

from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES, axis_rules,
                                        param_sharding, resolve_spec, shd)

"""Logical-axis sharding policy.

Model code annotates activations with *logical* axis names via ``shd(x, ...)``
and parameters are assigned logical axes by path-based rules. A rule set maps
logical names -> mesh axes; two built-in rule sets implement the two regimes
from DESIGN.md §5:

- ``TRAIN_RULES``  : FSDP("data") x TP("model"), batch over ("pod","data").
- ``SERVE_RULES``  : TP("model") for weights, batch->"data", cache seq->"model".

Outside a mesh context (CPU smoke tests) every annotation is a no-op.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# rule sets: logical axis name -> mesh axis (or tuple of mesh axes) or None
# ---------------------------------------------------------------------------

TRAIN_RULES: Dict[str, Any] = {
    # activations ("seq" -> "model" is Megatron-style sequence parallelism on
    # the residual stream: scan-carry checkpoints stay sharded, which is what
    # lets 1M-token batches of the large archs fit v5e HBM)
    "batch": ("pod", "data"),
    "seq": "model",
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_experts": "model",
    "moe_group": ("pod", "data", "model"),
    "cache_seq": None,
    # parameters (FSDP over "data", TP over "model")
    "vocab": "model",
    "embed": "data",          # FSDP shard of the d_model dim
    "heads": "model",
    "kv_heads": "model",
    "qkv_in": "data",
    "mlp": "model",
    "mlp_in": "data",
    "experts": "model",
    "expert_mlp": None,
    "mamba_inner": "model",
    "mamba_in": "data",
    "rwkv_out": "model",
    "rwkv_in": "data",
    "ssm_state": None,
}

SERVE_RULES: Dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_experts": "model",
    "moe_group": ("pod", "data", "model"),
    "cache_seq": "model",     # KV cache sequence dim sharded over model axis
    "cache_kv_heads": None,   # cache seq takes the model axis, not kv heads
    "rwkv_heads": "model",
    # parameters: TP on "model" + 2-D weight-stationary sharding over "data"
    # (MaxText-style serving layout; without it 100B-class archs do not fit
    # 16 GiB/chip at 16-way TP)
    "vocab": "model",
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "qkv_in": "data",
    "mlp": "model",
    "mlp_in": "data",
    "experts": "model",
    "expert_mlp": "data",
    "mamba_inner": "model",
    "mamba_in": "data",
    "rwkv_out": "model",
    "rwkv_in": "data",
    "ssm_state": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Any] = {}


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Dict[str, Any]):
    """Activate a (mesh, logical-rules) context for model tracing."""
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, dict(rules)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old


def _mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def resolve_spec(logical: Sequence[Optional[str]],
                 rules: Dict[str, Any],
                 mesh: Optional[Mesh]) -> P:
    """Map a tuple of logical names (or None) to a PartitionSpec."""
    axes_avail = set(_mesh_axes(mesh)) if mesh is not None else set()
    used = set()
    out = []
    for name in logical:
        if name is None:
            out.append(None)
            continue
        ax = rules.get(name, None)
        if ax is None:
            out.append(None)
            continue
        if isinstance(ax, (tuple, list)):
            ax_t = tuple(a for a in ax if a in axes_avail and a not in used)
            used.update(ax_t)
            out.append(ax_t if ax_t else None)
        else:
            if ax in axes_avail and ax not in used:
                used.add(ax)
                out.append(ax)
            else:
                out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def fit_spec(shape: Sequence[int], spec: P, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes do not divide evenly."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        if n <= 1 or shape[i] % n != 0:
            # try a prefix of the axes that still divides
            kept = []
            n = 1
            for a in axes:
                if shape[i] % (n * sizes.get(a, 1)) == 0 and sizes.get(a, 1) > 1:
                    kept.append(a)
                    n *= sizes.get(a, 1)
            out.append(tuple(kept) if len(kept) > 1 else
                       (kept[0] if kept else None))
        else:
            out.append(entry)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sharding_active() -> bool:
    return _CTX.mesh is not None and bool(_CTX.rules)


def shd(x, *logical: Optional[str]):
    """with_sharding_constraint by logical names; no-op without a mesh ctx."""
    if _CTX.mesh is None or not _CTX.rules:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank {x.ndim} vs logical {logical}")
    spec = resolve_spec(logical, _CTX.rules, _CTX.mesh)
    spec = fit_spec(x.shape, spec, _CTX.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


# ---------------------------------------------------------------------------
# parameter logical axes, by path pattern
# ---------------------------------------------------------------------------
# Patterns are matched against "/".join(path). First match wins. Entries map
# to a tuple of logical names aligned with the array shape, where a leading
# "*" means "leave leading (stacked-layer) dims unsharded".

PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed/tokens$",            ("vocab", "embed")),
    (r"lm_head/w$",               ("embed", "vocab")),
    (r".*attn/wq$",               ("*", "qkv_in", "heads", None)),
    (r".*attn/wk$",               ("*", "qkv_in", "kv_heads", None)),
    (r".*attn/wv$",               ("*", "qkv_in", "kv_heads", None)),
    (r".*attn/wo$",               ("*", "heads", None, "qkv_in")),
    (r".*attn/(q_norm|k_norm)$",  ("*", None)),
    (r".*mlp/w_gate$",            ("*", "mlp_in", "mlp")),
    (r".*mlp/w_up$",              ("*", "mlp_in", "mlp")),
    (r".*mlp/w_down$",            ("*", "mlp", "mlp_in")),
    (r".*moe/router$",            ("*", "mlp_in", None)),
    (r".*moe/w_gate$",            ("*", "experts", "mlp_in", "expert_mlp")),
    (r".*moe/w_up$",              ("*", "experts", "mlp_in", "expert_mlp")),
    (r".*moe/w_down$",            ("*", "experts", "expert_mlp", "mlp_in")),
    (r".*moe/shared_.*$",         ("*", "mlp_in", "mlp")),
    (r".*moe/shared_down$",       ("*", "mlp", "mlp_in")),
    (r".*mamba/in_proj$",         ("*", "mamba_in", "mamba_inner")),
    (r".*mamba/conv_w$",          ("*", None, "mamba_inner")),
    (r".*mamba/conv_b$",          ("*", "mamba_inner")),
    (r".*mamba/x_proj$",          ("*", "mamba_inner", None)),
    (r".*mamba/dt_proj$",         ("*", None, "mamba_inner")),
    (r".*mamba/dt_bias$",         ("*", "mamba_inner")),
    (r".*mamba/A_log$",           ("*", "mamba_inner", "ssm_state")),
    (r".*mamba/D$",               ("*", "mamba_inner")),
    (r".*mamba/out_proj$",        ("*", "mamba_inner", "mamba_in")),
    (r".*rwkv/w[rkvg]$",          ("*", "rwkv_in", "rwkv_out")),
    (r".*rwkv/wo$",               ("*", "rwkv_out", "rwkv_in")),
    (r".*rwkv/(decay_w1)$",       ("*", "rwkv_in", None)),
    (r".*rwkv/(decay_w2)$",       ("*", None, "rwkv_out")),
    (r".*rwkv/(decay_bias|bonus)$", ("*", "rwkv_out")),
    (r".*(norm|scale)",           ("*", None)),
    (r".*",                       ()),  # fallback: replicate
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def logical_axes_for_path(path, ndim: int) -> Tuple[Optional[str], ...]:
    s = _path_str(path)
    for pat, axes in PARAM_RULES:
        if re.search(pat, s):
            if not axes:
                return (None,) * ndim
            if axes[0] == "*":
                tail = axes[1:]
                pad = ndim - len(tail)
                if pad < 0:  # array has fewer dims than rule tail (unstacked)
                    return tail[-ndim:]
                return (None,) * pad + tail
            if len(axes) != ndim:
                pad = ndim - len(axes)
                return ((None,) * pad + axes) if pad > 0 else axes[-ndim:]
            return axes
    return (None,) * ndim


def param_sharding(params, mesh: Mesh, rules: Dict[str, Any]):
    """NamedSharding pytree for a parameter (or ShapeDtypeStruct) pytree."""
    def one(path, leaf):
        axes = logical_axes_for_path(path, np.ndim(leaf))
        spec = resolve_spec(axes, rules, mesh)
        spec = fit_spec(np.shape(leaf), spec, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params)


def param_spec_tree(params_shape, mesh, rules):
    """Same as param_sharding but over a ShapeDtypeStruct tree."""
    return param_sharding(params_shape, mesh, rules)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())

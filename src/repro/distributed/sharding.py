"""Logical-axis sharding policy.

Model code annotates activations with *logical* axis names via ``shd(x, ...)``
and parameters are assigned logical axes by path-based rules. A rule set maps
logical names -> mesh axes; two built-in rule sets implement the two regimes
from DESIGN.md §5:

- ``TRAIN_RULES``  : FSDP("data") x TP("model"), batch over ("pod","data").
- ``SERVE_RULES``  : TP("model") for weights, batch->"data", cache seq->"model".

Outside a mesh context (CPU smoke tests) every annotation is a no-op.
"""
from __future__ import annotations

import contextlib
import re
import threading
import warnings
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# rule sets: logical axis name -> mesh axis (or tuple of mesh axes) or None
# ---------------------------------------------------------------------------

TRAIN_RULES: Dict[str, Any] = {
    # activations ("seq" -> "model" is Megatron-style sequence parallelism on
    # the residual stream: scan-carry checkpoints stay sharded, which is what
    # lets 1M-token batches of the large archs fit v5e HBM)
    "batch": ("pod", "data"),
    "seq": "model",
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_experts": "model",
    "moe_group": ("pod", "data", "model"),
    "cache_seq": None,
    "cache_page_seq": None,
    # parameters (FSDP over "data", TP over "model")
    "vocab": "model",
    "embed": "data",          # FSDP shard of the d_model dim
    "heads": "model",
    "kv_heads": "model",
    "qkv_in": "data",
    "mlp": "model",
    "mlp_in": "data",
    "experts": "model",
    "expert_mlp": None,
    "mamba_inner": "model",
    "mamba_in": "data",
    "rwkv_out": "model",
    "rwkv_in": "data",
    "ssm_state": None,
}

SERVE_RULES: Dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_experts": "model",
    "moe_group": ("pod", "data", "model"),
    "cache_seq": "model",     # KV cache sequence dim sharded over model axis
    "cache_kv_heads": None,   # cache seq takes the model axis, not kv heads
    # paged pool: within-page positions shard over the group, mirroring
    # the dense cache_seq layout at page granularity (page_size must be
    # divisible by the group or fit_spec drops the dim to replicated)
    "cache_page_seq": "model",
    "rwkv_heads": "model",
    # parameters: TP on "model" + 2-D weight-stationary sharding over "data"
    # (MaxText-style serving layout; without it 100B-class archs do not fit
    # 16 GiB/chip at 16-way TP)
    "vocab": "model",
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "qkv_in": "data",
    "mlp": "model",
    "mlp_in": "data",
    "experts": "model",
    "expert_mlp": "data",
    "mamba_inner": "model",
    "mamba_in": "data",
    "rwkv_out": "model",
    "rwkv_in": "data",
    "ssm_state": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Any] = {}
        self.on_drop: Optional[Callable[[], None]] = None


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Dict[str, Any],
               on_drop: Optional[Callable[[], None]] = None):
    """Activate a (mesh, logical-rules) context for model tracing.

    ``on_drop`` (optional) is called once per dimension whose requested
    sharding ``fit_spec`` has to drop because the mesh axes do not divide
    it — engines use it to surface a per-engine drop counter in
    ``stats()`` (see ``ShardingDropWarning``)."""
    old = (_CTX.mesh, _CTX.rules, _CTX.on_drop)
    _CTX.mesh, _CTX.rules, _CTX.on_drop = mesh, dict(rules), on_drop
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules, _CTX.on_drop = old


def _mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def resolve_spec(logical: Sequence[Optional[str]],
                 rules: Dict[str, Any],
                 mesh: Optional[Mesh]) -> P:
    """Map a tuple of logical names (or None) to a PartitionSpec."""
    axes_avail = set(_mesh_axes(mesh)) if mesh is not None else set()
    return _resolve_spec_avail(logical, rules, axes_avail)


def _resolve_spec_avail(logical: Sequence[Optional[str]],
                        rules: Dict[str, Any],
                        axes_avail: set) -> P:
    """``resolve_spec`` against an explicit set of available mesh axes."""
    used = set()
    out = []
    for name in logical:
        if name is None:
            out.append(None)
            continue
        ax = rules.get(name, None)
        if ax is None:
            out.append(None)
            continue
        if isinstance(ax, (tuple, list)):
            ax_t = tuple(a for a in ax if a in axes_avail and a not in used)
            used.update(ax_t)
            out.append(ax_t if ax_t else None)
        else:
            if ax in axes_avail and ax not in used:
                used.add(ax)
                out.append(ax)
            else:
                out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


class ShardingDropWarning(UserWarning):
    """``fit_spec`` dropped a requested sharding because the mesh axes do
    not divide the dimension evenly (e.g. ``num_kv_heads=2`` at 4-way TP).
    Emitted ONCE per distinct (shape, spec, mesh-sizes) so a misconfigured
    TP degree is visible without flooding every trace."""


_DROP_LOCK = threading.Lock()
_DROP_EVENTS = 0                         # guarded by: _DROP_LOCK
_DROP_WARNED: set = set()                # guarded by: _DROP_LOCK


def dropped_dim_events() -> int:
    """Process-wide count of dims whose sharding ``fit_spec`` dropped."""
    with _DROP_LOCK:
        return _DROP_EVENTS


def reset_drop_state():
    """Test hook: clear the drop counter and the once-per-key warn set."""
    global _DROP_EVENTS
    with _DROP_LOCK:
        _DROP_EVENTS = 0
        _DROP_WARNED.clear()


def _note_drop(shape, dim: int, entry, sizes: Dict[str, int]):
    """Record one dropped-dim event: bump the module counter, warn once
    per structural key, and notify the active context's ``on_drop``."""
    global _DROP_EVENTS
    key = (tuple(shape), dim, entry if not isinstance(entry, list)
           else tuple(entry), tuple(sorted(sizes.items())))
    with _DROP_LOCK:
        _DROP_EVENTS += 1
        first = key not in _DROP_WARNED
        _DROP_WARNED.add(key)
    if first:
        warnings.warn(
            f"fit_spec dropped sharding {entry!r} on dim {dim} of shape "
            f"{tuple(shape)}: mesh axis sizes {sizes} do not divide "
            f"{shape[dim]} — the dim is replicated instead "
            "(misconfigured TP degree?)",
            ShardingDropWarning, stacklevel=3)
    if _CTX.on_drop is not None:
        _CTX.on_drop()


def _fit_spec_sizes(shape: Sequence[int], spec: P,
                    sizes: Dict[str, int]) -> P:
    """``fit_spec`` against explicit axis sizes (no Mesh needed)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        if n <= 1 or shape[i] % n != 0:
            # try a prefix of the axes that still divides
            kept = []
            k = 1
            for a in axes:
                if shape[i] % (k * sizes.get(a, 1)) == 0 \
                        and sizes.get(a, 1) > 1:
                    kept.append(a)
                    k *= sizes.get(a, 1)
            if n > 1 and k < n:
                # sharding was actually requested (product of available
                # axis sizes > 1) and could not be fully honored
                _note_drop(shape, i, entry, sizes)
            out.append(tuple(kept) if len(kept) > 1 else
                       (kept[0] if kept else None))
        else:
            out.append(entry)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def fit_spec(shape: Sequence[int], spec: P, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes do not divide evenly. Each
    dropped dim is counted (``dropped_dim_events``), warned once
    (``ShardingDropWarning``), and reported to the active ``axis_rules``
    context's ``on_drop`` hook."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return _fit_spec_sizes(shape, spec, sizes)


def sharding_active() -> bool:
    return _CTX.mesh is not None and bool(_CTX.rules)


def shd(x, *logical: Optional[str]):
    """with_sharding_constraint by logical names; no-op without a mesh ctx."""
    if _CTX.mesh is None or not _CTX.rules:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank {x.ndim} vs logical {logical}")
    spec = resolve_spec(logical, _CTX.rules, _CTX.mesh)
    spec = fit_spec(x.shape, spec, _CTX.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


# ---------------------------------------------------------------------------
# parameter logical axes, by path pattern
# ---------------------------------------------------------------------------
# Patterns are matched against "/".join(path). First match wins. Entries map
# to a tuple of logical names aligned with the array shape, where a leading
# "*" means "leave leading (stacked-layer) dims unsharded".

PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed/tokens$",            ("vocab", "embed")),
    (r"lm_head/w$",               ("embed", "vocab")),
    (r".*attn/wq$",               ("*", "qkv_in", "heads", None)),
    (r".*attn/wk$",               ("*", "qkv_in", "kv_heads", None)),
    (r".*attn/wv$",               ("*", "qkv_in", "kv_heads", None)),
    (r".*attn/wo$",               ("*", "heads", None, "qkv_in")),
    (r".*attn/(q_norm|k_norm)$",  ("*", None)),
    (r".*mlp/w_gate$",            ("*", "mlp_in", "mlp")),
    (r".*mlp/w_up$",              ("*", "mlp_in", "mlp")),
    (r".*mlp/w_down$",            ("*", "mlp", "mlp_in")),
    (r".*moe/router$",            ("*", "mlp_in", None)),
    (r".*moe/w_gate$",            ("*", "experts", "mlp_in", "expert_mlp")),
    (r".*moe/w_up$",              ("*", "experts", "mlp_in", "expert_mlp")),
    (r".*moe/w_down$",            ("*", "experts", "expert_mlp", "mlp_in")),
    (r".*moe/shared_.*$",         ("*", "mlp_in", "mlp")),
    (r".*moe/shared_down$",       ("*", "mlp", "mlp_in")),
    (r".*mamba/in_proj$",         ("*", "mamba_in", "mamba_inner")),
    (r".*mamba/conv_w$",          ("*", None, "mamba_inner")),
    (r".*mamba/conv_b$",          ("*", "mamba_inner")),
    (r".*mamba/x_proj$",          ("*", "mamba_inner", None)),
    (r".*mamba/dt_proj$",         ("*", None, "mamba_inner")),
    (r".*mamba/dt_bias$",         ("*", "mamba_inner")),
    (r".*mamba/A_log$",           ("*", "mamba_inner", "ssm_state")),
    (r".*mamba/D$",               ("*", "mamba_inner")),
    (r".*mamba/out_proj$",        ("*", "mamba_inner", "mamba_in")),
    (r".*rwkv/w[rkvg]$",          ("*", "rwkv_in", "rwkv_out")),
    (r".*rwkv/wo$",               ("*", "rwkv_out", "rwkv_in")),
    (r".*rwkv/(decay_w1)$",       ("*", "rwkv_in", None)),
    (r".*rwkv/(decay_w2)$",       ("*", None, "rwkv_out")),
    (r".*rwkv/(decay_bias|bonus)$", ("*", "rwkv_out")),
    (r".*(norm|scale)",           ("*", None)),
    (r".*",                       ()),  # fallback: replicate
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def logical_axes_for_path(path, ndim: int) -> Tuple[Optional[str], ...]:
    s = _path_str(path)
    for pat, axes in PARAM_RULES:
        if re.search(pat, s):
            if not axes:
                return (None,) * ndim
            if axes[0] == "*":
                tail = axes[1:]
                pad = ndim - len(tail)
                if pad < 0:  # array has fewer dims than rule tail (unstacked)
                    return tail[-ndim:]
                return (None,) * pad + tail
            if len(axes) != ndim:
                pad = ndim - len(axes)
                return ((None,) * pad + axes) if pad > 0 else axes[-ndim:]
            return axes
    return (None,) * ndim


def param_sharding(params, mesh: Mesh, rules: Dict[str, Any]):
    """NamedSharding pytree for a parameter (or ShapeDtypeStruct) pytree."""
    def one(path, leaf):
        axes = logical_axes_for_path(path, np.ndim(leaf))
        spec = resolve_spec(axes, rules, mesh)
        spec = fit_spec(np.shape(leaf), spec, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params)


def param_spec_tree(params_shape, mesh, rules):
    """Same as param_sharding but over a ShapeDtypeStruct tree."""
    return param_sharding(params_shape, mesh, rules)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def place_params(params, mesh: Mesh, rules: Dict[str, Any]):
    """Place a host/device param pytree onto ``mesh`` with the rule set's
    NamedShardings (each leaf lands as shards, never via a whole-array
    single-device copy)."""
    return jax.device_put(params, param_sharding(params, mesh, rules))


# ---------------------------------------------------------------------------
# engine-group helpers: what a TP group of size n shards, without a Mesh
# ---------------------------------------------------------------------------
# An engine group is a (1, n) ("data", "model") mesh, so "model" carries
# the whole group and "data"/"pod" collapse to size 1. These helpers
# answer sharding questions for such a group from axis sizes alone, which
# lets the weight store chunk params per-shard on the TRAINER side without
# ever building (or importing) the engines' meshes.

def _group_sizes(n: int) -> Dict[str, int]:
    return {"pod": 1, "data": 1, "model": int(n)}


def model_axis_dims(params, n: int,
                    rules: Dict[str, Any] = None) -> List[Optional[int]]:
    """Per-leaf (``jax.tree.leaves`` order) index of the dim an n-way
    engine group shards over its "model" axis under ``rules``
    (default SERVE_RULES), or None when the leaf replicates. Divisibility
    is honored exactly like ``fit_spec`` (non-divisible dims fall back to
    replication), so the chunking this drives always matches the
    placement the engines compute."""
    rules = SERVE_RULES if rules is None else rules
    sizes = _group_sizes(n)
    avail = {a for a, s in sizes.items() if s > 1}
    out: List[Optional[int]] = []

    def one(path, leaf):
        axes = logical_axes_for_path(path, np.ndim(leaf))
        spec = _resolve_spec_avail(axes, rules, avail)
        spec = _fit_spec_sizes(np.shape(leaf), spec, sizes)
        dim = next((i for i, e in enumerate(spec)
                    if e == "model" or (isinstance(e, tuple)
                                        and "model" in e)), None)
        out.append(dim)
        return leaf

    jax.tree_util.tree_map_with_path(one, params)
    return out


def validate_group(params, n: int, rules: Dict[str, Any] = None,
                   model_name: str = "") -> int:
    """Raise unless an n-way engine group actually shards ``params``.

    ``devices_per_engine`` used to be a silent no-op; now a group size
    whose "model" axis divides NO parameter dim (so every leaf would
    replicate and the group buys nothing but n-fold memory) is rejected
    loudly. Returns the number of sharded leaves on success."""
    if n <= 1:
        return 0
    dims = model_axis_dims(params, n, rules)
    sharded = sum(d is not None for d in dims)
    if sharded == 0:
        raise ValueError(
            f"devices_per_engine={n} shards nothing"
            + (f" of model {model_name!r}" if model_name else "")
            + f": no parameter dim of the {len(dims)} leaves is divisible "
            f"by {n} under the serve rules (head/expert/mlp/vocab dims "
            "must divide the group size) — the group would replicate the "
            "full model n-fold for zero parallelism. Pick a group size "
            "that divides the sharded dims, or use devices_per_engine=1.")
    return sharded

"""Data pipeline: trajectory packing for RL batches + a synthetic LM corpus
for the quickstart pretraining example + the multi-task sampler the paper's
evaluation uses (uniform task sampling, §7.1)."""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Trajectory:
    """One multi-turn rollout, token-aligned for training.

    ``loss_mask[i] == 1`` iff tokens[i] was produced by the policy (action
    tokens); environment observations are masked out. ``logprobs`` align with
    action tokens (0 elsewhere).
    """
    traj_id: str
    task: str
    tokens: List[int]
    loss_mask: List[int]
    logprobs: List[float]
    reward: float = 0.0
    group_id: str = ""
    start_version: int = 0        # weight version at trajectory start
    version: int = 0              # weight version at completion
    turns: int = 0
    seq: int = -1                 # monotonic arrival number, stamped by
                                  # SampleBuffer.put (FIFO tie-break; the
                                  # lexicographic traj_id is NOT ordered:
                                  # "t10" < "t2")
    meta: Dict = dataclasses.field(default_factory=dict)


def pack_batch(trajs: Sequence[Trajectory], seq_len: int,
               pad_id: int = 0) -> Dict[str, np.ndarray]:
    """Pack trajectories into fixed [B, seq_len] arrays for train_step."""
    B = len(trajs)
    tokens = np.full((B, seq_len), pad_id, np.int32)
    mask = np.zeros((B, seq_len), np.float32)
    blp = np.zeros((B, seq_len - 1), np.float32)
    adv = np.zeros((B,), np.float32)
    for i, t in enumerate(trajs):
        n = min(len(t.tokens), seq_len)
        tokens[i, :n] = t.tokens[:n]
        mask[i, :n] = t.loss_mask[:n]
        lp = np.zeros(len(t.tokens), np.float32)
        lp[: len(t.logprobs)] = 0.0
        # logprobs are recorded per token (0 for observation tokens)
        m = min(len(t.logprobs), len(t.tokens))
        lp[:m] = t.logprobs[:m]
        blp[i, : n - 1] = lp[1:n]
        adv[i] = t.reward
    return {"tokens": tokens, "loss_mask": mask,
            "behavior_logprobs": blp, "advantages": adv}


def group_advantages(trajs: Sequence[Trajectory], group_size: int,
                     eps: float = 1e-6) -> np.ndarray:
    """GRPO group-normalized advantages over contiguous groups."""
    r = np.asarray([t.reward for t in trajs], np.float32)
    g = r.reshape(-1, group_size)
    a = (g - g.mean(1, keepdims=True)) / (g.std(1, keepdims=True) + eps)
    return a.reshape(-1)


# ---------------------------------------------------------------------------
# synthetic LM corpus (quickstart)
# ---------------------------------------------------------------------------

_WORDS = ("the agent moves toward reward while the environment returns "
          "observation state action value policy gradient rollout train "
          "sample buffer weight sync pod mesh shard expert decode prefill"
          ).split()


def synthetic_corpus(n_docs: int, seed: int = 0) -> List[str]:
    rng = random.Random(seed)
    docs = []
    for _ in range(n_docs):
        n = rng.randint(8, 40)
        docs.append(" ".join(rng.choice(_WORDS) for _ in range(n)))
    return docs


def lm_batches(tokenizer, seq_len: int, batch: int, n_steps: int,
               seed: int = 0):
    """Yield packed {tokens, mask} LM batches from the synthetic corpus."""
    rng = random.Random(seed)
    docs = synthetic_corpus(max(64, batch * 4), seed)
    stream: List[int] = []
    for step in range(n_steps):
        tokens = np.zeros((batch, seq_len), np.int32)
        for b in range(batch):
            while len(stream) < seq_len:
                stream.extend(tokenizer.encode(rng.choice(docs), bos=True,
                                               eos=True))
            tokens[b] = stream[:seq_len]
            del stream[:seq_len]
        yield {"tokens": tokens}


class TaskSampler:
    """Multi-task sampler (paper §7.1): uniform when ``weights`` is None,
    weighted otherwise. Weight vectors are validated eagerly — a
    mismatched length, a negative/non-finite entry, or an all-zero vector
    is a configuration error, not something to silently fall back from."""

    def __init__(self, tasks: Sequence[str], seed: int = 0,
                 weights: Optional[Sequence[float]] = None):
        self.tasks = list(tasks)
        if not self.tasks:
            raise ValueError("TaskSampler needs at least one task")
        if weights is None:
            self.weights = None
        else:
            ws = [float(w) for w in weights]
            if len(ws) != len(self.tasks):
                raise ValueError(
                    f"weights length {len(ws)} != tasks length "
                    f"{len(self.tasks)} ({self.tasks})")
            if any(not np.isfinite(w) or w < 0 for w in ws):
                raise ValueError(f"weights must be finite and >= 0: {ws}")
            if sum(ws) <= 0:
                raise ValueError(f"weights must not sum to zero: {ws}")
            self.weights = ws
        self._rng = random.Random(seed)

    def sample(self) -> str:
        if self.weights is not None:
            return self._rng.choices(self.tasks, weights=self.weights)[0]
        return self._rng.choice(self.tasks)

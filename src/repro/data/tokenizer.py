"""Byte-level tokenizer. IDs: 0=PAD, 1=BOS, 2=EOS, 3..258 = bytes.

Model vocabularies are all >= 512, so byte tokens always fit; text round-trips
exactly. This is the data-plane tokenizer for live-mode RL (environments speak
text, the engine speaks tokens).
"""
from __future__ import annotations

from typing import List

PAD, BOS, EOS = 0, 1, 2
OFFSET = 3
VOCAB_MIN = OFFSET + 256


class ByteTokenizer:
    pad_id, bos_id, eos_id = PAD, BOS, EOS
    vocab_size = VOCAB_MIN

    def encode(self, text: str, bos: bool = False, eos: bool = False) -> List[int]:
        ids = [b + OFFSET for b in text.encode("utf-8")]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids) -> str:
        data = bytes(i - OFFSET for i in ids if OFFSET <= i < OFFSET + 256)
        return data.decode("utf-8", errors="replace")


TOKENIZER = ByteTokenizer()

"""FT supervisor: the control loop above ``LiveRLRunner`` that the paper
says disaggregation makes mandatory (§8) — periodic paired checkpoints
(train state + rollout plane) and supervised recovery from injected or
real failures.

Recovery policy by failure class:

- **env / engine / rollout-plane failures** recover from the latest
  rollout snapshot WITHOUT restarting training: env managers are rebuilt
  at their snapshot state and resumed, engine KV slots are re-injected
  through ``LLMProxy.reinject`` (re-prefilled if the weights moved on),
  and replayed trajectories the trainer already consumed are deduped by
  the SampleBuffer, so no ``traj_id`` trains twice.
- **reward failures** are absorbed by the runner's reward drain itself
  (re-submission from the retained payload).
- **trainer failures** restart from the latest PAIRED checkpoint:
  ``restore_latest`` walks steps newest-first, skipping any pair whose
  train checkpoint or rollout snapshot is corrupt ("checkpoint corrupt,
  falling back to step N-1") until one restores cleanly.

With ``scratch_recovery=True`` the supervisor degrades to the
restart-from-scratch baseline — failed trajectories are dropped and
respawned from zero — which is what ``benchmarks/fault_tolerance.py``
compares against.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from repro.checkpoint import checkpointer as CK
from repro.checkpoint.checkpointer import CorruptCheckpointError
from repro.ft.failure import DEFAULT_KINDS, FailureEvent, FailureInjector
from repro.ft.snapshot import RolloutSnapshot, RolloutSnapshotter


@dataclass
class FTConfig:
    snapshot_every: int = 1        # barrier cadence (steps)
    failure_rate: float = 0.0      # 0 = no injection; paper env rate ~0.1
    kinds: Tuple[str, ...] = DEFAULT_KINDS
    keep_last: int = 3             # retained snapshot/checkpoint pairs
    scratch_recovery: bool = False  # baseline: drop instead of restore
    seed: int = 0


class FTSupervisor:
    """Wraps one runner; drives snapshots, injection, and recovery."""

    def __init__(self, runner, cfg: Optional[FTConfig] = None,
                 ckpt_dir: Optional[str] = None,
                 injector: Optional[FailureInjector] = None,
                 snapshotter: Optional[RolloutSnapshotter] = None):
        self.runner = runner
        self.cfg = cfg or FTConfig()
        self.snapshotter = snapshotter or RolloutSnapshotter(
            ckpt_dir, keep_last=self.cfg.keep_last)
        self.injector = injector
        if injector is None and self.cfg.failure_rate > 0:
            self.injector = FailureInjector(self.cfg.failure_rate,
                                            kinds=self.cfg.kinds,
                                            seed=self.cfg.seed)
        self.events: List[FailureEvent] = []
        self.log: List[str] = []
        self.last_snapshot: Optional[RolloutSnapshot] = None
        runner.barrier_hook = self._on_barrier

    # ------------------------------------------------------------------
    def _on_barrier(self, runner, step: int):
        """Runs under the pump lock at every suspend->update->resume
        barrier: capture is synchronous (cheap), persistence is not.
        Pairs are labeled by WEIGHT VERSION, not the runner-local step
        index, so snapshots taken after a restart continue the original
        numbering instead of overwriting it."""
        v = int(runner.state.version)
        if self.cfg.snapshot_every <= 0 \
                or v % self.cfg.snapshot_every != 0:
            return
        snap = self.snapshotter.capture(runner, v)
        self.last_snapshot = snap
        if self.snapshotter.path is not None:
            self.snapshotter.save_async(snap)
            self.snapshotter.save_train_state_async(runner.state, v)

    # ------------------------------------------------------------------
    def run_steps(self, num_steps: int):
        """Drive the runner one trainer step at a time; after each step —
        the rollout worker is parked there — maybe inject a fault and
        recover it."""
        for _ in range(num_steps):
            self.runner.run_steps(1)
            step = self.runner.history[-1].step
            kind = self.injector.draw(step) if self.injector else None
            if kind:
                self.inject_and_recover(kind, step)
        return self.runner.history

    def close(self):
        self.runner.barrier_hook = None
        self.snapshotter.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------------
    # injection + recovery
    # ------------------------------------------------------------------
    def inject_and_recover(self, kind: str,
                           step: int) -> Optional[FailureEvent]:
        runner, inj = self.runner, self.injector
        t0 = time.monotonic()
        if kind == "env":
            ev = inj.kill_env(runner, step)
            if ev is not None and not self.cfg.scratch_recovery:
                self._recover_env(ev)
        elif kind == "engine":
            handle = inj.pick_engine(runner)
            ev = inj.kill_engine(runner, step, handle)
            self._recover_engine(ev, handle)
        elif kind == "reward":
            ev = inj.kill_reward(runner, step)
        elif kind == "rollout":
            ev = inj.kill_rollout(runner, step)
            if not self.cfg.scratch_recovery:
                self._recover_rollout(ev)
        else:
            raise ValueError(f"unknown failure kind {kind!r}")
        if ev is not None:
            ev.recovery_s = time.monotonic() - t0
            self.events.append(ev)
            how = "snapshot" if ev.recovered else "scratch"
            self.log.append(
                f"step {step}: injected {kind} failure on {ev.target} — "
                f"destroyed {ev.destroyed_tokens} tokens, recovered "
                f"{ev.recovered_tokens} ({how})")
        return ev

    def _snap_maps(self):
        snap = self.last_snapshot
        if snap is None:
            return None, {}, {}
        return snap, snap.handoff_records(), snap.queued_adds()

    def _slot_template(self):
        import jax
        eng = self.runner.proxy.handles[0].engine
        tmpl = eng.model.extract_cache_slot(eng._cache, 0)
        leaves, treedef = jax.tree.flatten(tmpl)
        return treedef, leaves

    def _recover_env(self, ev: FailureEvent):
        """Resume the killed manager from its snapshot record: the env
        object and token stream come back at snapshot state, and its
        generation continues (re-injected KV when the snapshot holds the
        slot, otherwise a fresh request over the restored prefix)."""
        snap, handoffs, queued = self._snap_maps()
        rec = None if snap is None else next(
            (r for r in snap.ems if r["em_id"] == ev.target), None)
        if rec is None or rec["aborting"]:
            return        # fault predates coverage: runner respawns fresh
        treedef, tmpl_leaves = self._slot_template()
        ev.recovered_tokens = self.snapshotter._resume_em(
            self.runner, rec, handoffs, queued, treedef, tmpl_leaves)
        ev.recovered = True

    def _recover_engine(self, ev: FailureEvent, handle):
        """Re-home every request the dead engine held. Snapshot-covered
        requests re-inject their KV slot on a surviving (or the reborn)
        engine; uncovered ones retry from the manager's token prefix —
        or, in the scratch baseline, fail outright and respawn."""
        runner = self.runner
        proxy = runner.proxy
        snap, handoffs, queued = self._snap_maps()
        treedef, tmpl_leaves = self._slot_template()
        lost = set(ev.lost_rids)
        rehomed = set()     # lost rids re-registered under the SAME id
        # every SERVICE tenant's in-flight managers dangle on the dead
        # engine, not just the trainer's — recover them all (uncovered
        # client managers take the retry path: the snapshot plane only
        # captures the trainer tenant)
        if getattr(runner, "service", None) is not None:
            ems = [em for t in runner.service.tenants() for em in t.active]
        else:
            ems = list(runner.active)
        for em in ems:
            rid = em._active_req
            if rid is None or rid not in lost \
                    or em.state.name != "GENERATING":
                continue
            # the manager's completed-turn prefix is at risk too: the
            # scratch baseline destroys it, supervised recovery keeps it
            prefix = sum(em.loss_mask)
            ev.destroyed_tokens += prefix
            proxy.drop_routes([rid])
            if self.cfg.scratch_recovery:
                em.fail()
                continue
            hrec = handoffs.get(rid)
            if hrec is not None:
                proxy.reinject(
                    self.snapshotter._rebuild_handoff(
                        hrec, treedef, tmpl_leaves),
                    callback=em.on_generation,
                    # drop_routes above unsubscribed the manager's token
                    # stream — re-register it so streaming consumers see
                    # the recovery as a seamless (idempotent) replay
                    on_tokens=em.on_tokens)
                rehomed.add(rid)
                ev.recovered_tokens += prefix + len(hrec["new_tokens"])
            elif rid in queued:
                proxy.submit(queued[rid], em.on_generation,
                             on_tokens=em.on_tokens)
                rehomed.add(rid)
                ev.recovered_tokens += prefix
            else:
                em._active_req = None
                em.retry()          # fresh id; the old route is gone
                ev.recovered_tokens += prefix
        # routes that belong to no live manager (raced completions) still
        # point at the dead engine — but never the ones just re-homed
        # above, which re-registered under their ORIGINAL request id
        proxy.drop_routes([rid for rid in lost
                           if rid not in rehomed and proxy.routed(rid)])
        ev.recovered = not self.cfg.scratch_recovery

    # ------------------------------------------------------------------
    # watchdog entry points (repro.obs.watchdog) — called from the
    # monitor thread, which holds NO locks
    # ------------------------------------------------------------------
    def recover_hung_engine(self, handle) -> FailureEvent:
        """Recover an engine whose beat went silent (a *wedged*
        ``step()``, not a loud crash — the gap injected faults never
        exercised). The wedged step holds ``_step_lock`` forever, so
        recovery must not touch engine locks: capture the routed
        requests first (routes outlive the kill), then ``hard_kill()``
        — the lock-free SIGKILL analogue, honored at the step's next
        kill-check as it unwinds — and wait for the replacement process
        (``crashes`` increments once ``crash()`` rebuilds the engine on
        the formerly-wedged thread). Only then re-home the lost
        requests under the service barrier, exactly like an injected
        engine crash."""
        runner = self.runner
        eng = handle.engine
        t0 = time.monotonic()
        step = len(runner.history)
        lost = runner.proxy.requests_on(handle)
        destroyed = eng.inflight_decode_tokens
        crashes0 = eng.crashes
        eng.hard_kill()
        deadline = t0 + 30.0
        while eng.crashes == crashes0:
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"hard-killed engine {handle.name or handle.pool} "
                    "never came back (no step() observed the kill)")
            time.sleep(0.005)
        ev = FailureEvent(step=step, kind="engine", target=handle.name
                          or handle.pool, destroyed_tokens=destroyed,
                          lost_rids=lost,
                          detail="watchdog: beat silent past deadline")
        with runner.service.barrier():
            self._recover_engine(ev, handle)
        ev.recovery_s = time.monotonic() - t0
        self.events.append(ev)
        self.log.append(
            f"step {step}: watchdog killed hung engine {ev.target} — "
            f"destroyed {ev.destroyed_tokens} tokens, recovered "
            f"{ev.recovered_tokens}")
        return ev

    def recover_stalled_ems(self) -> int:
        """Recover env managers that are GENERATING but whose active
        request is routed nowhere (orphaned by a lost engine or a
        dropped route): retry them over their retained token prefix.
        Taken under the service barrier so the plane is quiescent."""
        runner = self.runner
        proxy = runner.proxy
        n = 0
        with runner.service.barrier():
            for em in list(runner.active):
                rid = em._active_req
                if rid is None or em.state.name != "GENERATING" \
                        or proxy.routed(rid):
                    continue
                em._active_req = None
                em.retry()
                n += 1
        if n:
            self.log.append(f"watchdog: re-homed {n} stalled env "
                            "managers")
        return n

    def _recover_rollout(self, ev: FailureEvent):
        """Full plane restore from the latest snapshot while training
        keeps its progress — the dedup-heavy path: trajectories consumed
        since the snapshot replay and are dropped at ``put``."""
        snap = self.last_snapshot
        if snap is None:
            return
        report = self.snapshotter.restore(self.runner, snap,
                                          plane_only=True)
        ev.recovered_tokens = report["recovered_tokens"]
        ev.recovered = True
        ev.detail += (f" restored {report['resumed_ems']} ems, "
                      f"{report['pending_rewards']} pending rewards")


# ---------------------------------------------------------------------------
# trainer-failure restart: restore the latest intact (train, rollout) pair
# ---------------------------------------------------------------------------
def restore_latest(ckpt_dir: str, like_state,
                   make_runner: Callable,
                   log: Optional[List[str]] = None):
    """Restart path for a trainer failure: walk the paired checkpoints
    newest-first; a step whose train checkpoint or rollout snapshot is
    corrupt (truncated write, crashed save) is skipped with a
    "checkpoint corrupt, falling back to step N-1" log line. Returns
    ``(runner, step)`` with the rollout plane already restored.

    ``make_runner(train_state)`` must build a fresh, un-started
    ``LiveRLRunner`` whose engines hold ``train_state.params``.
    """
    snapper = RolloutSnapshotter(ckpt_dir)
    paired = sorted(set(CK.steps(ckpt_dir)) & set(snapper.steps()))
    if not paired:
        raise FileNotFoundError(
            f"no paired train+rollout checkpoints under {ckpt_dir}")
    log = log if log is not None else []
    for step in reversed(paired):
        try:
            state, _ = CK.restore(ckpt_dir, like_state, step=step)
            snap = snapper.load(step)
        except CorruptCheckpointError as e:
            log.append(f"step {step}: checkpoint corrupt, falling back "
                       f"to step N-1 ({e})")
            continue
        runner = make_runner(state)
        snapper.restore(runner, snap)
        log.append(f"restored paired checkpoint at step {step}")
        return runner, step
    raise CorruptCheckpointError(
        f"every paired checkpoint under {ckpt_dir} is corrupt "
        f"(tried steps {list(reversed(paired))}): " + "; ".join(log))

"""Failure injection for the live data plane (paper §8).

The paper's robustness claim rests on three observed failure classes:
training-worker crashes (restart from checkpoint), environment failures
(~1 per 10 iterations in production), and lost serverless invocations.
``FailureInjector`` reproduces all of them against a running
``LiveRLRunner`` — killing an env manager, an engine (all KV slots, queued
commands, and results gone), a pending reward invocation, or the whole
rollout plane — and reports how much in-flight work each fault destroyed,
so the supervisor can account recovered vs lost tokens per event.

Injection happens between runner steps, when the rollout worker is parked
(``run_steps`` parks it on exit), so faults land on a quiescent plane the
way a real crash lands on a process: state simply disappears.

Concurrency contract: the injector owns no locks and is single-threaded
by design — every entry point assumes the quiescent barrier above. The
cross-object mutations it performs (``runner._pending_rewards``, engine
teardown, ``runner._completed_this_round`` under the runner's
``_completed_lock``) are outside the per-class static-analysis model
(see ``repro.analysis.model``) and are protected by that barrier, not by
locks of this class.
"""
from __future__ import annotations

import random
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.envmanager import EMState
from repro.core.serverless import ServerlessError

DEFAULT_KINDS = ("env", "engine", "reward")


@dataclass
class FailureEvent:
    step: int
    kind: str                     # env | engine | reward | rollout | trainer
    target: str                   # em_id / engine name / url / "plane"
    destroyed_tokens: int = 0     # in-flight decode tokens the fault killed
    recovered_tokens: int = 0     # decode tokens resurrected from snapshot
    recovery_s: float = 0.0
    recovered: bool = False
    lost_rids: List[str] = field(default_factory=list)
    detail: str = ""

    @property
    def lost_tokens(self) -> int:
        return max(0, self.destroyed_tokens - self.recovered_tokens)


class FailureInjector:
    """Schedule + execute fault injection.

    ``rate`` is the per-iteration failure probability (paper default:
    ~1/10 iterations). ``schedule`` maps step -> kind and overrides the
    stochastic draw for deterministic benchmarks/tests; a scheduled run
    fires exactly those faults and nothing else.
    """

    def __init__(self, rate: float = 0.1,
                 kinds: Tuple[str, ...] = DEFAULT_KINDS, seed: int = 0,
                 schedule: Optional[Dict[int, str]] = None):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"failure rate must be in [0, 1], got {rate}")
        self.rate = rate
        self.kinds = tuple(kinds)
        self.schedule = dict(schedule) if schedule else None
        self._rng = random.Random(seed)

    def draw(self, step: int) -> Optional[str]:
        """Which fault (if any) fires after trainer step ``step``."""
        if self.schedule is not None:
            return self.schedule.get(step)
        if self.rate > 0 and self._rng.random() < self.rate:
            return self._rng.choice(self.kinds)
        return None

    # ------------------------------------------------------------------
    # the faults
    # ------------------------------------------------------------------
    def kill_env(self, runner, step: int) -> Optional[FailureEvent]:
        """Crash one in-flight environment: its manager FAILs, the whole
        trajectory-so-far is destroyed, and its generation request is
        cancelled (the cancellation is drained here so a later resume of
        the same manager can never race a stale ABORT)."""
        cands = [em for em in runner.active
                 if em.state == EMState.GENERATING]
        if not cands:
            return None
        em = self._rng.choice(cands)
        rid = em._active_req
        ev = FailureEvent(step=step, kind="env", target=em.em_id,
                          destroyed_tokens=sum(em.loss_mask),
                          lost_rids=[rid] if rid else [],
                          detail=f"turns={em.turns}")
        em.fail()
        pumps = 0
        while rid is not None and runner.proxy.routed(rid):
            runner.proxy.pump()
            pumps += 1
            if pumps > runner.cfg.max_pump_steps:
                raise RuntimeError(f"abort of {rid} did not drain")
        return ev

    def pick_engine(self, runner):
        """A decode-capable engine handle (the one whose loss hurts)."""
        handles = runner.proxy.handles
        cands = [h for h in handles if h.role != "prefill"] or handles
        return self._rng.choice(cands)

    def kill_engine(self, runner, step: int,
                    handle=None) -> FailureEvent:
        """Crash one engine process: every KV slot, queued command, and
        undelivered result it held is gone. Requests routed to it dangle
        until the supervisor recovers them."""
        handle = handle or self.pick_engine(runner)
        eng = handle.engine
        lost = runner.proxy.requests_on(handle)
        ev = FailureEvent(step=step, kind="engine",
                          target=handle.name or handle.pool,
                          destroyed_tokens=eng.inflight_decode_tokens,
                          lost_rids=lost,
                          detail=f"slots={eng.num_active} "
                                 f"queued={eng.queue_len}")
        eng.crash()
        return ev

    def kill_reward(self, runner, step: int) -> Optional[FailureEvent]:
        """Lose one pending serverless reward invocation: its future is
        replaced with a ServerlessError. The runner's reward drain
        re-submits from the retained payload (``reward_retry_limit``), so
        recovery is intrinsic — no trajectory is destroyed."""
        if not runner._pending_rewards:
            runner.serverless.fail_next(runner.cfg.reward_url)
            return FailureEvent(step=step, kind="reward",
                                target=runner.cfg.reward_url,
                                recovered=True,
                                detail="poisoned next invocation")
        entry = self._rng.choice(list(runner._pending_rewards))
        dead: Future = Future()
        dead.set_exception(ServerlessError(
            "invocation lost mid-call (injected fault)"))
        entry[2] = dead
        return FailureEvent(step=step, kind="reward",
                            target=entry[0].traj_id, recovered=True,
                            detail="pending future poisoned; reward drain "
                                   "re-submits from the retained payload")

    def kill_rollout(self, runner, step: int) -> FailureEvent:
        """Lose the whole rollout plane: every engine crashes, every env
        manager and pending reward is gone. Trainer-side state (the
        SampleBuffer with its consumed-id frontier) survives — restoring
        the plane from an older snapshot therefore replays trajectories
        the trainer already consumed, which the buffer dedups."""
        proxy = runner.proxy
        destroyed = sum(h.engine.inflight_decode_tokens
                        for h in proxy.handles)
        destroyed += sum(sum(em.loss_mask) for em in runner.active
                         if em.state == EMState.GENERATING)
        lost = [rid for h in proxy.handles for rid in proxy.requests_on(h)]
        for h in proxy.handles:
            h.engine.crash()
        proxy.drop_routes(lost)
        runner.active.clear()
        with runner._completed_lock:
            runner._completed_this_round.clear()
        n_rewards = len(runner._pending_rewards)
        runner._pending_rewards.clear()
        return FailureEvent(step=step, kind="rollout", target="plane",
                            destroyed_tokens=destroyed, lost_rids=lost,
                            detail=f"engines={len(proxy.handles)} "
                                   f"rewards={n_rewards}")

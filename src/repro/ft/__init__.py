"""Fault-tolerance plane (paper §8): rollout-level checkpoint/restore,
failure injection, and the supervised-recovery loop above LiveRLRunner."""
from repro.ft.failure import (DEFAULT_KINDS, FailureEvent, FailureInjector)
from repro.ft.snapshot import RolloutSnapshot, RolloutSnapshotter
from repro.ft.supervisor import FTConfig, FTSupervisor, restore_latest

__all__ = [
    "DEFAULT_KINDS", "FailureEvent", "FailureInjector",
    "RolloutSnapshot", "RolloutSnapshotter",
    "FTConfig", "FTSupervisor", "restore_latest",
]

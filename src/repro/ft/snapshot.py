"""Rollout-level checkpointing (paper §8): the RolloutSnapshotter.

The train-state checkpointer (``repro.checkpoint``) covers the trainer;
everything ELSE the disaggregated plane holds in flight — EnvManager state
machines, engine KV-cache slots, buffered samples, pending serverless
reward invocations — was lost on restart. The snapshotter serializes that
rollout plane into versioned snapshots alongside the train-state
checkpoint:

- **capture** runs at the runner's suspend -> update -> resume barrier
  (``LiveRLRunner.barrier_hook``), where the pump lock is held and the
  plane is quiescent. It is cheap: host lists are copied, environments are
  deep-copied, and KV slots are extracted through the existing
  ``Model.extract_cache_slot`` path and gathered to HOST numpy (safe
  against the engines' donated dispatches, and — since engines can run
  TP-sharded over device groups — already in the portable format that
  re-shards on inject into ANY group size at restore). No disk I/O
  happens under the barrier.
- **save** runs on a background writer thread (``save_async``), staging
  into a ``.tmp_rollout_*`` dir and publishing with one atomic
  ``os.replace`` — the same crash-safety contract as the checkpointer.
  Cache leaves go to ``kv.npz``; everything picklable to ``state.pkl``.
- **restore** rebuilds proxies/engines/env managers from a snapshot:
  engine PRNG chains and weight versions are reset, KV slots are
  re-injected through ``LLMProxy.reinject`` (a weight-version mismatch
  re-prefills under the current weights, protocol step (5) semantics),
  queued-but-unadmitted requests are re-submitted, pending rewards are
  re-invoked from their retained payloads, and the SampleBuffer — seq
  numbers, staleness version, and the consumed-``traj_id`` set — comes
  back exactly, so replayed trajectories dedup instead of training twice.
"""
from __future__ import annotations

import copy
import itertools
import os
import pickle
import shutil
import tempfile
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import checkpointer as CK
from repro.checkpoint.checkpointer import CorruptCheckpointError
from repro.core.envmanager import (EnvManager, RolloutPolicy,
                                   em_counter_value, ensure_em_counter)
from repro.rl.engine import KVHandoff


@dataclass
class RolloutSnapshot:
    """In-memory image of the rollout plane at one barrier."""
    step: int                      # trainer step the barrier belongs to
    version: int                   # weight version the engines run (and
    #                                the train-state checkpoint pairs with)
    runner_version: int            # runner.version (trails by one: it
    #                                advances after train_step)
    mode: str                      # RunnerConfig.mode at capture
    buffer: Dict                   # SampleBuffer.snapshot_state()
    in_hand: List                  # the batch fetched but not yet trained
    prev_fetched: int              # one_off previous-batch bookkeeping
    pending_rewards: List          # (traj, payload, attempts)
    ems: List[Dict]                # EnvManager.snapshot_state() records
    engines: List[Dict]            # per-engine rng / version / slots / queue
    sampler_rng: object            # TaskSampler RNG state
    seed_counter: int
    em_counter: int
    meta: Dict = field(default_factory=dict)

    def handoff_records(self) -> Dict[str, Dict]:
        """request_id -> handoff record, across every engine's active
        slots and queued INJECT commands."""
        out = {}
        for erec in self.engines:
            for hrec in erec["slots"]:
                out[hrec["request"].request_id] = hrec
            for kind, payload in erec["queued"]:
                if kind == "inject":
                    out[payload["request"].request_id] = payload
        return out

    def queued_adds(self) -> Dict[str, object]:
        """request_id -> GenRequest for dispatched-but-unadmitted ADDs."""
        return {payload.request_id: payload
                for erec in self.engines
                for kind, payload in erec["queued"] if kind == "add"}


def _handoff_record(hf: KVHandoff) -> Dict:
    """KVHandoff -> serializable record; the cache pytree becomes a flat
    leaf list (treedef is re-derived from the restoring engine)."""
    return {"request": hf.request, "tokens": list(hf.tokens),
            "new_tokens": list(hf.new_tokens),
            "logprobs": list(hf.logprobs), "pos": hf.pos,
            "start_version": hf.start_version,
            "weight_version": hf.weight_version, "source": hf.source,
            "cache_leaves": list(jax.tree.leaves(hf.cache))}


class RolloutSnapshotter:
    """Capture / persist / restore the rollout plane.

    ``path=None`` keeps snapshots in memory only (the supervisor's live
    env/engine recovery); with a path, ``save_async`` persists them next
    to the train-state checkpoints without stalling the barrier.
    """

    def __init__(self, path: Optional[str] = None, keep_last: int = 3):
        self.path = path
        self.keep_last = keep_last
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="rollout-snap")
        self._pending: List[Future] = []   # guarded by: _lock
        self._lock = threading.Lock()
        # per-engine host image of the paged KV pool, merged across
        # incremental captures: {engine name: {pid: [leaf arrays]}}.
        # Only touched from capture(), which runs under the runner
        # barrier (single-threaded by contract).
        self._pool_images: Dict[str, Dict[int, List[np.ndarray]]] = {}

    # ------------------------------------------------------------------
    # capture (under the runner barrier)
    # ------------------------------------------------------------------
    def capture(self, runner, step: int) -> RolloutSnapshot:
        """Consistent image of the rollout plane. Caller must hold the
        runner's pump lock (barrier hook) or otherwise guarantee the
        worker is parked."""
        runner._drain_completions()    # score stragglers first: the
        #                                completed-EM list must be empty
        proxy = runner.proxy
        engines = []
        kv_capture_bytes = 0
        for h in proxy.handles:
            eng = h.engine
            queued = []
            for kind, payload in eng.snapshot_commands():
                if kind == "inject":
                    queued.append((kind, _handoff_record(payload)))
                else:
                    queued.append((kind, payload))
            if getattr(eng, "paged", False):
                # incremental path: only pages written since the last
                # barrier cross device->host; the slot records are
                # assembled from the snapshotter's merged pool image so
                # the on-disk format stays identical to the dense path
                slots, moved = self._capture_paged_slots(h.name, eng)
            else:
                slots = [_handoff_record(hf)
                         for hf in eng.snapshot_slots()]
                moved = sum(int(np.asarray(leaf).nbytes)
                            for rec in slots
                            for leaf in rec["cache_leaves"])
            kv_capture_bytes += moved
            engines.append({
                "name": h.name, "role": h.role,
                "key": eng.snapshot_rng(),
                "weight_version": eng.weight_version,
                "slots": slots,
                "queued": queued,
                "kv_capture_bytes": moved,
            })
        # requests whose cancellation is already in flight (proxy-level
        # abort guard + engine-queued ABORTs, read once from the command
        # snapshots above) — their managers are not worth resuming
        aborting = proxy.pending_abort_ids()
        aborting.update(payload for erec in engines
                        for kind, payload in erec["queued"]
                        if kind == "abort")
        ems = []
        for em in runner.active:
            rec = em.snapshot_state()
            # a live snapshot must not alias the running environment
            rec["env"] = copy.deepcopy(rec["env"])
            rec["aborting"] = rec["active_req"] in aborting
            ems.append(rec)
        in_hand = list(runner.last_batch)
        buf = runner.buffer.snapshot_state()
        # the in-hand batch has not trained yet: restore re-queues it, so
        # its ids must not sit in the snapshot's consumed set
        buf["consumed"] -= {t.traj_id for t in in_hand}
        pending = [(traj, payload, attempts)
                   for traj, payload, _fut, attempts
                   in runner._pending_rewards]
        seed_val = next(runner._seed_counter)      # peek-then-recreate
        runner._seed_counter = itertools.count(seed_val)
        return RolloutSnapshot(
            step=step, version=int(runner.state.version),
            runner_version=runner.version, mode=runner.cfg.mode,
            buffer=buf, in_hand=in_hand,
            prev_fetched=runner._prev_batch_fetched_step,
            pending_rewards=pending, ems=ems, engines=engines,
            sampler_rng=runner.sampler._rng.getstate(),
            seed_counter=seed_val, em_counter=em_counter_value(),
            meta={"kv_capture_bytes": kv_capture_bytes})

    def _capture_paged_slots(self, name: str, eng):
        """Incremental KV capture for one paged engine: merge its dirty
        pages into the persistent host pool image, prune the image to
        pages a restore could still need (live slot tables + prefix
        cache), then assemble each active slot's SELF-CONTAINED dense
        ``cache_leaves`` record from the image — byte-compatible with
        ``_handoff_record``, so save/load/restore are untouched. Returns
        ``(slot_records, device_bytes_moved)``: when only one slot
        advanced since the last barrier, only its freshly written pages
        are gathered, not every active slot's full dense row."""
        cap = eng.capture_kv_incremental()
        img = self._pool_images.setdefault(name, {})
        img.update(cap["pages"])
        for pid in [p for p in img if p not in cap["live_pages"]]:
            del img[pid]
        tmpl = eng.model.init_cache(1, eng.max_len)
        tmpl_leaves = jax.tree.leaves(tmpl)
        page = eng.page_size
        slots = []
        for rec in cap["slots"]:
            leaves = []
            for li, t in enumerate(tmpl_leaves):
                dense = np.zeros(np.shape(t), np.asarray(t).dtype)
                for j, pid in enumerate(rec["table"]):
                    blk = img.get(pid)
                    if blk is not None:
                        dense[:, 0, :, j * page:(j + 1) * page, :] = blk[li]
                leaves.append(dense)
            slots.append({
                "request": rec["request"], "tokens": rec["tokens"],
                "new_tokens": rec["new_tokens"],
                "logprobs": rec["logprobs"], "pos": rec["pos"],
                "start_version": rec["start_version"],
                "weight_version": rec["weight_version"],
                "source": "snapshot", "cache_leaves": leaves,
            })
        return slots, int(cap["captured_bytes"])

    # ------------------------------------------------------------------
    # persistence (writer thread)
    # ------------------------------------------------------------------
    def _dir(self, step: int) -> str:
        return os.path.join(self.path, f"rollout_{step:08d}")

    def save(self, snap: RolloutSnapshot) -> str:
        """Atomic synchronous write. Cache/PRNG arrays land in ``kv.npz``
        (keyed by handoff index), the rest in ``state.pkl``."""
        if self.path is None:
            raise ValueError("RolloutSnapshotter was built without a path")
        os.makedirs(self.path, exist_ok=True)
        arrays: Dict[str, np.ndarray] = {}
        picklable = self._strip_arrays(snap, arrays)
        tmp = tempfile.mkdtemp(dir=self.path, prefix=".tmp_rollout_")
        try:
            np.savez(os.path.join(tmp, "kv.npz"), **arrays)
            with open(os.path.join(tmp, "state.pkl"), "wb") as f:
                pickle.dump(picklable, f)
            final = self._dir(snap.step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        finally:
            if os.path.exists(tmp):
                shutil.rmtree(tmp, ignore_errors=True)
        self.prune()
        return final

    def _strip_arrays(self, snap: RolloutSnapshot,
                      arrays: Dict[str, np.ndarray]) -> RolloutSnapshot:
        """Copy ``snap`` with every cache leaf / PRNG key moved into
        ``arrays`` and replaced by an npz key reference."""
        def strip_handoff(hrec: Dict, tag: str) -> Dict:
            out = dict(hrec)
            keys = []
            for j, leaf in enumerate(hrec["cache_leaves"]):
                k = f"{tag}_l{j}"
                arrays[k] = np.asarray(leaf)
                keys.append(k)
            out["cache_leaves"] = ("__npz__", keys)
            return out

        engines = []
        for i, erec in enumerate(snap.engines):
            out = dict(erec)
            arrays[f"e{i}_key"] = np.asarray(erec["key"])
            out["key"] = ("__npz__", [f"e{i}_key"])
            out["slots"] = [strip_handoff(h, f"e{i}_s{j}")
                            for j, h in enumerate(erec["slots"])]
            out["queued"] = [
                (kind, strip_handoff(p, f"e{i}_q{j}")
                 if kind == "inject" else p)
                for j, (kind, p) in enumerate(erec["queued"])]
            engines.append(out)
        return RolloutSnapshot(
            **{**snap.__dict__, "engines": engines})

    def save_async(self, snap: RolloutSnapshot):
        with self._lock:
            self._pending.append(self._pool.submit(self.save, snap))

    def save_train_state_async(self, state, step: int):
        """Pair the rollout snapshot with a train-state checkpoint at the
        same step, on the same writer thread (ordered after the rollout
        write submitted before it)."""
        with self._lock:
            self._pending.append(self._pool.submit(
                CK.save, self.path, state, step, self.keep_last))

    def wait(self):
        """Flush pending writes, surfacing writer errors."""
        with self._lock:
            pending, self._pending = self._pending, []
        for f in pending:
            f.result()

    def close(self):
        self.wait()
        self._pool.shutdown(wait=True)

    def steps(self) -> List[int]:
        if self.path is None:
            return []
        return CK.versioned_steps(self.path, prefix="rollout_")

    def latest_step(self) -> Optional[int]:
        all_steps = self.steps()
        return all_steps[-1] if all_steps else None

    def prune(self):
        CK.prune_versioned(self.path, self.keep_last, prefix="rollout_",
                           tmp_prefix=".tmp_rollout_")

    def load(self, step: Optional[int] = None) -> RolloutSnapshot:
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no rollout snapshots under {self.path}")
        d = self._dir(step)
        try:
            data = np.load(os.path.join(d, "kv.npz"))
            with open(os.path.join(d, "state.pkl"), "rb") as f:
                snap: RolloutSnapshot = pickle.load(f)
        except (OSError, ValueError, pickle.UnpicklingError, EOFError) as e:
            raise CorruptCheckpointError(
                f"rollout snapshot step {step} under {self.path} is "
                f"corrupt: {e}") from e

        def rehydrate(hrec: Dict) -> Dict:
            out = dict(hrec)
            _, keys = hrec["cache_leaves"]
            out["cache_leaves"] = [data[k] for k in keys]
            return out

        for erec in snap.engines:
            _, (kkey,) = erec["key"]
            erec["key"] = data[kkey]
            erec["slots"] = [rehydrate(h) for h in erec["slots"]]
            erec["queued"] = [(kind, rehydrate(p) if kind == "inject"
                               else p) for kind, p in erec["queued"]]
        return snap

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def _rebuild_handoff(self, hrec: Dict, treedef, tmpl_leaves
                         ) -> KVHandoff:
        leaves = hrec["cache_leaves"]
        if len(leaves) != len(tmpl_leaves):
            raise ValueError(
                f"snapshot KV slot for {hrec['request'].request_id}: leaf "
                f"count mismatch — engine cache has {len(tmpl_leaves)} "
                f"leaves, snapshot holds {len(leaves)}")
        for tpl, got in zip(tmpl_leaves, leaves):
            if tuple(np.shape(tpl)) != tuple(np.shape(got)):
                raise ValueError(
                    f"snapshot KV slot for {hrec['request'].request_id}: "
                    f"shape mismatch {np.shape(tpl)} vs {np.shape(got)}")
        return KVHandoff(
            request=hrec["request"], tokens=list(hrec["tokens"]),
            new_tokens=list(hrec["new_tokens"]),
            logprobs=list(hrec["logprobs"]), pos=hrec["pos"],
            start_version=hrec["start_version"],
            cache=jax.tree.unflatten(treedef, leaves),
            weight_version=hrec["weight_version"],
            source=hrec.get("source", "snapshot"))

    def _policy(self, runner) -> RolloutPolicy:
        return RolloutPolicy(max_new_tokens=runner.cfg.max_new_tokens,
                             temperature=runner.cfg.temperature)

    def _resume_em(self, runner, rec: Dict, handoffs: Dict,
                   queued_adds: Dict, treedef, tmpl_leaves) -> int:
        """Rebuild one EnvManager and resume its generation. Returns the
        number of decode tokens resurrected without regeneration: the
        manager's completed-turn prefix plus, when the snapshot holds the
        in-flight KV slot, the partial generation it carries."""
        recovered = sum(rec["loss_mask"])     # action tokens in the prefix
        rec = dict(rec, env=copy.deepcopy(rec["env"]))
        em = EnvManager.restore_from(
            rec, runner.proxy, tokenizer=runner.tok,
            policy=self._policy(runner),
            on_complete=runner._on_em_complete)
        runner.active.append(em)
        if em.state.name != "GENERATING":
            return recovered
        rid = rec["active_req"]
        hrec = handoffs.get(rid) if rid else None
        if hrec is not None:
            runner.proxy.reinject(
                self._rebuild_handoff(hrec, treedef, tmpl_leaves),
                callback=em.on_generation)
            return recovered + len(hrec["new_tokens"])
        if rid in queued_adds:
            runner.proxy.submit(queued_adds[rid], em.on_generation)
            return recovered
        # dispatched state unrecoverable: re-request from the manager's
        # token prefix (fresh id, re-prefill) — turns survive, the
        # in-flight action regenerates
        em._active_req = None
        em.retry()
        return recovered

    def restore(self, runner, snap: RolloutSnapshot,
                plane_only: bool = False) -> Dict:
        """Rebuild the rollout plane of ``runner`` from ``snap``.

        Cold restore (default): the runner was freshly constructed from
        the PAIRED train-state checkpoint (``state.version`` must equal
        ``snap.version``); buffer, sampler/seed RNGs, weight store, and
        the in-hand batch come back along with the plane.

        ``plane_only=True`` is the live-recovery path (a rollout-plane
        loss while training kept going): only env managers, engine slots,
        and pending rewards are resurrected; trainer-side state — the
        buffer with its consumed-id frontier, version counters, RNGs —
        stays live, so trajectories the trainer already consumed after
        the snapshot are regenerated and then DEDUPED at ``put``.
        """
        proxy = runner.proxy
        if len(snap.engines) != len(proxy.handles):
            raise ValueError(
                f"snapshot has {len(snap.engines)} engines, proxy has "
                f"{len(proxy.handles)} — restore needs a matching plane")
        if not plane_only and snap.mode != runner.cfg.mode:
            raise ValueError(
                f"snapshot was taken in mode {snap.mode!r}, runner is "
                f"{runner.cfg.mode!r}")
        if not plane_only and int(runner.state.version) != snap.version:
            raise ValueError(
                f"train state is version {int(runner.state.version)} but "
                f"the rollout snapshot pairs with version {snap.version} "
                "— restore the matching train-state checkpoint first")
        eng0 = proxy.handles[0].engine
        # host-built zero template: same treedef/shapes as a slot
        # extraction, and valid for paged engines too (which hold a page
        # pool instead of a dense per-slot cache)
        tmpl_leaves, treedef = jax.tree.flatten(
            eng0.model.init_cache(1, eng0.max_len))
        if not plane_only:
            runner.version = snap.runner_version
            # republish the restored weights at their version so the
            # first barrier's pull/update is the usual no-op — through
            # the runner's publisher, so a TP plane gets the per-shard
            # chunk format its engines pull
            runner._publish_params(runner.state.params, snap.version)
            buf = dict(snap.buffer)
            if snap.mode == "one_off":
                runner._prev_batch = (list(snap.in_hand)
                                      if snap.in_hand else None)
                runner._prev_batch_fetched_step = snap.prev_fetched
            else:
                # the fetched-but-untrained batch re-enters the buffer
                # ahead of everything else (its seq numbers are oldest)
                buf["items"] = list(snap.in_hand) + list(buf["items"])
            runner.buffer.restore_state(buf)
            runner.sampler._rng.setstate(snap.sampler_rng)
            runner._seed_counter = itertools.count(snap.seed_counter)
            for erec, h in zip(snap.engines, proxy.handles):
                h.engine.restore_rng(erec["key"])
                h.engine.weight_version = snap.version
        ensure_em_counter(snap.em_counter)
        handoffs = snap.handoff_records()
        queued_adds = snap.queued_adds()
        recovered_tokens = 0
        resumed = 0
        for rec in snap.ems:
            if rec["aborting"] or rec["state"] in ("DONE", "FAILED",
                                                   "ABORTED"):
                continue
            recovered_tokens += self._resume_em(
                runner, rec, handoffs, queued_adds, treedef, tmpl_leaves)
            resumed += 1
        for traj, payload, attempts in snap.pending_rewards:
            fut = runner.serverless.invoke_async(runner.cfg.reward_url,
                                                 payload)
            runner._pending_rewards.append([traj, payload, fut, attempts])
        return {"resumed_ems": resumed,
                "recovered_tokens": recovered_tokens,
                "pending_rewards": len(snap.pending_rewards),
                "buffered": 0 if plane_only else len(snap.buffer["items"])}

"""LLM-as-a-Judge reward (paper: Qwen2.5-7B validates math reasoning).

The judge is a frozen LM scoring the trajectory text; because its weights
never train, it is a stateless function (R3) and deploys behind the
serverless platform instead of holding dedicated GPUs at 7% utilization.
Live mode runs a tiny judge model on CPU; the score is the judge's mean
action-token log-likelihood (a fluency/consistency proxy) blended with the
environment return.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, get_config
from repro.models.model import Model
from repro.rl.losses import token_logprobs


class LLMJudge:
    def __init__(self, cfg: Optional[ModelConfig] = None, seed: int = 0,
                 env_weight: float = 0.8):
        self.cfg = cfg or get_config("tiny")
        self.model = Model(self.cfg, remat=False)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.env_weight = env_weight
        self._score_jit = jax.jit(self._score)

    def _score(self, tokens, mask):
        logits, _ = self.model.forward(params=self.params, tokens=tokens)
        lp = token_logprobs(logits, tokens)
        m = mask[:, 1:]
        mean_lp = (lp * m).sum() / jnp.clip(m.sum(), 1.0)
        # map mean logprob (-inf..0) to (0..1)
        return jnp.exp(jnp.clip(mean_lp / 4.0, -20.0, 0.0))

    def __call__(self, traj_payload: Dict) -> float:
        tokens = traj_payload.get("tokens", [])
        mask = traj_payload.get("loss_mask", [1] * len(tokens))
        if len(tokens) < 2:
            return float(traj_payload.get("env_return", 0.0))
        n = min(len(tokens), 512)
        t = jnp.asarray([tokens[:n]], jnp.int32)
        m = jnp.asarray([mask[:n]], jnp.float32)
        fluency = float(self._score_jit(t, m))
        env_r = float(traj_payload.get("env_return", 0.0))
        return self.env_weight * env_r + (1 - self.env_weight) * fluency

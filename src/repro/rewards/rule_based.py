"""Rule-based reward functions (stateless -> serverless-deployable, R3)."""
from __future__ import annotations

from typing import Dict, Optional

from repro.data.pipeline import Trajectory


def env_return_reward(traj_payload: Dict) -> float:
    """Default: the environment's accumulated return."""
    return float(traj_payload.get("env_return", 0.0))


def format_bonus_reward(traj_payload: Dict) -> float:
    """Env return + small bonus for well-formed tool/answer usage and a
    length penalty — the shape of production rule-based rewards."""
    r = float(traj_payload.get("env_return", 0.0))
    text = traj_payload.get("text", "")
    if "answer:" in text or "submit" in text or "buy" in text:
        r += 0.05
    n_tokens = int(traj_payload.get("num_tokens", 0))
    r -= 0.0001 * max(0, n_tokens - 2048)
    return r


REWARD_FNS = {
    "env_return": env_return_reward,
    "format_bonus": format_bonus_reward,
}

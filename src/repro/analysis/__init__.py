"""Concurrency & donation static-analysis plane.

Run ``python -m repro.analysis src tests`` (see README "Static
analysis"). Programmatic API: :func:`analyze_source` for in-memory
snippets (used by the test fixtures) and :func:`analyze_paths` for
trees; both return :class:`repro.analysis.findings.Finding` lists.
"""
from repro.analysis.findings import RULES, Finding
from repro.analysis.runner import analyze_paths, analyze_source, main

__all__ = ["RULES", "Finding", "analyze_paths", "analyze_source", "main"]

"""Per-class lock model + the held-lock AST walker shared by the
lock-discipline and lock-order/blocking checkers.

The model is built from the class body itself:

- **locks**: attributes assigned ``threading.Lock()`` / ``RLock()`` /
  ``Condition(...)`` anywhere in the class (normally ``__init__``).
- **aliases**: ``self._cv = threading.Condition(self._lock)`` makes
  ``_cv`` an alias of ``_lock`` — entering ``with self._cv:`` holds the
  SAME underlying lock, and the checkers canonicalize both names.
- **guards**: ``attr -> lock`` from ``# guarded by:`` comments on the
  assignment lines that introduce the attribute.
- **requires**: ``method -> {locks}`` from ``# requires:`` annotations —
  the method body is analyzed as if those locks were already held, and
  calling it without them is a ``caller-locked`` finding.

Scope (documented limitation): the walker tracks ``self.<attr>``
accesses and ``self.<lock>`` acquisitions only — cross-object accesses
(``runner._pending_rewards`` from the failure injector, proxy reads of
engine counters) are outside the per-class model and must be protected
by design (e.g. the runner's quiescent-barrier contract).

``__init__`` is exempt from guard checking: the object is not shared
before construction completes.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.annotations import Annotations
from repro.analysis.findings import Finding

_LOCK_CTORS = {"Lock", "RLock", "Condition"}
# with-items that LOOK like locks on foreign objects (``with
# runner._completed_lock:``): tracked as anonymous held regions for the
# blocking-under-lock rule, but never satisfy a guard.
_FOREIGN_LOCK_RE = re.compile(r"(_lock$|_cv$|^lock$)")


def _ctor_name(call: ast.AST) -> Optional[str]:
    """'Lock'/'RLock'/'Condition' when ``call`` constructs one."""
    if not isinstance(call, ast.Call):
        return None
    fn = call.func
    if isinstance(fn, ast.Name) and fn.id in _LOCK_CTORS:
        return fn.id
    if isinstance(fn, ast.Attribute) and fn.attr in _LOCK_CTORS:
        return fn.attr
    return None


def _self_attr(node: ast.AST) -> Optional[str]:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


@dataclasses.dataclass
class ClassModel:
    name: str
    node: ast.ClassDef
    filename: str
    locks: Set[str] = dataclasses.field(default_factory=set)
    aliases: Dict[str, str] = dataclasses.field(default_factory=dict)
    guards: Dict[str, str] = dataclasses.field(default_factory=dict)
    guard_lines: Dict[str, int] = dataclasses.field(default_factory=dict)
    requires: Dict[str, Set[str]] = dataclasses.field(default_factory=dict)
    methods: List[ast.FunctionDef] = dataclasses.field(default_factory=list)
    errors: List[Finding] = dataclasses.field(default_factory=list)

    def canon(self, lock: str) -> str:
        return self.aliases.get(lock, lock)

    def canon_set(self, locks) -> Set[str]:
        return {self.canon(x) for x in locks}


def build_class_model(node: ast.ClassDef, ann: Annotations,
                      filename: str) -> ClassModel:
    cm = ClassModel(name=node.name, node=node, filename=filename)
    for fn in node.body:
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            cm.methods.append(fn)

    # pass 1: lock declarations + aliases (anywhere in the class body)
    for stmt in ast.walk(node):
        if not isinstance(stmt, ast.Assign):
            continue
        ctor = _ctor_name(stmt.value)
        if ctor is None:
            continue
        for tgt in stmt.targets:
            attr = _self_attr(tgt)
            if attr is None:
                continue
            cm.locks.add(attr)
            if ctor == "Condition" and stmt.value.args:
                base = _self_attr(stmt.value.args[0])
                if base is not None:
                    cm.aliases[attr] = base
                    cm.locks.add(base)

    # pass 2: guarded-attribute annotations on assignment lines
    for stmt in ast.walk(node):
        if not isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            continue
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        end = getattr(stmt, "end_lineno", stmt.lineno) or stmt.lineno
        lock = next((ann.guards[ln]
                     for ln in range(stmt.lineno, end + 1)
                     if ln in ann.guards), None)
        if lock is None:
            continue
        for tgt in targets:
            attr = _self_attr(tgt)
            if attr is None:
                continue
            if lock not in cm.locks and lock not in cm.aliases:
                cm.errors.append(Finding(
                    rule="bad-annotation", file=filename, line=stmt.lineno,
                    context=cm.name, symbol=attr,
                    message=f"attribute {attr!r} is `guarded by: {lock}` "
                            f"but {cm.name} declares no lock named "
                            f"{lock!r} (known: {sorted(cm.locks)})",
                    hint="name a threading.Lock/RLock/Condition attribute "
                         "assigned in this class"))
                continue
            cm.guards[attr] = lock
            cm.guard_lines[attr] = stmt.lineno

    # pass 3: method-level `requires:` annotations (the marker may sit on
    # the def line, the pure comment above it, or — for multi-line
    # signatures — any signature line before the body starts)
    for fn in cm.methods:
        req = ann.requires_for_def(fn.lineno)
        if not req and fn.body:
            req = next((list(ann.requires[ln])
                        for ln in range(fn.lineno + 1, fn.body[0].lineno)
                        if ln in ann.requires), [])
        if not req:
            continue
        unknown = [x for x in req
                   if x not in cm.locks and x not in cm.aliases]
        for x in unknown:
            cm.errors.append(Finding(
                rule="bad-annotation", file=filename, line=fn.lineno,
                context=f"{cm.name}.{fn.name}", symbol=x,
                message=f"method requires unknown lock {x!r} "
                        f"(known: {sorted(cm.locks)})",
                hint="name a lock attribute declared in this class"))
        cm.requires[fn.name] = {x for x in req if x not in unknown}
    return cm


class HeldWalker:
    """Statement-level traversal of one method, tracking the set of locks
    held at every point. Subclasses hook ``on_attr`` / ``on_call`` /
    ``on_acquire``.

    Held-set semantics:
    - entering ``with self.<lock>:`` adds the canonical lock name for the
      body (and fires ``on_acquire`` with the held-set BEFORE the add);
    - a nested ``def`` / ``lambda`` body inherits the held set at its
      definition point (right for the condition-predicate closures in
      ``SampleBuffer.get_batch``; a closure stashed and called later
      escapes this approximation — keep such closures lock-free);
    - ``with`` on a foreign lock-looking attribute (``runner._lock``)
      adds an anonymous ``?``-prefixed marker: it never satisfies a
      guard but still arms the blocking-under-lock rule.
    """

    def __init__(self, cm: ClassModel, ann: Annotations):
        self.cm = cm
        self.ann = ann
        self.fn: Optional[ast.FunctionDef] = None
        self.findings: List[Finding] = []

    # hooks -------------------------------------------------------------
    def on_attr(self, node: ast.Attribute, held: Tuple[str, ...]):
        pass

    def on_call(self, node: ast.Call, held: Tuple[str, ...]):
        pass

    def on_acquire(self, lock: str, held: Tuple[str, ...], node: ast.AST):
        pass

    # traversal ---------------------------------------------------------
    def walk_method(self, fn: ast.FunctionDef):
        self.fn = fn
        base = tuple(sorted(
            self.cm.canon_set(self.cm.requires.get(fn.name, set()))))
        self._block(fn.body, base)

    def context(self) -> str:
        return f"{self.cm.name}.{self.fn.name}" if self.fn else self.cm.name

    def emit(self, **kw):
        f = Finding(file=self.cm.filename, context=self.context(), **kw)
        if not self.ann.is_ignored(f.line, f.rule):
            self.findings.append(f)

    def _acquired_name(self, expr: ast.AST) -> Tuple[Optional[str], bool]:
        """(canonical lock name or anonymous marker, is_own_lock)."""
        attr = _self_attr(expr)
        if attr is not None and (attr in self.cm.locks
                                 or attr in self.cm.aliases):
            return self.cm.canon(attr), True
        if isinstance(expr, ast.Attribute) \
                and _FOREIGN_LOCK_RE.search(expr.attr):
            return f"?{expr.attr}", False
        return None, False

    def _block(self, stmts, held: Tuple[str, ...]):
        for s in stmts:
            self._stmt(s, held)

    def _stmt(self, s: ast.stmt, held: Tuple[str, ...]):
        if isinstance(s, (ast.With, ast.AsyncWith)):
            inner = list(held)
            for item in s.items:
                self._expr(item.context_expr, tuple(inner))
                if item.optional_vars is not None:
                    self._expr(item.optional_vars, tuple(inner))
                name, own = self._acquired_name(item.context_expr)
                if name is not None:
                    if own:
                        self.on_acquire(name, tuple(inner),
                                        item.context_expr)
                    inner.append(name)
            self._block(s.body, tuple(inner))
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in s.decorator_list:
                self._expr(dec, held)
            self._block(s.body, held)        # closure: def-site held set
        elif isinstance(s, ast.ClassDef):
            self._block(s.body, held)
        elif isinstance(s, ast.If):
            self._expr(s.test, held)
            self._block(s.body, held)
            self._block(s.orelse, held)
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            self._expr(s.target, held)
            self._expr(s.iter, held)
            self._block(s.body, held)
            self._block(s.orelse, held)
        elif isinstance(s, ast.While):
            self._expr(s.test, held)
            self._block(s.body, held)
            self._block(s.orelse, held)
        elif isinstance(s, ast.Try):
            self._block(s.body, held)
            for h in s.handlers:
                if h.type is not None:
                    self._expr(h.type, held)
                self._block(h.body, held)
            self._block(s.orelse, held)
            self._block(s.finalbody, held)
        elif hasattr(ast, "Match") and isinstance(s, ast.Match):
            self._expr(s.subject, held)
            for case in s.cases:
                if case.guard is not None:
                    self._expr(case.guard, held)
                self._block(case.body, held)
        else:
            self._expr(s, held)

    def _expr(self, node: ast.AST, held: Tuple[str, ...]):
        for n in ast.walk(node):
            if isinstance(n, ast.Attribute) and _self_attr(n) is not None:
                self.on_attr(n, held)
            elif isinstance(n, ast.Call):
                self.on_call(n, held)


def real_locks(held) -> Set[str]:
    """Drop the anonymous foreign-lock markers from a held set."""
    return {h for h in held if not h.startswith("?")}

"""Lock-discipline checker (rules ``guarded-attr`` and ``caller-locked``).

For every class with a lock model (see :mod:`repro.analysis.model`):

- every read/write of a ``# guarded by: <lock>`` attribute must happen
  with the canonical lock held — via an enclosing ``with self.<lock>:``
  or because the enclosing method is ``# requires: <lock>``;
- every ``self.<method>()`` call of a ``# requires:``-annotated method
  must happen with that method's required locks already held.

``__init__`` bodies are exempt (object not yet shared), as are the
guarded assignment lines themselves inside ``__init__``.
"""
from __future__ import annotations

import ast
from typing import List, Tuple

from repro.analysis.annotations import Annotations
from repro.analysis.findings import Finding
from repro.analysis.model import ClassModel, HeldWalker, real_locks


class _DisciplineWalker(HeldWalker):
    def __init__(self, cm: ClassModel, ann: Annotations):
        super().__init__(cm, ann)
        self.in_init = False

    def on_attr(self, node: ast.Attribute, held):
        if self.in_init:
            return
        lock = self.cm.guards.get(node.attr)
        if lock is None:
            return
        canon = self.cm.canon(lock)
        if canon in real_locks(held):
            return
        self.emit(
            rule="guarded-attr", line=node.lineno, symbol=node.attr,
            message=f"access of self.{node.attr} (guarded by "
                    f"{lock!r}) without holding it",
            hint=f"wrap in `with self.{lock}:`, or annotate the enclosing "
                 f"method `# requires: {lock}` if callers hold it")

    def on_call(self, node: ast.Call, held):
        fn = node.func
        if not (isinstance(fn, ast.Attribute)
                and isinstance(fn.value, ast.Name)
                and fn.value.id == "self"):
            return
        req = self.cm.requires.get(fn.attr)
        if not req:
            return
        missing = sorted(self.cm.canon_set(req) - real_locks(held))
        if not missing:
            return
        self.emit(
            rule="caller-locked", line=node.lineno, symbol=fn.attr,
            message=f"call of caller-locked self.{fn.attr}() without "
                    f"holding {', '.join(missing)}",
            hint=f"acquire `with self.{missing[0]}:` before the call (the "
                 f"callee is annotated `# requires:` and does not lock)")


def check_discipline(cm: ClassModel, ann: Annotations) -> List[Finding]:
    if not cm.guards and not cm.requires:
        return []
    w = _DisciplineWalker(cm, ann)
    for fn in cm.methods:
        w.in_init = fn.name == "__init__"
        w.walk_method(fn)
    return w.findings

"""Annotation grammar of the analysis plane, parsed from real comment
tokens (``tokenize``), never from raw line scans — so annotation-shaped
text inside string literals (e.g. the fixture snippets in
``tests/test_analysis.py``) is not misread as an annotation.

Grammar (each marker must START the comment):

- ``# guarded by: <lock>`` — trailing on the assignment that introduces a
  shared attribute: every read/write of that attribute must happen under
  ``with self.<lock>:`` (or inside a method annotated as requiring it).
- ``# requires: <lock>[, <lock>...]`` — on a ``def`` line (or the pure
  comment line directly above it): the method is caller-locked; callers
  must already hold the named locks.
- ``# analysis: ignore[<rule-id>[, <rule-id>...]] <justification>`` —
  suppress findings of the listed rules on this line (trailing comment)
  or on the next line (pure comment line). A justification is expected;
  the bracket list is validated against the rule registry.
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize
from typing import Dict, List, Set

from repro.analysis.findings import RULES, Finding

_GUARD_RE = re.compile(r"^guarded\s+by:\s*([A-Za-z_]\w*)\s*$")
_REQ_RE = re.compile(r"^requires:\s*([A-Za-z_]\w*(?:\s*,\s*[A-Za-z_]\w*)*)")
_IGN_RE = re.compile(r"^analysis:\s*ignore\[([^\]]*)\]")


@dataclasses.dataclass
class Annotations:
    """Per-file annotation map, keyed by physical (1-indexed) line."""
    guards: Dict[int, str] = dataclasses.field(default_factory=dict)
    requires: Dict[int, List[str]] = dataclasses.field(default_factory=dict)
    ignores: Dict[int, Set[str]] = dataclasses.field(default_factory=dict)
    # lines whose ignore/requires comment stands alone (applies downward)
    pure: Set[int] = dataclasses.field(default_factory=set)
    errors: List[Finding] = dataclasses.field(default_factory=list)

    def is_ignored(self, line: int, rule: str) -> bool:
        """True when ``rule`` is suppressed at ``line``: by a trailing
        comment on the line itself, or an ignore comment anywhere in the
        contiguous pure-comment block directly above it (so a suppression
        can carry a multi-line justification)."""
        rules = self.ignores.get(line)
        if rules is not None and ("*" in rules or rule in rules):
            return True
        cand = line - 1
        while cand in self.pure:
            rules = self.ignores.get(cand)
            if rules is not None:
                return "*" in rules or rule in rules
            cand -= 1
        return False

    def requires_for_def(self, def_line: int) -> List[str]:
        """Locks a ``def`` at ``def_line`` declares via ``requires:`` —
        trailing on the def line, or a pure comment directly above."""
        out = list(self.requires.get(def_line, []))
        if not out and (def_line - 1) in self.pure:
            out = list(self.requires.get(def_line - 1, []))
        return out


def parse_annotations(source: str, filename: str) -> Annotations:
    ann = Annotations()
    lines = source.splitlines()
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return ann   # the AST pass reports the parse error
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        text = tok.string.lstrip("#").strip()
        line, col = tok.start
        if col == 0 or lines[line - 1][:col].strip() == "":
            ann.pure.add(line)
        m = _GUARD_RE.match(text)
        if m:
            ann.guards[line] = m.group(1)
            continue
        m = _REQ_RE.match(text)
        if m:
            ann.requires[line] = [s.strip()
                                  for s in m.group(1).split(",") if s.strip()]
            continue
        m = _IGN_RE.match(text)
        if m:
            rules = {s.strip() for s in m.group(1).split(",") if s.strip()}
            if not rules:
                rules = {"*"}
            for r in rules:
                if r != "*" and r not in RULES:
                    ann.errors.append(Finding(
                        rule="bad-annotation", file=filename, line=line,
                        context="<module>", symbol=r,
                        message=f"unknown rule id {r!r} in analysis: "
                                f"ignore[...] (known: {sorted(RULES)})",
                        hint="fix the rule id typo or drop the suppression"))
            ann.ignores[line] = rules
    return ann

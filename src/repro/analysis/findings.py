"""Finding model + rule registry for the concurrency/donation analysis
plane (``python -m repro.analysis``).

Every checker emits :class:`Finding` records carrying a rule id, a
location, a stable identity key (used by the shrink-only baseline — line
numbers are display-only so findings survive unrelated code motion), and
a fix hint.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# rule id -> one-line description (also what ``--list-rules`` prints and
# what `# analysis: ignore[rule-id]` comments are validated against)
RULES = {
    "guarded-attr": (
        "read/write of a `# guarded by:` attribute outside a `with "
        "self.<lock>:` block (and outside a `# requires:` method)"),
    "caller-locked": (
        "call of a `# requires: <lock>` method without holding that lock"),
    "lock-order": (
        "inconsistent lock-acquisition order (a cycle in the inferred "
        "lock DAG, including re-acquiring a held non-reentrant lock)"),
    "blocking-under-lock": (
        "blocking call (sleep / file I/O / block_until_ready / "
        "ServerlessPlatform.invoke* / Thread.join) inside a lock region"),
    "use-after-donate": (
        "read of a buffer passed at a donate_argnums position after the "
        "donated jit call, without rebinding it from the jit's result"),
    "donated-params": (
        "a `params` argument appears in a donate_argnums set (params are "
        "shared with the trainer and sibling engines; donating "
        "invalidates them for every other holder)"),
    "bad-annotation": (
        "malformed analysis annotation: unknown lock in `guarded by:` / "
        "`requires:`, or unknown rule id in `analysis: ignore[...]`"),
    "parse-error": "file could not be parsed (syntax error)",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    file: str          # path as given to the runner (repo-relative in CI)
    line: int          # 1-indexed; display only, NOT part of the identity
    context: str       # "Class.method", "Class", or module-level function
    symbol: str        # attribute / lock-cycle / blocked call / arg name
    message: str
    hint: str = ""

    def key(self) -> Tuple[str, str, str, str]:
        """Stable identity for baseline matching (line-insensitive)."""
        return (self.file, self.rule, self.context, self.symbol)

    def render(self) -> str:
        out = f"{self.file}:{self.line}: [{self.rule}] {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

"""Entry point of the analysis plane: ``python -m repro.analysis``.

Usage::

    python -m repro.analysis src tests            # CI gate (with baseline)
    python -m repro.analysis --no-baseline src    # raw findings
    python -m repro.analysis --update-baseline src tests
    python -m repro.analysis --list-rules

Exit status: 0 when every finding is absorbed by the (shrink-only)
baseline; 1 on any new finding, baseline growth, or parse error.
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from typing import List

from repro.analysis.annotations import parse_annotations
from repro.analysis.baseline import (compare, counts_of, load_baseline,
                                     save_baseline)
from repro.analysis.discipline import check_discipline
from repro.analysis.donation import check_donation
from repro.analysis.findings import RULES, Finding
from repro.analysis.model import build_class_model
from repro.analysis.ordering import check_ordering

DEFAULT_BASELINE = os.path.join("results", "analysis_baseline.json")


def analyze_source(source: str, filename: str = "<memory>") -> List[Finding]:
    """Run all rule families over one source string (the API the test
    fixtures use)."""
    ann = parse_annotations(source, filename)
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError as exc:
        return [Finding(
            rule="parse-error", file=filename, line=exc.lineno or 0,
            context="<module>", symbol="syntax",
            message=f"could not parse: {exc.msg}", hint="fix the syntax")]
    findings: List[Finding] = list(ann.errors)
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        cm = build_class_model(node, ann, filename)
        findings.extend(cm.errors)
        findings.extend(check_discipline(cm, ann))
        findings.extend(check_ordering(cm, ann))
    findings.extend(check_donation(tree, ann, filename))
    return sorted(findings, key=lambda f: (f.file, f.line, f.rule))


def iter_py_files(paths: List[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d not in ("__pycache__", ".git"))
            out.extend(os.path.join(root, f)
                       for f in sorted(files) if f.endswith(".py"))
    return out


def analyze_paths(paths: List[str]) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        with open(path, encoding="utf-8") as fh:
            source = fh.read()
        findings.extend(analyze_source(source, path))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="concurrency & donation static analysis")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to analyze")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline file (default: {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline; any finding fails")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from live findings "
                         "(refuses to grow it)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule:22s} {desc}")
        return 0
    if not args.paths:
        ap.error("no paths given (try: python -m repro.analysis src tests)")

    findings = analyze_paths(args.paths)
    for f in findings:
        print(f.render())
    live = counts_of(findings)

    if args.no_baseline:
        print(f"{len(findings)} finding(s), no baseline")
        return 1 if findings else 0

    base = load_baseline(args.baseline)

    if args.update_baseline:
        grow = [k for k, n in live.items() if n > base.get(k, 0)]
        if base and grow:
            print("refusing to grow the baseline (it is shrink-only); "
                  "fix or `# analysis: ignore[...]` these instead:")
            for k in sorted(grow):
                print(f"  {k[0]} [{k[1]}] {k[2]}: {k[3]}")
            return 1
        save_baseline(args.baseline, live)
        print(f"baseline written: {args.baseline} ({len(live)} entries)")
        return 0

    failures, resolved = compare(live, base)
    for line in failures:
        print(line)
    for line in resolved:
        print(line)
    n = len(findings)
    if failures:
        print(f"FAIL: {len(failures)} violation(s) "
              f"({n} finding(s) total, baseline {len(base)} entries)")
        return 1
    print(f"OK: {n} finding(s), all absorbed by baseline "
          f"({len(base)} entries"
          + (f", {len(resolved)} resolved — shrink the file" if resolved
             else "") + ")")
    return 0

"""Use-after-donate checker (rules ``use-after-donate`` and
``donated-params``).

JAX buffer donation (``donate_argnums``) invalidates the caller's Python
reference: after ``new_cache, logits = self._decode_jit(params, tok,
cache)`` the old ``cache`` array is deleted on device and any later read
raises (or silently aliases garbage under some backends). The engine's
decode/prefill family relies on immediate rebinding; this checker makes
that contract machine-verified.

Detection:

- **donated defs** — ``@functools.partial(jax.jit, donate_argnums=...)``
  decorators and ``x = jax.jit(fn, donate_argnums=...)`` assignments.
  ``donate_argnums`` may be a literal int/tuple or a local name whose
  assignments are unioned (handles ``donate = (2,) if self.donate else
  ()`` — analysis assumes donation may happen).
- **donated callables** — ``self._decode_jit = _decode`` style aliases
  (attribute or plain name) of donated defs are tracked module-wide, so
  call sites in other methods are checked.
- **use-after-donate** — at each call of a donated callable, the
  positional args at donated indices are captured; a linear (source
  order) scan of the rest of the enclosing function flags any read of
  that expression before it is rebound. The jit-call's own assignment
  targets count as a rebind (``self._cache, out = self._decode_jit(...,
  self._cache)`` is clean).
- **donated-params** — at the jit definition, a donated position whose
  parameter is named ``params`` (or ``*_params``) is flagged
  unconditionally: params are shared with the trainer and sibling
  engines, so donation invalidates every other holder.

Known limitation (documented, not silent): the post-call scan is linear
in source order — a donated reference re-read via a loop back-edge is
missed. Keep donated dispatches straight-line, as the engine does.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.annotations import Annotations
from repro.analysis.findings import Finding


# --------------------------------------------------------------------------
# expression identity


def expr_key(node: ast.AST):
    """Structural identity for Name/Attribute chains, ctx-insensitive.
    Returns None for anything else (calls, subscripts, literals)."""
    if isinstance(node, ast.Name):
        return ("name", node.id)
    if isinstance(node, ast.Attribute):
        base = expr_key(node.value)
        if base is None:
            return None
        return ("attr", base, node.attr)
    return None


# --------------------------------------------------------------------------
# donate_argnums resolution


def _int_consts(node: ast.AST) -> Set[int]:
    return {n.value for n in ast.walk(node)
            if isinstance(n, ast.Constant) and isinstance(n.value, int)
            and not isinstance(n.value, bool)}


def _resolve_donate(kw_value: ast.AST,
                    scope: Optional[ast.AST]) -> Set[int]:
    """Union of all ints the donate_argnums expression can take."""
    if isinstance(kw_value, ast.Name) and scope is not None:
        out: Set[int] = set()
        for stmt in ast.walk(scope):
            if isinstance(stmt, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == kw_value.id
                    for t in stmt.targets):
                out |= _int_consts(stmt.value)
        return out
    return _int_consts(kw_value)


def _is_jit(node: ast.AST) -> bool:
    """Matches ``jax.jit`` / ``jit``."""
    return ((isinstance(node, ast.Attribute) and node.attr == "jit")
            or (isinstance(node, ast.Name) and node.id == "jit"))


def _is_partial(node: ast.AST) -> bool:
    return ((isinstance(node, ast.Attribute) and node.attr == "partial")
            or (isinstance(node, ast.Name) and node.id == "partial"))


def _donate_from_call(call: ast.Call,
                      scope: Optional[ast.AST]) -> Optional[Set[int]]:
    """Donate set when ``call`` is a jit compilation with donation:
    ``jax.jit(..., donate_argnums=D)`` or
    ``functools.partial(jax.jit, donate_argnums=D)``. None otherwise."""
    is_jit_call = _is_jit(call.func)
    is_partial_jit = (_is_partial(call.func) and call.args
                      and _is_jit(call.args[0]))
    if not (is_jit_call or is_partial_jit):
        return None
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            return _resolve_donate(kw.value, scope)
    return None


# --------------------------------------------------------------------------
# module-wide donated-callable registry


class DonationRegistry:
    def __init__(self):
        # def name -> (donate indices, positional param names, def line)
        self.defs: Dict[str, Tuple[Set[int], List[str], int]] = {}
        # self.<attr> / bare-name aliases of donated defs -> donate set
        self.attrs: Dict[str, Set[int]] = {}
        self.names: Dict[str, Set[int]] = {}

    def donate_for_call(self, func: ast.AST) -> Optional[Set[int]]:
        if isinstance(func, ast.Name):
            if func.id in self.names:
                return self.names[func.id]
            if func.id in self.defs:
                return self.defs[func.id][0]
        if (isinstance(func, ast.Attribute)
                and isinstance(func.value, ast.Name)
                and func.value.id == "self"
                and func.attr in self.attrs):
            return self.attrs[func.attr]
        return None


def build_registry(tree: ast.Module) -> DonationRegistry:
    reg = DonationRegistry()

    # donated defs: decorator form (scope for name resolution = the
    # function enclosing the def, if any)
    parents: Dict[ast.AST, ast.AST] = {}
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent

    def enclosing_func(node: ast.AST) -> Optional[ast.AST]:
        cur = parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = parents.get(cur)
        return None

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                donate = _donate_from_call(dec, enclosing_func(node))
                if donate:
                    params = ([a.arg for a in node.args.posonlyargs]
                              + [a.arg for a in node.args.args])
                    reg.defs[node.name] = (donate, params, node.lineno)

    # donated assignment forms: x = jax.jit(fn, donate_argnums=...),
    # self._decode_jit = _decode, x = _decode
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        donate: Optional[Set[int]] = None
        if isinstance(node.value, ast.Call):
            donate = _donate_from_call(node.value, enclosing_func(node))
        elif isinstance(node.value, ast.Name) \
                and node.value.id in reg.defs:
            donate = reg.defs[node.value.id][0]
        if not donate:
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                reg.names[tgt.id] = donate
            elif (isinstance(tgt, ast.Attribute)
                  and isinstance(tgt.value, ast.Name)
                  and tgt.value.id == "self"):
                reg.attrs[tgt.attr] = donate
    return reg


# --------------------------------------------------------------------------
# checks


def _check_donated_params(reg: DonationRegistry, filename: str,
                          ann: Annotations) -> List[Finding]:
    out: List[Finding] = []
    for name, (donate, params, line) in sorted(reg.defs.items()):
        for i in sorted(donate):
            if i < len(params) and (params[i] == "params"
                                    or params[i].endswith("_params")):
                f = Finding(
                    rule="donated-params", file=filename, line=line,
                    context=name, symbol=params[i],
                    message=f"donate_argnums includes position {i} "
                            f"({params[i]!r}) of jit {name!r}: params are "
                            f"shared with the trainer and sibling engines",
                    hint="donate only engine-private buffers (KV caches); "
                         "drop the params index from donate_argnums")
                if not ann.is_ignored(line, f.rule):
                    out.append(f)
    return out


def _flat_stmts(fn: ast.AST) -> List[ast.stmt]:
    """Statements of ``fn`` in source order, excluding nested function
    bodies (their timelines are independent)."""
    out: List[ast.stmt] = []

    def rec(stmts):
        for s in stmts:
            out.append(s)
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
                continue
            for field in ("body", "orelse", "finalbody"):
                rec(getattr(s, field, []) or [])
            for h in getattr(s, "handlers", []) or []:
                rec(h.body)
            for c in getattr(s, "cases", []) or []:
                rec(c.body)
    rec(fn.body)
    return out


def _writes_in(stmt: ast.stmt) -> List:
    keys = []
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        targets = [stmt.target]
    for t in targets:
        for n in ast.walk(t):
            k = expr_key(n)
            if k is not None:
                keys.append(k)
    return keys


def _reads_in(stmt: ast.stmt, skip: ast.AST = None) -> List[Tuple[object, int]]:
    """(key, line) for every Name/Attribute read in ``stmt``, excluding
    pure Store contexts and the subtree ``skip``."""
    skip_nodes = set(ast.walk(skip)) if skip is not None else set()
    out = []
    if isinstance(stmt, ast.AugAssign):
        # `x += 1` reads x even though the target ctx is Store
        k = expr_key(stmt.target)
        if k is not None:
            out.append((k, stmt.target.lineno))
    for n in ast.walk(stmt):
        if n in skip_nodes:
            continue
        if isinstance(n, (ast.Name, ast.Attribute)) \
                and isinstance(getattr(n, "ctx", None), ast.Load):
            k = expr_key(n)
            if k is not None:
                out.append((k, n.lineno))
    return out


def _own_exprs(stmt: ast.stmt) -> List[ast.AST]:
    """Expression subtrees owned by ``stmt`` itself (not by a nested
    statement) — where a donated call in this statement can live."""
    if isinstance(stmt, (ast.If, ast.While)):
        return [stmt.test]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.target, stmt.iter]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        out: List[ast.AST] = []
        for item in stmt.items:
            out.append(item.context_expr)
            if item.optional_vars is not None:
                out.append(item.optional_vars)
        return out
    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.ClassDef, ast.Try)):
        return []
    if hasattr(ast, "Match") and isinstance(stmt, ast.Match):
        return [stmt.subject]
    return [stmt]


def _render_key(k) -> str:
    if k[0] == "name":
        return k[1]
    return f"{_render_key(k[1])}.{k[2]}"


def check_donation(tree: ast.Module, ann: Annotations,
                   filename: str) -> List[Finding]:
    reg = build_registry(tree)
    findings = _check_donated_params(reg, filename, ann)
    if not (reg.defs or reg.names or reg.attrs):
        return findings

    # enclosing-context names for findings
    contexts: List[Tuple[ast.AST, str]] = []

    def collect(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                name = f"{prefix}{child.name}"
                contexts.append((child, name))
                collect(child, f"{name}.")
            elif isinstance(child, ast.ClassDef):
                collect(child, f"{prefix}{child.name}.")
            else:
                collect(child, prefix)
    collect(tree, "")

    for fn, ctx in contexts:
        stmts = _flat_stmts(fn)
        for idx, stmt in enumerate(stmts):
            calls = [n for expr in _own_exprs(stmt)
                     for n in ast.walk(expr) if isinstance(n, ast.Call)]
            for call in calls:
                donate = reg.donate_for_call(call.func)
                if donate is None:
                    continue
                rebound = set(_writes_in(stmt))
                for i in sorted(donate):
                    if i >= len(call.args):
                        continue
                    k = expr_key(call.args[i])
                    if k is None or k in rebound:
                        continue
                    # linear read-before-rebind scan of the rest of fn
                    for later in stmts[idx + 1:]:
                        hit = next((ln for kk, ln in _reads_in(later)
                                    if kk == k), None)
                        if hit is not None:
                            f = Finding(
                                rule="use-after-donate", file=filename,
                                line=hit, context=ctx,
                                symbol=_render_key(k),
                                message=f"read of {_render_key(k)} after "
                                        f"it was donated to a jit at line "
                                        f"{call.lineno} (buffer is "
                                        f"invalidated by donation)",
                                hint="rebind the reference from the jit's "
                                     "return value before any further "
                                     "use, as the engine decode path does")
                            if not ann.is_ignored(hit, f.rule):
                                findings.append(f)
                            break
                        if k in _writes_in(later):
                            break   # rebound before any read: clean
    return findings

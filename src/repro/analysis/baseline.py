"""Shrink-only baseline for the analysis plane.

``results/analysis_baseline.json`` absorbs legacy findings so the
checker could land green on a tree with pre-existing debt, while CI
still fails the moment anything NEW appears or the debt grows:

- a finding whose key ``(file, rule, context, symbol)`` is absent from
  the baseline -> failure (new finding);
- a key whose live count exceeds its baselined count -> failure (an old
  problem got worse);
- a baselined key with no live finding -> the runner prints it as a
  resolved entry to DELETE from the file (exit 0, but the nag is loud).

Keys are line-insensitive so unrelated code motion never churns the
file. ``--update-baseline`` rewrites the file from the live findings
but refuses to grow it — debt can only be paid down.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Tuple

from repro.analysis.findings import Finding

Key = Tuple[str, str, str, str]
_FIELDS = ("file", "rule", "context", "symbol")


def counts_of(findings: List[Finding]) -> Dict[Key, int]:
    out: Dict[Key, int] = {}
    for f in findings:
        out[f.key()] = out.get(f.key(), 0) + 1
    return out


def load_baseline(path: str) -> Dict[Key, int]:
    if not os.path.exists(path):
        return {}
    with open(path) as fh:
        data = json.load(fh)
    out: Dict[Key, int] = {}
    for e in data.get("entries", []):
        key = tuple(e[f] for f in _FIELDS)
        out[key] = out.get(key, 0) + int(e.get("count", 1))
    return out


def save_baseline(path: str, counts: Dict[Key, int]) -> None:
    entries = [dict(zip(_FIELDS, key), count=n)
               for key, n in sorted(counts.items())]
    with open(path, "w") as fh:
        json.dump({"entries": entries}, fh, indent=2, sort_keys=True)
        fh.write("\n")


def compare(live: Dict[Key, int],
            base: Dict[Key, int]) -> Tuple[List[str], List[str]]:
    """(failures, resolved-entry nags) from live findings vs baseline."""
    failures: List[str] = []
    for key, n in sorted(live.items()):
        b = base.get(key, 0)
        if b == 0:
            failures.append(
                "new finding (not in baseline): "
                f"{key[0]} [{key[1]}] {key[2]}: {key[3]} (x{n})")
        elif n > b:
            failures.append(
                f"baseline growth: {key[0]} [{key[1]}] {key[2]}: "
                f"{key[3]} went {b} -> {n}")
    resolved = [
        f"resolved (delete from baseline): {key[0]} [{key[1]}] "
        f"{key[2]}: {key[3]} (was x{n})"
        for key, n in sorted(base.items()) if key not in live]
    return failures, resolved

"""Lock-order + blocking-under-lock checker.

**lock-order** — per class, build the acquisition graph: an edge
``A -> B`` means some code path acquires B while already holding A.
Edges come from three places:

- syntactically nested ``with self.A: ... with self.B:`` blocks;
- a method annotated ``# requires: A`` that acquires B in its body;
- interprocedural self-calls: if ``m()`` holds A when it calls
  ``self.n()``, every lock n() can acquire (computed to fixed point over
  the self-call graph) is ordered after A.

Any cycle — including the self-loop of re-acquiring a held
``threading.Lock`` (non-reentrant: instant deadlock) — is a finding.
The graph is per-class; cross-class cycles (e.g. engine vs proxy) are
out of scope and must be handled by design (documented in the engine
module docstring).

**blocking-under-lock** — flag calls from a blocklist made while any
lock (own or foreign-looking) is held:

- ``time.sleep``, ``open(...)``, ``np.savez``/``np.savez_compressed``/
  ``np.load``, ``pickle.dump``/``pickle.load``, ``shutil.rmtree``,
  ``os.replace`` — file I/O and sleeps serialize every sibling thread;
- ``.block_until_ready()`` — synchronizes the device stream;
- ``.invoke()`` / ``.invoke_async()`` — ServerlessPlatform entry points
  (cold starts can take seconds);
- ``.join()`` with no positional args — Thread.join (``str.join`` /
  ``os.path.join`` always take one, so they pass);
- ``.get_batch()`` — blocks on the buffer condition until data arrives.

``.wait()`` / ``.wait_for()`` are deliberately allowed: a Condition
releases its lock while waiting — that is the correct idiom.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.annotations import Annotations
from repro.analysis.findings import Finding
from repro.analysis.model import ClassModel, HeldWalker

_BLOCKING_FUNCS = {
    ("time", "sleep"), ("np", "savez"), ("np", "savez_compressed"),
    ("np", "load"), ("numpy", "savez"), ("numpy", "savez_compressed"),
    ("numpy", "load"), ("pickle", "dump"), ("pickle", "load"),
    ("shutil", "rmtree"), ("os", "replace"),
}
_BLOCKING_METHODS = {"block_until_ready", "invoke", "invoke_async",
                     "get_batch"}


def _blocking_name(call: ast.Call):
    """Human-readable name when ``call`` is on the blocklist, else None."""
    fn = call.func
    if isinstance(fn, ast.Name) and fn.id == "open":
        return "open"
    if isinstance(fn, ast.Attribute):
        if isinstance(fn.value, ast.Name) \
                and (fn.value.id, fn.attr) in _BLOCKING_FUNCS:
            return f"{fn.value.id}.{fn.attr}"
        if fn.attr in _BLOCKING_METHODS:
            return f".{fn.attr}"
        if fn.attr == "join" and not call.args:
            # Thread.join() / Thread.join(timeout=...); str.join and
            # os.path.join always pass a positional iterable/component.
            return ".join"
    return None


class _OrderWalker(HeldWalker):
    """Records acquisition edges + self-call sites, flags blocking calls
    and same-lock re-acquisition as it walks."""

    def __init__(self, cm: ClassModel, ann: Annotations):
        super().__init__(cm, ann)
        # edges[(A, B)] = first (line, method) where B acquired under A
        self.edges: Dict[Tuple[str, str], Tuple[int, str]] = {}
        # method -> set of locks it acquires directly
        self.direct: Dict[str, Set[str]] = {}
        # (caller, callee, held-at-call-site, line)
        self.calls: List[Tuple[str, str, Tuple[str, ...], int]] = []

    def walk_method(self, fn):
        self.direct.setdefault(fn.name, set())
        super().walk_method(fn)

    def on_acquire(self, lock, held, node):
        self.direct[self.fn.name].add(lock)
        if lock in held:
            self.emit(
                rule="lock-order", line=node.lineno, symbol=lock,
                message=f"re-acquisition of already-held {lock!r} "
                        f"(non-reentrant Lock: self-deadlock)",
                hint="hoist the inner `with`, or split the method with a "
                     "`# requires:`-annotated locked helper")
        for h in held:
            if not h.startswith("?") and h != lock:
                self.edges.setdefault((h, lock),
                                      (node.lineno, self.fn.name))

    def on_call(self, node: ast.Call, held):
        name = _blocking_name(node)
        if name is not None and held:
            self.emit(
                rule="blocking-under-lock", line=node.lineno, symbol=name,
                message=f"blocking call {name}(...) while holding "
                        f"{', '.join(h.lstrip('?') for h in held)}",
                hint="stage the data under the lock, release it, then "
                     "block (see RolloutSnapshotter.save for the idiom)")
        fn = node.func
        if (isinstance(fn, ast.Attribute)
                and isinstance(fn.value, ast.Name)
                and fn.value.id == "self"):
            self.calls.append((self.fn.name, fn.attr, held, node.lineno))


def _closure(direct: Dict[str, Set[str]],
             callgraph: Dict[str, Set[str]]) -> Dict[str, Set[str]]:
    """A(m) = direct(m) ∪ ⋃ A(self-callees of m), to fixed point."""
    acq = {m: set(s) for m, s in direct.items()}
    changed = True
    while changed:
        changed = False
        for m, callees in callgraph.items():
            for c in callees:
                extra = acq.get(c, set()) - acq.setdefault(m, set())
                if extra:
                    acq[m] |= extra
                    changed = True
    return acq


def check_ordering(cm: ClassModel, ann: Annotations) -> List[Finding]:
    # Always walk: a class with no locks of its OWN can still block under
    # a foreign lock region (``with runner._lock: np.savez(...)``).
    w = _OrderWalker(cm, ann)
    for fn in cm.methods:
        w.walk_method(fn)
    findings = list(w.findings)

    # interprocedural edges: held locks at a self-call site precede
    # everything the callee (transitively) acquires
    callgraph: Dict[str, Set[str]] = {}
    for caller, callee, _held, _line in w.calls:
        callgraph.setdefault(caller, set()).add(callee)
    acq = _closure(w.direct, callgraph)
    for caller, callee, held, line in w.calls:
        for h in held:
            if h.startswith("?"):
                continue
            for b in acq.get(callee, set()):
                if b != h:
                    w.edges.setdefault((h, b), (line, caller))

    # cycle detection over the two-or-more-lock edges (self-loops were
    # already flagged at the acquisition site)
    graph: Dict[str, Set[str]] = {}
    for (a, b) in w.edges:
        graph.setdefault(a, set()).add(b)

    reported: Set[frozenset] = set()

    def dfs(start: str, node: str, path: List[str], seen: Set[str]):
        for nxt in sorted(graph.get(node, ())):
            if nxt == start and len(path) > 1:
                cyc = frozenset(path)
                if cyc in reported:
                    continue
                reported.add(cyc)
                line, meth = w.edges[(path[-1], start)]
                order = " -> ".join(path + [start])
                f = Finding(
                    rule="lock-order", file=cm.filename, line=line,
                    context=f"{cm.name}.{meth}",
                    symbol="<->".join(sorted(cyc)),
                    message=f"inconsistent lock order: cycle {order} in "
                            f"the acquisition graph of {cm.name}",
                    hint="pick one canonical order and restructure the "
                         "minority path (document it in the module "
                         "docstring)")
                if not ann.is_ignored(f.line, f.rule):
                    findings.append(f)
            elif nxt not in seen:
                dfs(start, nxt, path + [nxt], seen | {nxt})

    for start in sorted(graph):
        dfs(start, start, [start], {start})
    return findings

"""Versioned pytree checkpointing (npz + JSON treedef), used by the training
worker for fault recovery ("training-worker failures restart from the latest
checkpoint", paper §8)."""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, tree, step: int = 0) -> str:
    """Atomically save a pytree. Returns the checkpoint directory."""
    ckpt_dir = os.path.join(path, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=path if os.path.isdir(path) else None,
                           prefix=".tmp_ckpt_")
    try:
        leaves, treedef = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"treedef": str(treedef), "num_leaves": len(leaves),
                       "step": step}, f)
        if os.path.exists(ckpt_dir):
            shutil.rmtree(ckpt_dir)
        os.replace(tmp, ckpt_dir)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    return ckpt_dir


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for d in os.listdir(path)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


def restore(path: str, like, step: Optional[int] = None):
    """Restore into the structure of ``like`` (a pytree template)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    ckpt_dir = os.path.join(path, f"step_{step:08d}")
    data = np.load(os.path.join(ckpt_dir, "arrays.npz"))
    leaves, treedef = _flatten(like)
    if len(leaves) != len(data.files):
        raise ValueError(f"leaf count mismatch: template {len(leaves)} vs "
                         f"checkpoint {len(data.files)}")
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for tpl, got in zip(leaves, new_leaves):
        if tuple(np.shape(tpl)) != tuple(got.shape):
            raise ValueError(f"shape mismatch {np.shape(tpl)} vs {got.shape}")
    return jax.tree.unflatten(treedef, new_leaves), step

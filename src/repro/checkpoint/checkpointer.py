"""Versioned pytree checkpointing (npz + JSON treedef), used by the training
worker for fault recovery ("training-worker failures restart from the latest
checkpoint", paper §8).

Crash safety contract:
- ``save`` stages into a ``.tmp_ckpt_*`` dir INSIDE ``path`` (created up
  front) and publishes with one atomic ``os.replace``, so a crash mid-save
  never clobbers the previous ``latest_step``;
- ``latest_step`` ignores leftover ``.tmp_ckpt_*`` staging dirs from a
  crashed save (and anything else that is not a ``step_*`` directory);
- ``keep_last`` prunes old ``step_*`` dirs after a successful save (and
  sweeps dead staging dirs), bounding disk growth across long runs;
- a checkpoint whose ``arrays.npz``/``meta.json`` cannot be read raises
  :class:`CorruptCheckpointError` — the FT supervisor catches it and falls
  back to step N-1 (see ``repro.ft.supervisor``).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


class CorruptCheckpointError(ValueError):
    """A checkpoint directory exists but its payload cannot be read."""


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, tree, step: int = 0,
         keep_last: Optional[int] = None) -> str:
    """Atomically save a pytree. Returns the checkpoint directory.

    The staging dir always lives inside ``path`` (created if missing), so
    the final ``os.replace`` is same-directory atomic and a crashed save
    never litters the caller's CWD. ``keep_last`` prunes all but the newest
    N ``step_*`` dirs (plus any dead staging dirs) after publication.
    """
    os.makedirs(path, exist_ok=True)
    ckpt_dir = os.path.join(path, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=path, prefix=".tmp_ckpt_")
    try:
        leaves, treedef = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"treedef": str(treedef), "num_leaves": len(leaves),
                       "step": step}, f)
        if os.path.exists(ckpt_dir):
            shutil.rmtree(ckpt_dir)
        os.replace(tmp, ckpt_dir)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    if keep_last is not None:
        prune(path, keep_last)
    return ckpt_dir


def versioned_steps(path: str, prefix: str = "step_") -> List[int]:
    """All published ``<prefix>NNNNNNNN`` dirs under ``path``, ascending.
    Staging dirs and stray files never match. Shared with the rollout
    snapshotter (``rollout_`` prefix) so both sides of a paired
    checkpoint follow one directory-versioning contract."""
    if not os.path.isdir(path):
        return []
    pat = re.compile(re.escape(prefix) + r"(\d+)$")
    out = [int(m.group(1)) for d in os.listdir(path)
           if (m := pat.match(d))
           and os.path.isdir(os.path.join(path, d))]
    return sorted(out)


def prune_versioned(path: str, keep_last: int, prefix: str = "step_",
                    tmp_prefix: str = ".tmp_ckpt_"):
    """Delete all but the newest ``keep_last`` ``<prefix>*`` dirs, plus
    any ``<tmp_prefix>*`` staging dirs a crashed save left behind."""
    if keep_last < 1:
        raise ValueError(f"keep_last must be >= 1, got {keep_last}")
    for s in versioned_steps(path, prefix)[:-keep_last]:
        shutil.rmtree(os.path.join(path, f"{prefix}{s:08d}"),
                      ignore_errors=True)
    for d in os.listdir(path):
        if d.startswith(tmp_prefix):
            shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def steps(path: str) -> List[int]:
    """All published checkpoint steps under ``path``, ascending."""
    return versioned_steps(path)


def latest_step(path: str) -> Optional[int]:
    all_steps = steps(path)
    return all_steps[-1] if all_steps else None


def prune(path: str, keep_last: int):
    return prune_versioned(path, keep_last)


def restore(path: str, like, step: Optional[int] = None):
    """Restore into the structure of ``like`` (a pytree template).

    Raises :class:`CorruptCheckpointError` when the checkpoint payload is
    unreadable (truncated npz, malformed meta.json) and ``ValueError``
    naming the step and both leaf counts on a template mismatch.
    """
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    ckpt_dir = os.path.join(path, f"step_{step:08d}")
    try:
        data = np.load(os.path.join(ckpt_dir, "arrays.npz"))
        with open(os.path.join(ckpt_dir, "meta.json")) as f:
            meta = json.load(f)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        raise CorruptCheckpointError(
            f"checkpoint step {step} under {path} is corrupt: {e}") from e
    leaves, treedef = _flatten(like)
    n_ckpt = int(meta.get("num_leaves", len(data.files)))
    if len(leaves) != n_ckpt or len(data.files) != n_ckpt:
        raise ValueError(
            f"checkpoint step {step}: leaf count mismatch — template has "
            f"{len(leaves)} leaves, checkpoint recorded {n_ckpt} "
            f"(npz holds {len(data.files)})")
    if meta.get("treedef") is not None and meta["treedef"] != str(treedef):
        raise ValueError(
            f"checkpoint step {step}: treedef mismatch — the template's "
            "pytree structure differs from the one saved "
            f"({n_ckpt} leaves each); was the model config changed?")
    try:
        new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    except Exception as e:  # zip member truncated / missing
        raise CorruptCheckpointError(
            f"checkpoint step {step} under {path} is corrupt: {e}") from e
    for tpl, got in zip(leaves, new_leaves):
        if tuple(np.shape(tpl)) != tuple(got.shape):
            raise ValueError(
                f"checkpoint step {step}: shape mismatch "
                f"{np.shape(tpl)} vs {got.shape}")
    return jax.tree.unflatten(treedef, new_leaves), step

"""Token sampling (temperature / top-k / top-p) with logprob bookkeeping."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample_tokens(key, logits: jnp.ndarray, temperature: float = 1.0,
                  top_k: Optional[int] = None, top_p: Optional[float] = None):
    """logits: [B,V]. Returns (tokens [B], logprobs [B]).

    logprobs are w.r.t. the *sampling* distribution's base logits (after
    temperature/filtering), which is what importance ratios in GRPO need.
    """
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:   # greedy
        tokens = jnp.argmax(logits, axis=-1)
        lp = jax.nn.log_softmax(logits, axis=-1)
        return tokens, jnp.take_along_axis(lp, tokens[:, None], -1)[:, 0]
    logits = logits / temperature
    if top_k is not None and top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p is not None and 0.0 < top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], -1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    lp = jax.nn.log_softmax(logits, axis=-1)
    tokens = jax.random.categorical(key, logits, axis=-1)
    return tokens, jnp.take_along_axis(lp, tokens[:, None], -1)[:, 0]


def sample_mixed(key, logits: jnp.ndarray, temperatures):
    """Slot-batched sampling with per-row temperature and greedy fallback.

    logits: [B,V]; temperatures: scalar or [B] — rows with temperature <= 0
    take the argmax (with the full-softmax logprob GRPO ratios need), the
    rest sample at their own temperature. This is the sampler the engine's
    decode paths run INSIDE jit — the single-step dispatch, the admission
    prefill, and every iteration of the scanned multi-token decode body —
    so it stays purely functional in (key, logits, temperatures).

    Returns (tokens [B], logprobs [B]) w.r.t. the sampling distribution.
    """
    t = jnp.broadcast_to(jnp.asarray(temperatures, jnp.float32),
                         logits.shape[:1])
    scaled = logits / jnp.clip(t, 1e-6)[:, None]
    toks, lps = sample_tokens(key, scaled, temperature=1.0)
    toks_g = jnp.argmax(logits, axis=-1)
    lp_g = jnp.take_along_axis(
        jax.nn.log_softmax(logits, -1), toks_g[:, None], -1)[:, 0]
    use_greedy = t <= 0.0
    return (jnp.where(use_greedy, toks_g, toks),
            jnp.where(use_greedy, lp_g, lps))

"""Continuous-batching inference engine (the paper's command-driven event
loop, Fig. 8): a slot-based engine over ``Model.decode_step`` that polls
ADD/ABORT commands between engine steps, so adding or aborting a trajectory
never stalls ongoing generation. This is the JAX stand-in for vLLM/SGLang in
the data plane, and the unit LLMProxy dispatches to.

Also implements the weight-sync hooks of the §6.2 protocol: ``suspend`` /
``resume`` / ``update_params`` (with KV-cache recomputation for in-flight
trajectories, step (5) of the protocol).

Prefill/decode disaggregation (§6.3, live counterpart of the simulator's
``pd_disagg`` mode): an engine can be constructed with
``role="prefill"`` — it runs the compute-bound prefill, samples the first
token, then packages the slot's KV cache as a :class:`KVHandoff` and emits
it through ``on_handoff`` instead of decoding — or ``role="decode"``,
which accepts handoffs via :meth:`inject` and runs the bandwidth-bound
decode loop. ``LLMProxy(pd_disagg=True)`` routes between the two roles.

The decode hot path is device-resident (§5.2/§6.3 make decode the
bandwidth-bound phase worth optimizing): each engine step runs
``steps_per_dispatch`` decode steps in ONE jit dispatch
(``Model.decode_block``, a ``lax.scan`` with on-device stop/length
masking and sampling inside the body), the KV-cache argument of every
compiled entry point is donated so XLA updates it in place instead of
copying ``[max_slots, max_len]`` worth of cache per step, and admission
prefill pads prompts to power-of-two buckets while writing the slot's
cache row directly (O(log max_len) compiled prefill shapes, no transient
batch-1 cache). Commands still drain between macro-steps, so ADD/ABORT
latency is bounded by one macro-step (K decode tokens per slot).

TP engine groups: constructed with a ``mesh`` (a per-engine (1, n)
("data", "model") group mesh), the engine executes SHARDED over its
device group — params and KV cache are placed with per-leaf
NamedShardings, every jit dispatch runs inside an ``axis_rules`` context
so the model's ``shd`` annotations become GSPMD constraints, KV-slot
handoffs gather to host numpy (portable across unequal group sizes),
and sharded weight sync assembles per-shard chunks straight into each
device's shard (:meth:`update_from_chunks` — no full per-engine copy).
Donation rules are UNCHANGED: the sharded cache is still donated
per-jit, params are never donated (mesh engines own a private placed
copy, but the host pytree stays shared with trainer/store/siblings).

Locking (machine-checked by ``python -m repro.analysis``; see the
``# guarded by:`` / ``# requires:`` annotations):

- ``_lock`` guards the command queue and result map (``_commands``,
  ``_results``): the cheap, contended producer/consumer state.
- ``_step_lock`` guards the slot/cache/param state and the stat counters:
  the expensive, step-granular state.
- **Canonical order: ``_step_lock`` -> ``_lock``** — the step path holds
  ``_step_lock`` and briefly takes ``_lock`` to drain commands or post
  results (``crash`` nests them the same way). Nothing may take
  ``_step_lock`` while holding ``_lock``.
- Cross-class: the proxy calls ``inject``/``add_request`` (which take
  only ``_lock``) while holding its own routing lock, and the engine
  calls ``on_finish``/``on_handoff`` hooks (which take the proxy's lock)
  while holding ``_step_lock``. That is only deadlock-free because no
  engine path takes ``_step_lock`` under the proxy's lock — which is why
  ``num_active``/``inflight_decode_tokens`` (read by the proxy under its
  lock) are deliberately lock-free racy reads, not ``_step_lock``
  acquisitions. Use :meth:`stats` for a consistent counter snapshot.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import threading
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import (SERVE_RULES, axis_rules,
                                        param_sharding, validate_group)
from repro.models.model import Model
from repro.rl.paged_kv import PagedKVAllocator, PrefixCache
from repro.rl.sampling import sample_mixed


@dataclasses.dataclass
class GenRequest:
    request_id: str
    prompt: List[int]
    max_new_tokens: int = 64
    temperature: float = 1.0
    top_k: Optional[int] = None
    stop_tokens: Sequence[int] = ()
    tag: str = "default"          # task-domain tag (hardware affinity, R1)


@dataclasses.dataclass
class GenResult:
    request_id: str
    tokens: List[int]             # newly generated tokens
    logprobs: List[float]
    finish_reason: str            # "stop" | "length" | "aborted"
    weight_version: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0


@dataclasses.dataclass
class KVHandoff:
    """A prefilled trajectory in flight between a prefill-role and a
    decode-role engine: the request, the token/logprob state after the
    first sampled token, and the slot's cache pytree (batch axis == 1,
    extracted with ``Model.extract_cache_slot`` and gathered to HOST
    numpy arrays). The host gather is what makes the handoff portable
    across engines with *different* TP group sizes — injection re-shards
    the slot under the target engine's own mesh. Both engines must share
    the same model and ``max_len`` for the cache shapes to line up."""
    request: GenRequest
    tokens: List[int]             # prompt + first sampled token
    new_tokens: List[int]
    logprobs: List[float]
    pos: int
    start_version: int
    cache: object
    weight_version: int = 0       # weights the cache was prefilled under
    source: str = ""              # originating pool/engine (stats only)


@dataclasses.dataclass
class _Slot:
    active: bool = False
    request: Optional[GenRequest] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    new_tokens: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    pos: int = 0                  # absolute position of next token slot
    start_version: int = 0        # weight version at trajectory start


ROLES = ("colocated", "prefill", "decode")


class EngineKilledError(RuntimeError):
    """Raised inside a step when :meth:`InferenceEngine.hard_kill` fired:
    the step unwinds (releasing ``_step_lock``) and ``step()`` converts
    the kill into a :meth:`crash` — the SIGKILL-plus-replacement-process
    model the watchdog uses to un-wedge a silently hung engine."""


def _slice_chunks(parts, dim: int, idx, shape) -> np.ndarray:
    """Assemble ``full[idx]`` from equal-size chunks of ``full`` along
    ``dim`` WITHOUT concatenating the full array: only the chunks
    overlapping the requested slice are touched. ``idx`` is the per-dim
    slice tuple a ``make_array_from_callback`` device callback receives;
    contiguous (step-1) slices only, which is all NamedSharding asks."""
    norm = [slice(*sl.indices(n)) for sl, n in zip(idx, shape)]
    if len(parts) == 1:
        return np.ascontiguousarray(np.asarray(parts[0])[tuple(norm)])
    csize = int(np.shape(parts[0])[dim])
    start, stop = norm[dim].start, norm[dim].stop
    pieces = []
    for c in range(start // csize, (stop - 1) // csize + 1):
        lo = max(start - c * csize, 0)
        hi = min(stop - c * csize, csize)
        sub = list(norm)
        sub[dim] = slice(lo, hi)
        pieces.append(np.asarray(parts[c])[tuple(sub)])
    out = (pieces[0] if len(pieces) == 1
           else np.concatenate(pieces, axis=dim))
    return np.ascontiguousarray(out)


class InferenceEngine:
    """Slot-based continuous batching engine.

    ``role`` selects the engine's place in the data plane: ``"colocated"``
    (default) serves prefill and decode monolithically; ``"prefill"`` only
    prefills and emits a ``KVHandoff`` per admitted request through
    ``on_handoff``; ``"decode"`` continues handed-off trajectories injected
    via :meth:`inject` (it can also serve raw ADDs as a fallback, but the
    proxy never routes them here in disaggregated mode).
    """

    def __init__(self, model: Model, params, *, max_slots: int = 8,
                 max_len: int = 512, seed: int = 0,
                 on_finish: Optional[Callable[[GenResult], None]] = None,
                 role: str = "colocated",
                 on_handoff: Optional[Callable[[KVHandoff], None]] = None,
                 steps_per_dispatch: int = 8, donate: bool = True,
                 bucketed_prefill: Optional[bool] = None,
                 mesh=None, shard_rules: Optional[Dict] = None,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None):
        """``steps_per_dispatch`` (K) is the decode macro-step size: K
        decode steps run per jit dispatch via ``Model.decode_block``.
        Larger K amortizes dispatch + host round-trip overhead but bounds
        command latency — an ABORT queued mid-macro-step takes effect up
        to K tokens later — so latency-sensitive serving should lower it
        (K=1 selects the legacy single-step dispatch). ``donate=False``
        disables KV-cache buffer donation (the un-donated copy-per-step
        baseline, kept for benchmarks/decode_hotpath.py).
        ``bucketed_prefill`` force-disables (False) the power-of-two
        prompt bucketing on stacks that support it — the
        one-compile-per-prompt-length seed behavior, kept for the same
        benchmark; None (default) enables it wherever valid.

        ``mesh`` (optional) is the engine's TP device group — a
        ``launch.mesh.make_group_mesh`` (1, n) ("data", "model") mesh.
        With a mesh the engine executes SHARDED over the group: params
        and the KV cache are placed with per-leaf NamedShardings under
        ``shard_rules`` (default SERVE_RULES), every jit traces inside an
        ``axis_rules`` context so the model's ``shd`` annotations become
        sharding constraints, and the engine owns a PRIVATE placed param
        copy (single-device engines keep sharing the caller's pytree).
        An n that shards no parameter dim raises (``validate_group``)
        instead of silently replicating the model n-fold."""
        if role not in ROLES:
            raise ValueError(f"role must be one of {ROLES}, got {role!r}")
        if steps_per_dispatch < 1:
            raise ValueError("steps_per_dispatch must be >= 1, got "
                             f"{steps_per_dispatch}")
        self.model = model
        self.params = params                       # guarded by: _step_lock
        self.mesh = mesh
        self.tp_group = (int(np.prod(mesh.devices.shape))
                         if mesh is not None else 1)
        self._shard_rules = (dict(shard_rules) if shard_rules is not None
                             else dict(SERVE_RULES))
        if self.tp_group > 1:
            validate_group(params, self.tp_group, self._shard_rules,
                           model.cfg.name)
        self.max_slots = max_slots
        self.max_len = max_len
        self.on_finish = on_finish
        self.role = role                           # guarded by: _step_lock
        self.on_handoff = on_handoff
        # streaming hook (Rollout-as-a-Service tier): called under
        # _step_lock with (request_id, CUMULATIVE new_tokens, CUMULATIVE
        # logprobs) whenever a slot makes progress — on admission (first
        # sampled token), after each decode macro-step, and at finish.
        # Cumulative delivery makes the hook idempotent downstream
        # (TokenStream keeps per-request offsets), so replays across PD
        # handoffs / KV recomputes / FT re-injection are no-ops. The hook
        # must only take leaf locks (see TokenStream) — never _step_lock,
        # the proxy lock, or the service lock.
        self.on_progress: Optional[
            Callable[[str, List[int], List[float]], None]] = None
        self.steps_per_dispatch = steps_per_dispatch
        self.donate = donate
        # Admission prefill and KV recompute (protocol step (5)) pad
        # prompts to power-of-two buckets so XLA compiles O(log max_len)
        # shapes instead of one per distinct prompt length. Only valid for
        # full-attention stacks: padded positions beyond last_pos are
        # causally masked and later overwritten by decode, but a recurrent
        # mixer (mamba/rwkv) would scan pad tokens into its state, and a
        # ring-buffered sliding window could wrap them over live entries.
        supported = (
            model.window is None
            and all(mixer == "attn" for mixer, _ in model.cfg.block_pattern))
        self._bucketed_prefill = (supported if bucketed_prefill is None
                                  else bool(bucketed_prefill) and supported)
        # Paged KV (opt-in): per-slot dense cache rows are replaced by a
        # shared page pool + per-slot page tables, with a radix-style
        # prefix cache so redundant rollouts / multi-turn continuations
        # prefill once and FORK (rl/paged_kv.py). Greedy decode is
        # byte-identical to paged=False (see attention_decode_paged);
        # paged stays opt-in because SAMPLED (temp>0) streams are not:
        # decode dispatch compacts to the pow2-bucketed ACTIVE batch and
        # jax.random.categorical draws depend on the batch shape.
        self.paged = bool(paged)
        self.page_size = page_size
        if self.paged:
            if not model.supports_paged():
                raise ValueError(
                    f"{model.cfg.name}: paged KV requires an attention-"
                    "only stack with no sliding window")
            if page_size < 1 or page_size & (page_size - 1):
                raise ValueError(f"page_size must be a power of two, "
                                 f"got {page_size}")
            if max_len % page_size:
                raise ValueError(f"max_len={max_len} not divisible by "
                                 f"page_size={page_size}")
            self.num_pages = (num_pages if num_pages is not None
                              else (max_slots * max_len) // page_size)
            self._pages_per_slot = max_len // page_size
            self._trash_pid = self.num_pages       # extra pool row
            # page bookkeeping: all mutated under _step_lock (the
            # allocator's own lock is a leaf below _lock; PrefixCache is
            # lock-free and relies on _step_lock serialization)
            self._alloc = PagedKVAllocator(self.num_pages, page_size)  # guarded by: _step_lock
            self._prefix = PrefixCache(self._alloc, page_size)  # guarded by: _step_lock
            self._tables: List[List[int]] = [[] for _ in range(max_slots)]  # guarded by: _step_lock
            # page ids written on device since the last incremental
            # snapshot capture (FT dirty tracking)
            self._dirty = set()                    # guarded by: _step_lock
            self.shared_prefix_tokens = 0          # guarded by: _step_lock
        # width of the padded per-slot stop-token matrix fed to
        # decode_block; grows (power of two -> bounded recompiles) if a
        # request carries more stop tokens
        self._stop_width = 4                       # guarded by: _step_lock
        self.weight_version = 0                    # guarded by: _step_lock
        # bare flag, atomic under the GIL — see suspend() for the contract
        self.suspended = False
        self._key = jax.random.PRNGKey(seed)       # guarded by: _step_lock
        self._slots = [_Slot() for _ in range(max_slots)]  # guarded by: _step_lock
        # ("add", req) | ("abort", id) | ("inject", KVHandoff)
        self._commands = collections.deque()       # guarded by: _lock
        self._lock = threading.Lock()
        # serializes the mutators of _slots/_cache/params: step() (the pump
        # thread) vs update_params() (the control thread's weight sync).
        # The command queue has its own lock so add/abort/inject never
        # block on an in-flight decode step.
        self._step_lock = threading.Lock()
        self._results: Dict[str, GenResult] = {}   # guarded by: _lock
        # fit_spec drop events observed by THIS engine's traces/placements
        # (the module-wide one-shot ShardingDropWarning fires alongside);
        # bumped via the axis_rules on_drop hook, which only runs inside
        # _shard_ctx() — and every _shard_ctx() site holds _step_lock
        self.sharding_drops = 0                    # guarded by: _step_lock
        # host chunk bytes consumed by sharded weight syncs
        self.sync_bytes = 0                        # guarded by: _step_lock
        # param/cache placement: a mesh engine shards both over its group
        # (per-leaf NamedShardings; a sharded leaf never lands as a
        # whole-array copy on any one device). Done under _step_lock so
        # placement-time fit_spec drops funnel through _on_fit_drop with
        # the same lock trace-time drops hold.
        with self._step_lock:
            if self.mesh is not None:
                with self._shard_ctx():
                    self._param_shardings = param_sharding(
                        params, self.mesh, self._shard_rules)
                    self.params = jax.device_put(params,
                                                 self._param_shardings)
                    store = self._init_kv_store()
                    self._cache_shardings = model.cache_sharding(
                        store, self.mesh, self._shard_rules,
                        axes=(model.paged_cache_logical_axes()
                              if self.paged else None))
                    store = jax.device_put(store, self._cache_shardings)
            else:
                self._param_shardings = None
                self._cache_shardings = None
                store = self._init_kv_store()
            # the engine's KV store: dense per-slot cache (paged=False)
            # or the shared page pool (paged=True)
            if self.paged:
                self._pool = store                 # guarded by: _step_lock
                self._cache = None                 # guarded by: _step_lock
            else:
                self._cache = store                # guarded by: _step_lock
        # stats (steps/busy_steps count MACRO-steps, i.e. engine
        # iterations; decode_dispatches counts decode jit calls — with
        # K = steps_per_dispatch, dispatches/token converges to 1/K —
        # while prefill/decode token counters stay in TOKENS, which is
        # what proxy-level accounting and the rebalancer consume;
        # recomputes counts in-flight KV rebuilds (protocol (5)) and
        # crashes counts injected engine losses (repro.ft))
        self.steps = 0                             # guarded by: _step_lock
        self.busy_steps = 0                        # guarded by: _step_lock
        self.decode_dispatches = 0                 # guarded by: _step_lock
        self.prefill_tokens = 0                    # guarded by: _step_lock
        self.decode_tokens = 0                     # guarded by: _step_lock
        self.recomputes = 0                        # guarded by: _step_lock
        self.handoffs_out = 0                      # guarded by: _step_lock
        self.handoffs_in = 0                       # guarded by: _step_lock
        self.crashes = 0                           # guarded by: _step_lock
        # liveness beat for the observability watchdog: bumped at the END
        # of every step() AFTER _step_lock is released — a bare lock-free
        # counter (atomic under the GIL) so the watchdog can read it
        # while a wedged step holds _step_lock forever. A beat that stops
        # advancing while has_pending is True is the hang signal.
        self.beats = 0
        # hard-kill latch + test-only wedge hook (see hard_kill). Both
        # bare: hard_kill must work from the watchdog thread while the
        # step path is hung inside _step_lock.
        self._kill_evt = threading.Event()
        self._prestep_hook: Optional[Callable[["InferenceEngine"],
                                              None]] = None
        # requests rejected at submit because prompt+budget can NEVER fit
        # max_len (bugfix: formerly conflated with "no free slot" and
        # queued forever). Guarded by _lock, not _step_lock: the
        # rejection runs synchronously on the submitter's thread, which
        # may hold the proxy's routing state and must not take
        # _step_lock (cross-class ordering, see module docstring).
        self.rejected_too_long = 0                 # guarded by: _lock
        self._build_jit()

    def _init_kv_store(self):   # requires: _step_lock
        """Fresh zeroed KV store (host layout): the dense per-slot cache,
        or the page pool plus one trash row absorbing padded-table
        writes/gathers."""
        if self.paged:
            return self.model.init_paged_pool(self.num_pages + 1,
                                              self.page_size)
        return self.model.init_cache(self.max_slots, self.max_len)

    # ------------------------------------------------------------------
    def _build_jit(self):
        model = self.model
        # Donate the cache argument (index 2 in every entry point): the
        # engine owns exactly one live cache reference (always rebound from
        # the jit result under _step_lock), so XLA may alias input to
        # output and update the [max_slots, max_len] cache in place
        # instead of copying it per call. Params are NOT donated: the same
        # param buffers are shared with the trainer, the weight store, and
        # sibling engines (build_pd_proxy passes one pytree to all of
        # them), so donating would invalidate them for everyone else.
        donate = (2,) if self.donate else ()

        @functools.partial(jax.jit, donate_argnums=donate)
        def _decode(params, tokens, cache, positions, key, temperature):
            logits, cache = model.decode_step(params, tokens, cache,
                                              positions)
            toks, lps = sample_mixed(key, logits, temperature)
            return toks, lps, cache

        K = self.steps_per_dispatch

        @functools.partial(jax.jit, donate_argnums=donate)
        def _decode_block(params, tokens, cache, positions, key,
                          temperatures, stop_ids, budgets):
            # derive the K per-step keys ON DEVICE with the same
            # sequential split chain _next_key walks host-side, so (a)
            # sampled streams stay byte-identical across
            # steps_per_dispatch settings and (b) the macro-step costs
            # one dispatch total instead of K host-side splits plus one
            def split_body(c, _):
                c, sub = jax.random.split(c)
                return c, sub
            new_key, keys = jax.lax.scan(split_body, key, None, length=K)
            toks, lps, emitted, cache = model.decode_block(
                params, tokens, cache, positions, keys, temperatures,
                stop_ids, budgets, sample_fn=sample_mixed)
            return toks, lps, emitted, cache, new_key

        @functools.partial(jax.jit, donate_argnums=donate)
        def _prefill_into_slot(params, tokens, cache, slot, last_pos, key,
                               temperature):
            """tokens: [1, S]; writes the slot's cache row IN PLACE
            (Model.prefill slot mode — no transient batch-1 cache) and
            samples the first generated token from the last prompt
            position."""
            logits, cache = model.prefill(params, tokens, cache,
                                          last_pos=last_pos, slot=slot)
            toks, lps = sample_mixed(key, logits, temperature)
            return toks, lps, cache

        self._decode_jit = _decode
        self._decode_block_jit = _decode_block
        self._prefill_jit = _prefill_into_slot
        self._sample = sample_mixed
        if not self.paged:
            return
        page = self.page_size

        @functools.partial(jax.jit, donate_argnums=donate)
        def _decode_block_paged(params, tokens, pool, tables, positions,
                                key, temperatures, stop_ids, budgets):
            def split_body(c, _):
                c, sub = jax.random.split(c)
                return c, sub
            new_key, keys = jax.lax.scan(split_body, key, None, length=K)
            toks, lps, emitted, pool = model.decode_block_paged(
                params, tokens, pool, tables, positions, keys,
                temperatures, stop_ids, budgets, sample_fn=sample_mixed,
                page_size=page)
            return toks, lps, emitted, pool, new_key

        @functools.partial(jax.jit, donate_argnums=donate)
        def _prefill_paged(params, tokens, pool, table, last_rel, key,
                           temperature):
            logits, pool = model.prefill_paged(
                params, tokens, pool, table, page, last_pos=last_rel)
            toks, lps = sample_mixed(key, logits, temperature)
            return toks, lps, pool

        @functools.partial(jax.jit, donate_argnums=donate)
        def _prefill_paged_fork(params, tokens, pool, table, ctx_len,
                                last_rel, key, temperature):
            logits, pool = model.prefill_paged(
                params, tokens, pool, table, page, last_pos=last_rel,
                ctx_len=ctx_len)
            toks, lps = sample_mixed(key, logits, temperature)
            return toks, lps, pool

        self._decode_block_paged_jit = _decode_block_paged
        self._prefill_paged_jit = _prefill_paged
        self._prefill_paged_fork_jit = _prefill_paged_fork

    def _shard_ctx(self):
        """axis_rules context for tracing and placement: activates the
        group mesh + logical rules (so ``Model``'s ``shd`` annotations
        become NamedSharding constraints) plus the per-engine drop
        counter. A no-op nullcontext for single-device engines. Only
        entered with ``_step_lock`` held — the on_drop hook relies on
        it."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return axis_rules(self.mesh, self._shard_rules,
                          on_drop=self._on_fit_drop)

    def _on_fit_drop(self):   # requires: _step_lock
        self.sharding_drops += 1

    def _next_key(self):   # requires: _step_lock
        self._key, k = jax.random.split(self._key)
        return k

    # ------------------------------------------------------------------
    # command interface (thread-safe)
    # ------------------------------------------------------------------
    def add_request(self, req: GenRequest):
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            # unservable at ANY occupancy: queueing it would either wedge
            # admission forever (the old conflated `_admit` check) or
            # waste a round trip to the drain-time backstop — reject NOW,
            # on the submitter's thread, with a counted aborted result
            self._reject_too_long(req)
            return
        with self._lock:
            self._commands.append(("add", req))

    def _reject_too_long(self, req: GenRequest):
        """Emit the aborted result for a request whose prompt+budget can
        never fit ``max_len``. Takes only ``_lock`` — callable from
        ``add_request`` on a submitter thread that may sit under proxy
        routing state (never ``_step_lock``; see cross-class ordering)."""
        # advisory racy read for result metadata: exact versioning is
        # meaningless for a request that never touched the slots
        res = GenResult(request_id=req.request_id, tokens=[], logprobs=[],
                        finish_reason="aborted",
                        # analysis: ignore[guarded-attr] advisory read
                        weight_version=self.weight_version,
                        prefill_tokens=0, decode_tokens=0)
        with self._lock:
            self.rejected_too_long += 1
            self._results[res.request_id] = res
        if self.on_finish:
            self.on_finish(res)

    def inject(self, handoff: KVHandoff):
        """Queue a prefilled trajectory for decode (PD disaggregation)."""
        with self._lock:
            self._commands.append(("inject", handoff))

    def abort(self, request_id: str):
        with self._lock:
            self._commands.append(("abort", request_id))

    def set_role(self, role: str):
        """Switch the engine's data-plane role (dynamic rebalancing). The
        caller (LLMProxy) is responsible for draining queued commands and
        in-flight slots first — see ``extract_pending`` and
        ``drain_active_handoffs`` — and for installing ``on_handoff`` when
        the new role is ``"prefill"``."""
        if role not in ROLES:
            raise ValueError(f"role must be one of {ROLES}, got {role!r}")
        with self._step_lock:
            self.role = role

    def extract_pending(self) -> List:
        """Atomically remove and return all queued commands (role switch:
        the proxy re-dispatches them through its routing tables)."""
        with self._lock:
            cmds = list(self._commands)
            self._commands.clear()
        return cmds

    def drain_active_handoffs(self) -> List[KVHandoff]:
        """Package every in-flight slot as a KVHandoff and free it — the
        migration half of a decode->prefill role switch. Serialized against
        ``step``/``update_params`` so no slot is mid-decode while its cache
        is extracted."""
        with self._step_lock:
            return [self._package_handoff(i)
                    for i, s in enumerate(self._slots) if s.active]

    # ------------------------------------------------------------------
    # fault tolerance (repro.ft): snapshot + failure injection
    # ------------------------------------------------------------------
    def snapshot_slots(self) -> List[KVHandoff]:
        """Non-destructive copy of every in-flight slot as a KVHandoff —
        the engine half of a rollout snapshot. Unlike
        ``drain_active_handoffs`` the slots stay live; the engine keeps
        decoding after the snapshot returns."""
        with self._step_lock:
            return [self._peek_handoff(i)
                    for i, s in enumerate(self._slots) if s.active]

    def snapshot_commands(self) -> List:
        """Copy of the queued-but-unprocessed commands (ADD / INJECT /
        ABORT), for snapshotting requests that were dispatched but never
        admitted."""
        with self._lock:
            return list(self._commands)

    def snapshot_rng(self):
        """The engine's PRNG chain head as a host array (snapshot).
        Serialized against the step loop: half-advanced key reads would
        make a restored snapshot replay a different sample stream."""
        with self._step_lock:
            return np.asarray(self._key)

    def restore_rng(self, key):
        with self._step_lock:
            self._key = jnp.asarray(key)

    def crash(self):
        """Simulate losing this engine's process: every in-flight slot,
        queued command, undelivered result, and the whole KV cache are
        gone (the engine object itself survives, standing in for a
        restarted replacement bound to the same devices). The proxy route
        table still points dangling requests here — recovery re-injects
        them from the latest snapshot (see ``repro.ft.supervisor``)."""
        with self._step_lock:
            with self._lock:
                self._commands.clear()
                self._results.clear()
            self._slots = [_Slot() for _ in range(self.max_slots)]
            store = self._init_kv_store()
            if self.mesh is not None:
                # the reborn replacement binds the same device group, so
                # its fresh cache takes the same shardings
                store = jax.device_put(store, self._cache_shardings)
            if self.paged:
                self._pool = store
                # the pool metadata dies with the process: fresh
                # allocator / prefix cache / tables, no dirty pages
                self._alloc = PagedKVAllocator(self.num_pages,
                                               self.page_size)
                self._prefix = PrefixCache(self._alloc, self.page_size)
                self._tables = [[] for _ in range(self.max_slots)]
                self._dirty = set()
            else:
                self._cache = store
            self.crashes += 1

    def hard_kill(self):
        """Kill switch for a silently hung engine (watchdog recovery
        path). Sets a bare latch WITHOUT taking any lock — a wedged
        ``step()`` holds ``_step_lock`` forever, so a lock-taking kill
        would hang the killer too. The step path checks the latch at its
        pre-step boundary and raises :class:`EngineKilledError`; if the
        step is blocked inside a (test-hook) wedge, setting the event
        also unblocks hooks that wait on it. ``step()`` converts the
        unwind into :meth:`crash` — the same lost-process state the FT
        plane already knows how to recover."""
        self._kill_evt.set()

    def suspend(self):
        """Stop admitting new requests; in-flight slots are preserved.
        A bare flag write (atomic under the GIL): the pump thread reads it
        inside ``step``; callers needing a hard barrier (nothing decoding
        while weights swap) hold the runner-level pump lock across
        suspend → update → resume."""
        self.suspended = True

    def resume(self):
        self.suspended = False

    def update_params(self, params, version: int,
                      recompute_caches: bool = True):
        """Weight sync (protocol steps (3)+(5)): swap weights and rebuild
        each in-flight trajectory's cache under the new weights.

        No-op when ``version`` equals the engine's current weight version
        (e.g. iteration 0, where the store still holds the weights the
        engine was built with): re-prefilling every in-flight cache under
        identical weights would burn a full prefill per slot for nothing.
        The version check happens under ``_step_lock``: checked outside,
        two concurrent syncs could interleave check-then-swap and leave
        params and weight_version from different versions.
        """
        with self._step_lock:
            if version == self.weight_version:
                return
            if self.mesh is not None:
                # per-leaf sharded placement: each leaf lands under its
                # NamedSharding (device_put splits host leaves into
                # shards), never as a whole-array copy on one device of
                # the group
                with self._shard_ctx():
                    params = jax.device_put(params, self._param_shardings)
            self.params = params
            self.weight_version = version
            if recompute_caches:
                if self.paged:
                    # cached prefix KV was computed under the OLD
                    # weights: a post-sync fork of it would silently mix
                    # versions in one trajectory
                    self._prefix.clear()
                for i, s in enumerate(self._slots):
                    if s.active and s.pos > 0:
                        self._reprefill_slot(i)

    def update_from_chunks(self, chunks, version: int,
                           recompute_caches: bool = True):
        """Sharded weight sync: swap in a new version delivered as
        PER-SHARD host chunks (``weightstore.pull_param_chunks`` format —
        one ``(dim, parts)`` entry per param leaf, ``dim=None`` for
        unchunked leaves). A mesh engine assembles each leaf directly
        into its NamedSharding via ``jax.make_array_from_callback``:
        every device's callback slices just ITS shard out of the chunk
        list, so a sharded leaf is never materialized whole — on host or
        on any single device — even when the store's chunk count differs
        from this engine's TP degree (unequal PD group sizes). A
        single-device engine concatenates chunks. Same same-version no-op
        and in-flight KV recompute semantics as :meth:`update_params`."""
        with self._step_lock:
            if version == self.weight_version:
                return
            treedef = jax.tree.structure(self.params)
            shardings = (jax.tree.leaves(self._param_shardings)
                         if self.mesh is not None
                         else [None] * len(chunks))
            leaves = [self._assemble_leaf(dim, parts, shd)
                      for (dim, parts), shd in zip(chunks, shardings)]
            self.params = jax.tree.unflatten(treedef, leaves)
            self.weight_version = version
            if recompute_caches:
                if self.paged:
                    # stale-version prefix KV, same as update_params
                    self._prefix.clear()
                for i, s in enumerate(self._slots):
                    if s.active and s.pos > 0:
                        self._reprefill_slot(i)

    def _assemble_leaf(self, dim, parts, sharding):   # requires: _step_lock
        """One param leaf from its host chunks. ``sync_bytes`` counts the
        host bytes actually consumed: for a sharded leaf the device
        callbacks sum to ~1x the leaf (split across the group), for a
        replicated leaf on a group they sum to group-x — which is the
        honest cost of replication the benchmark reports."""
        if dim is not None and len(parts) > 1:
            shape = list(np.shape(parts[0]))
            shape[dim] *= len(parts)
            shape = tuple(shape)
        else:
            shape = tuple(np.shape(parts[0]))
        if sharding is None:
            arr = (np.concatenate([np.asarray(p) for p in parts], axis=dim)
                   if len(parts) > 1 else np.asarray(parts[0]))
            self.sync_bytes += int(arr.nbytes)
            return jnp.asarray(arr)

        def cb(idx):
            piece = _slice_chunks(parts, dim, idx, shape)
            self.sync_bytes += int(piece.nbytes)
            return piece

        return jax.make_array_from_callback(shape, sharding, cb)

    def param_device_bytes(self) -> Dict[str, int]:
        """Parameter bytes resident per device (addressable shards) — the
        no-full-copy accounting: at TP degree g, sharded leaves
        contribute 1/g per device, so no device of a useful group holds
        the full parameter footprint."""
        with self._step_lock:
            out: Dict[str, int] = {}
            for leaf in jax.tree.leaves(self.params):
                if hasattr(leaf, "addressable_shards"):
                    for sh in leaf.addressable_shards:
                        d = str(sh.device)
                        out[d] = out.get(d, 0) + int(sh.data.nbytes)
                else:
                    out["host"] = (out.get("host", 0)
                                   + int(np.asarray(leaf).nbytes))
            return out

    def _bucket_len(self, n: int) -> int:
        b = 16
        while b < n:
            b <<= 1
        return min(b, self.max_len)

    def _prefill_slot(self, i: int, temperature: float,
                      ctx_tokens: int = 0):   # requires: _step_lock
        """Fill slot ``i``'s cache row from its tokens[:pos] — shared by
        first admission and the protocol-(5) KV recompute. On attention-
        only stacks the prompt is padded to a power-of-two bucket (padded
        positions beyond last_pos are causally masked and later overwritten
        by decode), so XLA compiles O(log max_len) prefill shapes instead
        of one per distinct prompt length. Returns the (token, logprob)
        sampled at the true last prompt position.

        Paged engines prefill only the TAIL past ``ctx_tokens`` cached
        prefix tokens (a page multiple, 0 = fresh prompt): the forked
        prefix pages already hold its KV. The tail is padded to a page-
        multiple bucket; overshoot past the slot's allocation writes to
        the trash row."""
        s = self._slots[i]
        if not self.paged:
            toks = s.tokens[: s.pos]
            if self._bucketed_prefill:
                toks = toks + [0] * (self._bucket_len(len(toks)) - len(toks))
            tok_arr = jnp.asarray([toks], jnp.int32)
            last = jnp.asarray([s.pos - 1], jnp.int32)
            with self._shard_ctx():
                tok, lp, self._cache = self._prefill_jit(
                    self.params, tok_arr, self._cache, i, last,
                    self._next_key(), jnp.float32(temperature))
            return tok, lp
        page = self.page_size
        m = ctx_tokens
        tail = s.tokens[m: s.pos]
        n = len(tail)
        if self._bucketed_prefill:
            sb = max(self._bucket_len(n), page)
        else:
            sb = -(-n // page) * page
        # never index page-table slots past the table width: the real
        # tail region always fits ([m, pos) is within max_len), only the
        # bucket overshoot is trimmed
        sb = min(sb, self.max_len - m)
        tail = tail + [0] * (sb - n)
        tok_arr = jnp.asarray([tail], jnp.int32)
        tbl = jnp.asarray(self._full_table(i))
        last_rel = jnp.asarray([s.pos - 1 - m], jnp.int32)
        with self._shard_ctx():
            if m == 0:
                tok, lp, self._pool = self._prefill_paged_jit(
                    self.params, tok_arr, self._pool, tbl, last_rel,
                    self._next_key(), jnp.float32(temperature))
            else:
                tok, lp, self._pool = self._prefill_paged_fork_jit(
                    self.params, tok_arr, self._pool, tbl, jnp.int32(m),
                    last_rel, self._next_key(), jnp.float32(temperature))
        first = m // page
        self._dirty.update(self._tables[i][first: first + sb // page])
        return tok, lp

    def _reprefill_slot(self, i: int):   # requires: _step_lock
        if self.paged:
            # the recompute rewrites every page of the slot from position
            # 0 — give it exclusive pages first so the rewrite cannot
            # mutate pages shared with the prefix cache or other slots
            self._cow_slot_pages(i)
        self._prefill_slot(i, -1.0)   # greedy: the sampled token is unused
        self.recomputes += 1

    def _cow_slot_pages(self, i: int):   # requires: _step_lock
        """Copy-on-write every shared page of slot ``i``'s table. Pool
        pressure first evicts prefix-cache pages; if the pool is STILL
        exhausted the slot falls back to rewriting the shared page in
        place — safe for the weight-sync path because the prefix cache
        was cleared and every sharing slot is itself recomputed to
        byte-identical contents under the same new weights."""
        tbl = self._tables[i]
        for j, pid in enumerate(tbl):
            if self._alloc.refcount(pid) <= 1:
                continue
            while (self._alloc.free_pages == 0
                   and self._prefix.cached_pages > 0):
                self._prefix.evict(1)
            new = self._alloc.cow(pid)
            if new is not None and new != pid:
                tbl[j] = new
                self._dirty.add(new)

    def _grow_stop_width(self, stop_tokens: Sequence[int]):   # requires: _step_lock
        while len(stop_tokens) > self._stop_width:
            self._stop_width *= 2

    # ------------------------------------------------------------------
    def _admit(self, req: GenRequest) -> bool:   # requires: _step_lock
        # too-long requests never reach here: add_request rejects them at
        # submit and _drain_commands backstops queue-restored ones, so a
        # False return always means "retry later", never "can never fit"
        free = [i for i, s in enumerate(self._slots) if not s.active]
        if not free:
            return False
        i = free[0]
        shared = 0
        if self.paged:
            table, shared = self._alloc_slot_pages(req)
            if table is None:
                return False      # pool pressure: defer like no-free-slot
            self._tables[i] = table
        s = self._slots[i]
        s.active = True
        s.request = req
        s.tokens = list(req.prompt)
        s.new_tokens, s.logprobs = [], []
        s.pos = len(req.prompt)
        s.start_version = self.weight_version
        self._grow_stop_width(req.stop_tokens)
        tok, lp = self._prefill_slot(i, req.temperature, ctx_tokens=shared)
        self.prefill_tokens += s.pos - shared   # real NEW tokens prefilled
        if self.paged:
            self.shared_prefix_tokens += shared
            # register the freshly-prefilled prompt pages so concurrent
            # admissions of shared-prompt requests fork them immediately
            self._prefix.insert(req.prompt, self._tables[i])
        self._append_token(i, int(tok[0]), float(lp[0]))
        # stream the first sampled token (idempotent if _append_token
        # already finished the request and _finish emitted it)
        self._emit_progress(req.request_id, s)
        if self.role == "prefill" and s.active:
            # still generating after the first token: migrate the slot's
            # cache to a decode-role engine instead of decoding here
            self._emit_handoff(i)
        return True

    def _alloc_slot_pages(self, req: GenRequest):   # requires: _step_lock
        """Reserve slot pages for ``req`` up-front: EVERY page the request
        can touch (prompt + full decode budget, capped at max_len) is
        allocated at admission, so a mid-flight decode step can never hit
        an out-of-pages failure. Shared-prefix pages come from the radix
        cache (incref'd, never written by this slot); the rest are fresh
        private pages. Returns ``(table, shared_tokens)`` or
        ``(None, 0)`` when the pool — even after evicting cached prefix
        pages — cannot cover the request (caller defers it)."""
        page = self.page_size
        total = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        n_pages = -(-total // page)
        matched = self._prefix.match(req.prompt)
        # the tail (>= 1 prompt token: decode needs a real last position
        # to prefill logits from) always starts on a fresh private page
        matched = matched[: (len(req.prompt) - 1) // page]
        self._alloc.incref(matched)   # pin before eviction can run
        need = n_pages - len(matched)
        while (self._alloc.free_pages < need
               and self._prefix.cached_pages > 0):
            self._prefix.evict(1)
        priv = self._alloc.alloc(need)
        if priv is None:
            self._alloc.decref(matched)
            return None, 0
        self._dirty.update(priv)
        return matched + priv, len(matched) * page

    def _full_table(self, i: int) -> np.ndarray:   # requires: _step_lock
        """Slot ``i``'s page table padded to full width with the trash
        page id — the fixed-shape form every paged jit consumes (padded
        gathers read the trash row and are masked; padded writes land in
        the trash row)."""
        tbl = np.full((self._pages_per_slot,), self._trash_pid, np.int32)
        pids = self._tables[i][: self._pages_per_slot]
        tbl[: len(pids)] = pids
        return tbl

    def _release_slot_pages(self, i: int):   # requires: _step_lock
        """Return slot ``i``'s pages to the pool — but first hand the
        finished trajectory to the prefix cache so a multi-turn
        continuation (same conversation + new env tokens) forks it
        instead of re-prefilling. Only guaranteed-WRITTEN positions are
        cacheable: the device has KV for tokens[:pos-1] (the final
        sampled token was never fed), so the insert stops at the last
        full page below pos-1."""
        if not self.paged:
            return
        tbl = self._tables[i]
        if not tbl:
            return
        s = self._slots[i]
        done = max(s.pos - 1, 0)
        if done >= self.page_size:
            self._prefix.insert(s.tokens[:done], tbl)
        self._alloc.decref(tbl)
        self._tables[i] = []

    def _peek_handoff(self, i: int) -> KVHandoff:   # requires: _step_lock
        """Freeze slot ``i`` into a KVHandoff WITHOUT freeing the slot.
        ``extract_cache_slot`` produces fresh arrays (a dynamic slice), so
        the handoff stays valid even after later donated dispatches
        invalidate the engine's own cache buffer. The slot is gathered to
        HOST numpy (``jax.device_get`` all-gathers a sharded slot's
        shards): the host copy is the portable interchange format — it
        injects into any engine regardless of that engine's TP group
        size, and the FT snapshotter serializes it as-is. A paged engine
        gathers the slot's pages back into the SAME dense layout, so the
        handoff format — and everything downstream of it (unequal-TP
        re-shard, FT serialization, paged<->dense handoffs) — is
        unchanged."""
        s = self._slots[i]
        if self.paged:
            cache = jax.device_get(self.model.paged_to_dense_slot(
                self._pool, jnp.asarray(self._full_table(i))))
        else:
            cache = jax.device_get(self.model.extract_cache_slot(
                self._cache, i))
        return KVHandoff(
            request=s.request, tokens=list(s.tokens),
            new_tokens=list(s.new_tokens), logprobs=list(s.logprobs),
            pos=s.pos, start_version=s.start_version,
            cache=cache, weight_version=self.weight_version)

    def _package_handoff(self, i: int) -> KVHandoff:   # requires: _step_lock
        """Freeze slot ``i`` into a KVHandoff and free the slot."""
        s = self._slots[i]
        handoff = self._peek_handoff(i)
        self._release_slot_pages(i)
        s.active = False
        s.request = None
        return handoff

    def _emit_handoff(self, i: int):   # requires: _step_lock
        if self.on_handoff is None:
            raise RuntimeError(
                "prefill-role engine needs an on_handoff hook "
                "(set by LLMProxy(pd_disagg=True))")
        handoff = self._package_handoff(i)
        self.handoffs_out += 1
        self.on_handoff(handoff)

    def _admit_handoff(self, handoff: KVHandoff) -> bool:   # requires: _step_lock
        free = [i for i, s in enumerate(self._slots) if not s.active]
        if not free:
            return False
        i = free[0]
        if self.paged:
            # all-private pages: the handoff carries opaque dense KV, so
            # there is no token<->page correspondence to share from (the
            # finished slot will still be INSERTED for future forks)
            req = handoff.request
            total = min(len(req.prompt) + req.max_new_tokens, self.max_len)
            need = -(-total // self.page_size)
            while (self._alloc.free_pages < need
                   and self._prefix.cached_pages > 0):
                self._prefix.evict(1)
            pids = self._alloc.alloc(need)
            if pids is None:
                return False
            self._tables[i] = pids
            self._dirty.update(pids)
        s = self._slots[i]
        s.active = True
        s.request = handoff.request
        s.tokens = list(handoff.tokens)
        s.new_tokens = list(handoff.new_tokens)
        s.logprobs = list(handoff.logprobs)
        s.pos = handoff.pos
        s.start_version = handoff.start_version
        self._grow_stop_width(handoff.request.stop_tokens)
        if handoff.weight_version != self.weight_version:
            # the handoff sat in the command queue across a weight sync:
            # protocol step (5) only recomputes ACTIVE slots, so rebuild
            # this cache under the current weights instead of injecting
            # the stale one
            self._reprefill_slot(i)
        elif self.paged:
            # scatter the dense slot image into this slot's pages (one
            # eager page-granular scatter; GSPMD handles a sharded pool)
            self._pool = self.model.dense_slot_to_pages(
                self._pool,
                jax.tree.map(jnp.asarray, handoff.cache),
                jnp.asarray(self._full_table(i)))
        else:
            self._cache = self.model.inject_cache_slot(self._cache,
                                                       handoff.cache, i)
        self.handoffs_in += 1
        return True

    def _emit_progress(self, rid: str, s: _Slot):   # requires: _step_lock
        """Stream slot progress: the CUMULATIVE new-token list (survives
        ``_finish``, which clears only ``request``/``active``)."""
        if self.on_progress is not None and s.new_tokens:
            self.on_progress(rid, list(s.new_tokens), list(s.logprobs))

    def _emit_step_progress(self, active: List[int]):   # requires: _step_lock
        """Post-macro-step streaming for slots still generating (finished
        slots already emitted their final cumulative state in _finish)."""
        if self.on_progress is None:
            return
        for i in active:
            s = self._slots[i]
            if s.active:
                self._emit_progress(s.request.request_id, s)

    def _append_token(self, i: int, tok: int, lp: float):   # requires: _step_lock
        s = self._slots[i]
        s.tokens.append(tok)
        s.new_tokens.append(tok)
        s.logprobs.append(lp)
        s.pos += 1
        req = s.request
        if tok in req.stop_tokens:
            self._finish(i, "stop")
        elif len(s.new_tokens) >= req.max_new_tokens or s.pos >= self.max_len:
            self._finish(i, "length")

    def _finish(self, i: int, reason: str):   # requires: _step_lock
        s = self._slots[i]
        res = GenResult(
            request_id=s.request.request_id,
            tokens=list(s.new_tokens), logprobs=list(s.logprobs),
            finish_reason=reason, weight_version=self.weight_version,
            prefill_tokens=len(s.request.prompt),
            decode_tokens=len(s.new_tokens))
        with self._lock:
            self._results[res.request_id] = res
        self._release_slot_pages(i)
        s.active = False
        s.request = None
        # final cumulative stream push BEFORE on_finish: the proxy's
        # finish hook unregisters the request's stream, so this ordering
        # guarantees the stream saw every token by the time it closes
        self._emit_progress(res.request_id, s)
        if self.on_finish:
            self.on_finish(res)

    @staticmethod
    def _cmd_request_id(cmd) -> Optional[str]:
        kind, payload = cmd
        if kind == "add":
            return payload.request_id
        if kind == "inject":
            return payload.request.request_id
        return None

    def _emit_aborted_pending(self, cmd):   # requires: _step_lock
        """A never-admitted ADD/INJECT was aborted: still emit a result so
        the proxy/EnvManager callback chain observes the cancellation."""
        kind, payload = cmd
        if kind == "add":
            res = GenResult(request_id=payload.request_id, tokens=[],
                            logprobs=[], finish_reason="aborted",
                            weight_version=self.weight_version,
                            prefill_tokens=0, decode_tokens=0)
        else:
            # the handoff carries already-sampled tokens: report them as
            # decode_tokens so proxy/runner token accounting balances
            res = GenResult(request_id=payload.request.request_id,
                            tokens=list(payload.new_tokens),
                            logprobs=list(payload.logprobs),
                            finish_reason="aborted",
                            weight_version=self.weight_version,
                            prefill_tokens=len(payload.request.prompt),
                            decode_tokens=len(payload.new_tokens))
        with self._lock:
            self._results[res.request_id] = res
        if self.on_finish:
            self.on_finish(res)

    def _abort(self, request_id: str):   # requires: _step_lock
        for i, s in enumerate(self._slots):
            if s.active and s.request.request_id == request_id:
                self._finish(i, "aborted")
                return
        # not yet admitted: drop from pending adds/injects
        dropped = None
        with self._lock:
            kept = collections.deque()
            for c in self._commands:
                if dropped is None and self._cmd_request_id(c) == request_id:
                    dropped = c
                else:
                    kept.append(c)
            self._commands = kept
        if dropped is not None:
            self._emit_aborted_pending(dropped)

    def _drain_commands(self):   # requires: _step_lock
        """Process queued commands. ABORTs always drain — a blocked ADD or
        INJECT (no free slot / suspended) defers itself and every later
        admission (FIFO preserved) but must not head-of-line-block
        cancellations queued behind it."""
        # idle-pump fast path: reading the deque's emptiness is atomic
        # under the GIL, so an empty queue costs O(1) with no lock
        # acquisition or deque rebuild (the common case in every pump); a
        # command enqueued concurrently is seen by the next pump at worst
        # analysis: ignore[guarded-attr] deliberate lock-free probe
        if not self._commands:
            return
        with self._lock:
            pending = list(self._commands)
            self._commands.clear()
        deferred = []
        for cmd in pending:
            kind, payload = cmd
            if kind == "abort":
                hit = next((c for c in deferred
                            if self._cmd_request_id(c) == payload), None)
                if hit is not None:
                    deferred.remove(hit)
                    self._emit_aborted_pending(hit)
                else:
                    self._abort(payload)
                continue
            if (kind == "add" and len(payload.prompt)
                    + payload.max_new_tokens > self.max_len):
                # drain-time backstop for paths that enqueue directly
                # (FT command-queue restore); live submissions are
                # rejected in add_request before they ever queue
                self._reject_too_long(payload)
                continue
            blocked = self.suspended or bool(deferred)
            if not blocked:
                ok = (self._admit(payload) if kind == "add"
                      else self._admit_handoff(payload))
                blocked = not ok
            if blocked:
                deferred.append(cmd)
        if deferred:
            with self._lock:
                self._commands.extendleft(reversed(deferred))

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration (macro-step): drain commands, then up to
        ``steps_per_dispatch`` decode steps for all active slots in ONE
        jit dispatch. Returns the number of decode tokens emitted (0 when
        idle) — token-denominated so callers' activity/backlog signals are
        invariant to the dispatch batching. Serialized against
        ``update_params`` so a weight sync never races a decode step over
        the same slots/cache.

        A :meth:`hard_kill` mid-step unwinds here: ``EngineKilledError``
        propagates out of the locked region (releasing ``_step_lock``),
        and the handler models SIGKILL + replacement process — the latch
        and any wedge hook die with the old process, :meth:`crash` wipes
        slots/cache, and the pump loop continues on the reborn engine.
        ``beats`` is the watchdog's liveness signal: bumped outside all
        locks on every return path, so it only goes silent while a step
        is genuinely stuck."""
        try:
            with self._step_lock:
                out = self._step_locked()
        except EngineKilledError:
            self._kill_evt.clear()
            self._prestep_hook = None
            self.crash()
            out = 0
        self.beats += 1
        return out

    def _gather_slot_arrays(self):   # requires: _step_lock
        """Per-slot device inputs for a decode dispatch. Inactive slots
        ride along as zero rows (budget 0 freezes them on device)."""
        B = self.max_slots
        last_tokens = np.zeros((B, 1), np.int32)
        positions = np.zeros((B,), np.int32)
        temps = np.ones((B,), np.float32)
        budgets = np.zeros((B,), np.int32)
        stop_ids = np.full((B, self._stop_width), -1, np.int32)
        for i, s in enumerate(self._slots):
            if s.active:
                last_tokens[i, 0] = s.tokens[-1]
                positions[i] = s.pos - 1  # index of the token we feed
                temps[i] = s.request.temperature
                budgets[i] = min(
                    s.request.max_new_tokens - len(s.new_tokens),
                    self.max_len - s.pos)
                st = list(s.request.stop_tokens)
                stop_ids[i, : len(st)] = st
        return last_tokens, positions, temps, budgets, stop_ids

    def _step_locked(self) -> int:   # requires: _step_lock
        # 1) command processing between engine steps (non-blocking)
        self._drain_commands()
        # test-only wedge point (observability plane): placed AFTER the
        # command drain so _lock is free while a hook blocks — queue_len
        # and has_pending stay readable from other threads during a
        # simulated hang. A real hang would wedge inside the decode
        # dispatch below; the hook models it at a deterministic boundary.
        hook = self._prestep_hook
        if hook is not None:
            hook(self)
        if self._kill_evt.is_set():
            raise EngineKilledError(f"engine hard-killed at step "
                                    f"{self.steps}")
        # 2) one decode macro-step over active slots
        active = [i for i, s in enumerate(self._slots) if s.active]
        self.steps += 1
        if not active:
            return 0
        self.busy_steps += 1
        if self.paged:
            return self._decode_macro_paged(active)
        K = self.steps_per_dispatch
        last_tokens, positions, temps, budgets, stop_ids = \
            self._gather_slot_arrays()
        if K == 1:
            # legacy single-step dispatch (stop/length handled host-side)
            with self._shard_ctx():
                toks, lps, self._cache = self._decode_jit(
                    self.params, jnp.asarray(last_tokens), self._cache,
                    jnp.asarray(positions), self._next_key(),
                    jnp.asarray(temps))
            self.decode_dispatches += 1
            toks, lps = np.asarray(toks), np.asarray(lps)
            for i in active:
                if self._slots[i].active:
                    self.decode_tokens += 1
                    self._append_token(i, int(toks[i]), float(lps[i]))
            self._emit_step_progress(active)
            return len(active)
        # device-resident block: the jit consumes one key per inner step
        # (the SAME split-chain schedule as K single-step dispatches, so
        # sampled streams are reproducible across steps_per_dispatch
        # settings) and hands back the advanced chain head
        with self._shard_ctx():
            toks, lps, emitted, self._cache, self._key = \
                self._decode_block_jit(
                    self.params, jnp.asarray(last_tokens), self._cache,
                    jnp.asarray(positions), self._key, jnp.asarray(temps),
                    jnp.asarray(stop_ids), jnp.asarray(budgets))
        self.decode_dispatches += 1
        toks = np.asarray(toks)          # [K, B]
        lps = np.asarray(lps)
        emitted = np.asarray(emitted)
        n_emitted = 0
        for i in active:
            # each slot's emitted column is a True-prefix; _append_token
            # re-derives the stop/length finish the device masked on
            for k in range(K):
                if not self._slots[i].active or not emitted[k, i]:
                    break
                self.decode_tokens += 1
                n_emitted += 1
                self._append_token(i, int(toks[k, i]), float(lps[k, i]))
        self._emit_step_progress(active)
        return n_emitted

    def _decode_macro_paged(self, active: List[int]) -> int:   # requires: _step_lock
        """Paged decode macro-step: only the ACTIVE slots ride the
        dispatch, padded to a power-of-two batch bucket (bounded
        compiles) with trash page tables and budget 0 for padding rows.
        This batch COMPACTION is where the paged throughput win comes
        from — the dense path pays ``max_slots`` attention rows on every
        dispatch regardless of occupancy, while this path pays the
        occupancy bucket. Greedy streams stay byte-identical to the dense
        path because each real row computes the exact dense op sequence
        over its full table width (see ``attention_decode_paged``)."""
        K = self.steps_per_dispatch
        ba = 1
        while ba < len(active):
            ba <<= 1
        last_tokens = np.zeros((ba, 1), np.int32)
        positions = np.zeros((ba,), np.int32)
        temps = np.ones((ba,), np.float32)
        budgets = np.zeros((ba,), np.int32)
        stop_ids = np.full((ba, self._stop_width), -1, np.int32)
        tables = np.full((ba, self._pages_per_slot), self._trash_pid,
                         np.int32)
        for j, i in enumerate(active):
            s = self._slots[i]
            last_tokens[j, 0] = s.tokens[-1]
            positions[j] = s.pos - 1  # index of the token we feed
            temps[j] = s.request.temperature
            budgets[j] = min(s.request.max_new_tokens - len(s.new_tokens),
                             self.max_len - s.pos)
            st = list(s.request.stop_tokens)
            stop_ids[j, : len(st)] = st
            # the device writes KV at positions [pos-1, pos-1+K): mark
            # their pages dirty NOW, before _append_token can finish the
            # slot and release its table to the prefix cache
            tbl = self._tables[i]
            lo = (s.pos - 1) // self.page_size
            hi = min((s.pos - 1 + K) // self.page_size + 1, len(tbl))
            self._dirty.update(tbl[lo:hi])
            tables[j] = self._full_table(i)
        with self._shard_ctx():
            toks, lps, emitted, self._pool, self._key = \
                self._decode_block_paged_jit(
                    self.params, jnp.asarray(last_tokens), self._pool,
                    jnp.asarray(tables), jnp.asarray(positions),
                    self._key, jnp.asarray(temps), jnp.asarray(stop_ids),
                    jnp.asarray(budgets))
        self.decode_dispatches += 1
        toks = np.asarray(toks)          # [K, ba]
        lps = np.asarray(lps)
        emitted = np.asarray(emitted)
        n_emitted = 0
        for j, i in enumerate(active):
            for k in range(K):
                if not self._slots[i].active or not emitted[k, j]:
                    break
                self.decode_tokens += 1
                n_emitted += 1
                self._append_token(i, int(toks[k, j]), float(lps[k, j]))
        self._emit_step_progress(active)
        return n_emitted

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        """Consistent snapshot of the step-granular counters. Callers
        must NOT hold any proxy/runner lock here (it takes ``_step_lock``,
        and the engine calls back into those holders' locks from under
        it — see the module docstring's cross-class ordering note)."""
        with self._step_lock:
            out = {
                "steps": self.steps,
                "busy_steps": self.busy_steps,
                "decode_dispatches": self.decode_dispatches,
                "prefill_tokens": self.prefill_tokens,
                "decode_tokens": self.decode_tokens,
                "recomputes": self.recomputes,
                "handoffs_out": self.handoffs_out,
                "handoffs_in": self.handoffs_in,
                "crashes": self.crashes,
                "weight_version": self.weight_version,
                "tp_group": self.tp_group,
                "sharding_drops": self.sharding_drops,
                "sync_bytes": self.sync_bytes,
            }
            with self._lock:   # nested acquisition: canonical order
                out["rejected_too_long"] = self.rejected_too_long
            if self.paged:
                out.update({
                    "shared_prefix_tokens": self.shared_prefix_tokens,
                    "free_pages": self._alloc.free_pages,
                    "page_highwater": self._alloc.highwater,
                    "prefix_cached_pages": self._prefix.cached_pages,
                    "prefix_hits": self._prefix.hits,
                    "prefix_misses": self._prefix.misses,
                })
            return out

    def capture_kv_incremental(self) -> Dict[str, object]:
        """FT capture for paged engines: gather ONLY the pages written
        since the last capture (page-granularity dirty tracking) instead
        of device_get-ing every active slot's full dense row. The
        snapshotter merges the returned pages into its host-side pool
        image and assembles self-contained dense records from it, so the
        on-disk snapshot format is unchanged.

        Returns ``pages`` ({pid: [one host array per pool leaf]}),
        ``slots`` (active-slot metadata incl. page table), ``live_pages``
        (pids any restore could still need — slot tables plus prefix
        cache — for pruning the host image), and ``captured_bytes``."""
        with self._step_lock:
            if not self.paged:
                raise RuntimeError("incremental KV capture requires "
                                   "paged=True")
            dirty = sorted(p for p in self._dirty
                           if self._alloc.refcount(p) > 0)
            self._dirty.clear()
            pages: Dict[int, list] = {}
            captured = 0
            if dirty:
                idx = jnp.asarray(dirty, jnp.int32)
                host = jax.device_get(
                    jax.tree.map(lambda leaf: leaf[:, idx], self._pool))
                flat = jax.tree.leaves(host)
                captured = sum(int(a.nbytes) for a in flat)
                for j, pid in enumerate(dirty):
                    pages[pid] = [a[:, j] for a in flat]
            slots = []
            live = set(self._prefix.page_ids())
            for i, s in enumerate(self._slots):
                if not s.active:
                    continue
                live.update(self._tables[i])
                slots.append({
                    "slot": i, "request": s.request,
                    "tokens": list(s.tokens),
                    "new_tokens": list(s.new_tokens),
                    "logprobs": list(s.logprobs),
                    "pos": s.pos,
                    "start_version": s.start_version,
                    "weight_version": self.weight_version,
                    "table": list(self._tables[i]),
                })
            return {"pages": pages, "slots": slots, "live_pages": live,
                    "captured_bytes": captured}

    def pop_result(self, request_id: str) -> Optional[GenResult]:
        with self._lock:
            return self._results.pop(request_id, None)

    @property
    def num_active(self) -> int:
        """Racy by design: the proxy reads this under ITS lock, and
        taking ``_step_lock`` here would close the cross-class deadlock
        cycle described in the module docstring. Occupancy is advisory
        (load balancing) so a stale read is harmless."""
        # analysis: ignore[guarded-attr] lock-free read, see docstring
        return sum(s.active for s in self._slots)

    @property
    def inflight_decode_tokens(self) -> int:
        """Decode tokens held by in-flight slots — the work destroyed if
        this engine dies right now (fault-tolerance accounting). Same
        deliberate lock-free read as ``num_active``."""
        # analysis: ignore[guarded-attr] lock-free read, see num_active
        return sum(len(s.new_tokens) for s in self._slots if s.active)

    @property
    def queue_len(self) -> int:
        with self._lock:
            return len(self._commands)

    @property
    def has_pending(self) -> bool:
        return self.queue_len > 0 or self.num_active > 0

    def run_until_idle(self, max_steps: int = 100000):
        for _ in range(max_steps):
            if not self.has_pending:
                return
            self.step()
        raise RuntimeError("engine did not drain")

"""RL and LM losses.

GRPO (the paper's training algorithm, §7.1): group-normalized advantages,
PPO-style token-level clipping, optional KL regularization to a reference
policy. Multi-turn trajectories mask environment-observation tokens out of
the loss via ``loss_mask`` (only action tokens are optimized), which is how
agentic RL differs from single-turn RLHF.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def token_logprobs(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """logits: [B,S,V] (any float dtype) predicting token t+1; tokens: [B,S].

    Returns log p(tokens[:, 1:]) as [B, S-1] in fp32.

    Memory note: written as fused masked reductions (iota==label select +
    logsumexp) instead of log_softmax + take_along_axis — the latter
    materializes [B,S,V] fp32 activations *and* an s32 [B,S,V] scatter in
    the backward pass (measured ~33 GiB/device on 1M-token MoE batches; see
    EXPERIMENTS.md §Perf). XLA fuses these reductions so nothing [B,S,V]
    beyond the bf16 logits themselves is materialized.
    """
    lg = logits[:, :-1]
    lab = tokens[:, 1:]
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    shifted = (lg - m).astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    iota = jax.lax.broadcasted_iota(lab.dtype, (1, 1, lg.shape[-1]), 2)
    label_shift = jnp.sum(
        jnp.where(lab[..., None] == iota, shifted, 0.0), axis=-1)
    return label_shift - lse


def lm_loss(logits, tokens, mask=None):
    """Next-token cross entropy. mask: [B,S] over *input* positions."""
    lp = token_logprobs(logits, tokens)
    m = jnp.ones_like(lp) if mask is None else mask[:, 1:].astype(jnp.float32)
    return -(lp * m).sum() / jnp.clip(m.sum(), 1.0)


def group_normalized_advantages(rewards: jnp.ndarray, group_size: int,
                                eps: float = 1e-6) -> jnp.ndarray:
    """GRPO advantages. rewards: [B] with B = n_groups * group_size and
    group members contiguous. Returns [B]."""
    g = rewards.reshape(-1, group_size)
    mean = g.mean(axis=1, keepdims=True)
    std = g.std(axis=1, keepdims=True)
    return ((g - mean) / (std + eps)).reshape(-1)


def grpo_loss(logits: jnp.ndarray,
              tokens: jnp.ndarray,
              loss_mask: jnp.ndarray,
              advantages: jnp.ndarray,
              behavior_logprobs: jnp.ndarray,
              ref_logprobs: Optional[jnp.ndarray] = None,
              clip_eps: float = 0.2,
              kl_coef: float = 0.0):
    """Token-level clipped policy-gradient loss from logits. See
    ``grpo_from_logprobs`` for the memory-lean entry point the trainer uses.
    """
    lp = token_logprobs(logits, tokens)                 # [B,S-1]
    return grpo_from_logprobs(lp, tokens, loss_mask, advantages,
                              behavior_logprobs, ref_logprobs=ref_logprobs,
                              clip_eps=clip_eps, kl_coef=kl_coef)


def grpo_from_logprobs(lp: jnp.ndarray,
                       tokens: jnp.ndarray,
                       loss_mask: jnp.ndarray,
                       advantages: jnp.ndarray,
                       behavior_logprobs: jnp.ndarray,
                       ref_logprobs: Optional[jnp.ndarray] = None,
                       clip_eps: float = 0.2,
                       kl_coef: float = 0.0):
    """lp: [B,S-1] current-policy logprobs of tokens[:,1:]; loss_mask: [B,S];
    advantages: [B] per trajectory or [B,S-1] per token."""
    m = loss_mask[:, 1:].astype(jnp.float32)
    if advantages.ndim == 1:
        adv = advantages[:, None]
    else:
        adv = advantages
    ratio = jnp.exp(lp - behavior_logprobs)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * adv
    pg = -jnp.minimum(unclipped, clipped)
    loss = (pg * m).sum() / jnp.clip(m.sum(), 1.0)
    metrics = {
        "pg_loss": loss,
        "ratio_mean": (ratio * m).sum() / jnp.clip(m.sum(), 1.0),
        "clip_frac": (((jnp.abs(ratio - 1) > clip_eps) * m).sum()
                      / jnp.clip(m.sum(), 1.0)),
        "entropy_proxy": -(lp * m).sum() / jnp.clip(m.sum(), 1.0),
    }
    if kl_coef > 0.0 and ref_logprobs is not None:
        # k3 estimator: E[exp(ref-lp) - (ref-lp) - 1] >= 0
        d = ref_logprobs - lp
        kl = (jnp.exp(d) - d - 1.0)
        kl_term = (kl * m).sum() / jnp.clip(m.sum(), 1.0)
        loss = loss + kl_coef * kl_term
        metrics["kl"] = kl_term
    metrics["loss"] = loss
    return loss, metrics


def ppo_loss(logits, tokens, loss_mask, advantages, behavior_logprobs,
             values=None, returns=None, clip_eps: float = 0.2,
             value_coef: float = 0.5):
    """PPO: same clipped PG; optional value head term (values/returns [B,S])."""
    loss, metrics = grpo_loss(logits, tokens, loss_mask, advantages,
                              behavior_logprobs, clip_eps=clip_eps)
    if values is not None and returns is not None:
        v_loss = 0.5 * jnp.mean(jnp.square(values - returns))
        loss = loss + value_coef * v_loss
        metrics = dict(metrics, v_loss=v_loss, loss=loss)
    return loss, metrics

"""Paged KV-cache bookkeeping: a refcounted copy-on-write page allocator
plus a radix-style shared-prefix cache (the vLLM PagedAttention / SGLang
RadixAttention idea, sized for the decode plane of §5.2/§6.3).

The engine owns ONE device-resident page pool per attention cache leaf
(``Model.init_paged_pool``); this module tracks which pool rows (pages)
belong to whom. Pages are shared by reference counting:

- each live slot holds one reference per page-table entry,
- the prefix cache holds one reference per cached page,
- a page returns to the free list when its last reference drops.

Forking (``redundancy>1`` rollouts, multi-turn continuations) is an
``incref`` of the matched prefix pages — no KV bytes move. Shared pages
are never written on the hot path: the engine rounds a prefix match DOWN
to a full-page multiple strictly below the prompt length, so a slot's
tail always starts on a fresh private page. ``cow`` covers the one
writer of previously-shared pages (the weight-sync KV recompute).

The allocator also timestamps writes (``note_write`` / ``dirty_since``):
page-granularity dirty tracking is what turns the FT plane's slot
captures into incremental snapshots (only pages written since the last
capture cross the device->host boundary).

Locking: both classes are leaf locks under the engine's canonical order
(``_step_lock`` -> ``_lock`` -> here). ``PagedKVAllocator._lock`` guards
all allocator state; :class:`PrefixCache` is driven only from under the
engine's ``_step_lock`` and delegates page lifetime to the allocator, so
it needs no lock of its own beyond the allocator's.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple


class PageLeakError(AssertionError):
    """Raised by :meth:`PagedKVAllocator.check` on invariant violation."""


class PagedKVAllocator:
    """Fixed-size pool of KV pages with refcounts and a LIFO free list.

    Page ids are ``0..num_pages-1``; the engine reserves one extra pool
    row (id ``num_pages``) as the trash page for padded writes/gathers —
    that row is outside this allocator on purpose.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        self._lock = threading.Lock()
        # LIFO free list: recently-freed pages are re-used first (their
        # pool rows are the ones most likely still in cache)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))  # guarded by: _lock
        self._refs: List[int] = [0] * num_pages    # guarded by: _lock
        # monotonic write stamps for incremental snapshots: stamp 0 means
        # "never written"; dirty_since(e) returns pages written at stamp>e
        self._stamp: List[int] = [0] * num_pages   # guarded by: _lock
        self._clock = 0                            # guarded by: _lock
        self.highwater = 0                         # guarded by: _lock

    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def used_pages(self) -> int:
        with self._lock:
            return self.num_pages - len(self._free)

    def refcount(self, pid: int) -> int:
        with self._lock:
            return self._refs[pid]

    # ------------------------------------------------------------------
    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` fresh pages with refcount 1 each, or None if the pool
        cannot satisfy the whole request (all-or-nothing: the engine
        allocates a slot's full prompt+budget worth of pages at admission
        so decode can never fail mid-flight)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        with self._lock:
            if n > len(self._free):
                return None
            pids = [self._free.pop() for _ in range(n)]
            for p in pids:
                self._refs[p] = 1
            self.highwater = max(self.highwater,
                                 self.num_pages - len(self._free))
            return pids

    def incref(self, pids: Sequence[int]):
        """Fork: one more holder per page (slot table entry or prefix-
        cache node)."""
        with self._lock:
            for p in pids:
                if self._refs[p] <= 0:
                    raise PageLeakError(f"incref of free page {p}")
                self._refs[p] += 1

    def decref(self, pids: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns the pages actually freed
        (refcount hit zero -> back on the free list)."""
        freed: List[int] = []
        with self._lock:
            for p in pids:
                if self._refs[p] <= 0:
                    raise PageLeakError(f"decref of free page {p}")
                self._refs[p] -= 1
                if self._refs[p] == 0:
                    self._free.append(p)
                    freed.append(p)
        return freed

    def cow(self, pid: int) -> Optional[int]:
        """Copy-on-write: an exclusive page id for a writer of ``pid``.
        Refcount 1 -> already exclusive, returned as-is. Shared -> a
        fresh page is allocated (caller copies/recomputes the contents)
        and the writer's reference to ``pid`` is dropped. None when the
        pool is exhausted (caller may evict prefix-cache pages and
        retry, or fall back to an in-place rewrite)."""
        with self._lock:
            if self._refs[pid] <= 0:
                raise PageLeakError(f"cow of free page {pid}")
            if self._refs[pid] == 1:
                return pid
            if not self._free:
                return None
            new = self._free.pop()
            self._refs[new] = 1
            self._refs[pid] -= 1
            self.highwater = max(self.highwater,
                                 self.num_pages - len(self._free))
            return new

    # ------------------------------------------------------------------
    # write stamps (incremental snapshots)
    # ------------------------------------------------------------------
    def note_write(self, pids: Sequence[int]):
        """Record that ``pids`` were (re)written on device."""
        with self._lock:
            self._clock += 1
            for p in pids:
                self._stamp[p] = self._clock

    def clock(self) -> int:
        with self._lock:
            return self._clock

    def dirty_since(self, stamp: int) -> List[int]:
        """ALLOCATED pages written after ``stamp`` (free pages are never
        captured: their contents are dead)."""
        with self._lock:
            return [p for p in range(self.num_pages)
                    if self._refs[p] > 0 and self._stamp[p] > stamp]

    # ------------------------------------------------------------------
    def check(self, external_refs: Optional[Dict[int, int]] = None):
        """Invariants (hypothesis harness + engine tests):

        - every page is exactly once in {free list} or {refcount > 0};
        - the free list holds no duplicates and no referenced page;
        - with ``external_refs`` (pid -> expected holders), refcounts
          match the callers' books exactly (no leaked references).
        """
        with self._lock:
            free = list(self._free)
            refs = list(self._refs)
        if len(set(free)) != len(free):
            raise PageLeakError(f"duplicate pages in free list: {free}")
        for p in free:
            if refs[p] != 0:
                raise PageLeakError(f"page {p} free but refcount {refs[p]}")
        for p in range(self.num_pages):
            if refs[p] < 0:
                raise PageLeakError(f"page {p} refcount {refs[p]} < 0")
            if refs[p] == 0 and p not in set(free):
                raise PageLeakError(f"page {p} leaked (ref 0, not free)")
        if external_refs is not None:
            for p in range(self.num_pages):
                want = external_refs.get(p, 0)
                if refs[p] != want:
                    raise PageLeakError(
                        f"page {p}: refcount {refs[p]} != {want} holders")


class _Node:
    __slots__ = ("pid", "children", "tick")

    def __init__(self, pid: int, tick: int):
        self.pid = pid
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.tick = tick


class PrefixCache:
    """Radix-style prefix cache over page-granular token chunks.

    Keys are tuples of ``page_size`` token ids; a path from the root
    spells a token prefix and each node pins one KV page (the cache
    holds a real allocator reference per node, so cached pages survive
    their originating slot). ``match`` is the fork fast path; ``insert``
    is called after admission prefill (prompt pages) and at slot release
    (full-sequence pages, which is what makes multi-turn continuations
    hit). Eviction is LRU over LEAF nodes only — evicting an interior
    node would orphan its descendants' match path.

    Driven exclusively from under the engine's ``_step_lock`` (admission,
    release, weight sync); page lifetime is delegated to the allocator,
    whose lock is the leaf of the ordering.
    """

    def __init__(self, alloc: PagedKVAllocator, page_size: int):
        self.alloc = alloc
        self.page_size = page_size
        self._root: Dict[Tuple[int, ...], _Node] = {}
        self._tick = 0
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------
    def _chunks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        p = self.page_size
        n = len(tokens) // p
        return [tuple(tokens[i * p:(i + 1) * p]) for i in range(n)]

    @property
    def cached_pages(self) -> int:
        def count(children) -> int:
            return sum(1 + count(n.children) for n in children.values())
        return count(self._root)

    def page_ids(self) -> List[int]:
        out: List[int] = []

        def walk(children):
            for n in children.values():
                out.append(n.pid)
                walk(n.children)
        walk(self._root)
        return out

    # ------------------------------------------------------------------
    def match(self, tokens: Sequence[int]) -> List[int]:
        """Page ids of the longest cached full-page prefix of ``tokens``.
        Returns WITHOUT taking references — the caller must ``incref``
        the returned pages before anything (e.g. ``evict``) can drop the
        cache's own reference."""
        self._tick += 1
        pids: List[int] = []
        children = self._root
        for chunk in self._chunks(tokens):
            node = children.get(chunk)
            if node is None:
                break
            node.tick = self._tick
            pids.append(node.pid)
            children = node.children
        if pids:
            self.hits += 1
        else:
            self.misses += 1
        return pids

    def insert(self, tokens: Sequence[int], pids: Sequence[int]):
        """Register ``tokens``' full-page chunks against the slot's page
        table ``pids``. Existing nodes win (their pages hold bitwise-
        identical KV, and keeping them maximizes sharing); new nodes take
        a cache reference on the slot's page."""
        self._tick += 1
        children = self._root
        for j, chunk in enumerate(self._chunks(tokens)):
            if j >= len(pids):
                break
            node = children.get(chunk)
            if node is None:
                node = _Node(pids[j], self._tick)
                self.alloc.incref([node.pid])
                children[chunk] = node
            node.tick = self._tick
            children = node.children

    def evict(self, n: int) -> int:
        """Drop up to ``n`` LRU leaf nodes (repeatedly, so an LRU chain
        unwinds child-first). Returns how many pages were actually freed
        back to the pool — a dropped node whose page other slots still
        reference frees nothing yet."""
        freed = 0
        for _ in range(max(n, 0)):
            victim = self._lru_leaf()
            if victim is None:
                break
            parent, key, node = victim
            del parent[key]
            freed += len(self.alloc.decref([node.pid]))
        return freed

    def _lru_leaf(self):
        best = None

        def walk(children):
            nonlocal best
            for key, n in children.items():
                if n.children:
                    walk(n.children)
                elif best is None or n.tick < best[2].tick:
                    best = (children, key, n)
        walk(self._root)
        return best

    def clear(self):
        """Drop every cached page (weight sync: cached KV is stale under
        the new weights; engine crash: the pool itself is gone)."""
        pids = self.page_ids()
        self._root = {}
        if pids:
            self.alloc.decref(pids)

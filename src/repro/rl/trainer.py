"""Training-side substrate: TrainState + jit/pjit-able step functions.

``make_grpo_train_step`` is what the dry-run lowers for ``train_4k`` shapes
and what the live ActorTrain worker executes. ``make_lm_train_step`` supports
the quickstart pretraining example.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax.numpy as jnp

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.models.moe import moe_aux_loss
from repro.optim.adamw import AdamW, AdamWState, constant
from repro.rl import losses as LO


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    version: jnp.ndarray        # weight version (staleness protocol)


def init_train_state(model: Model, key, optimizer: AdamW) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=optimizer.init(params),
                      version=jnp.zeros((), jnp.int32))


def grpo_batch_spec(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """ShapeDtypeStructs of a GRPO training batch (used by the dry-run)."""
    f = jax.ShapeDtypeStruct
    return {
        "tokens": f((batch, seq), jnp.int32),
        "loss_mask": f((batch, seq), jnp.float32),
        "advantages": f((batch,), jnp.float32),
        "behavior_logprobs": f((batch, seq - 1), jnp.float32),
    }


def make_grpo_train_step(model: Model, optimizer: AdamW,
                         clip_eps: float = 0.2, kl_coef: float = 0.0,
                         num_microbatches: int = 1):
    """GRPO train step. ``num_microbatches > 1`` enables gradient
    accumulation inside one jit (a lax.scan over batch slices): activation
    working set scales ~1/k at the same global batch — the production fix
    for activation-bound architectures (jamba train_4k, §Perf iter 5)."""
    cfg = model.cfg

    def loss_fn(params, batch):
        lp, aux = model.forward_logprobs(params, batch["tokens"],
                                         cond=batch.get("cond"))
        loss, metrics = LO.grpo_from_logprobs(
            lp, batch["tokens"], batch["loss_mask"],
            batch["advantages"], batch["behavior_logprobs"],
            ref_logprobs=batch.get("ref_logprobs"),
            clip_eps=clip_eps, kl_coef=kl_coef)
        if cfg.uses_moe:
            loss = loss + moe_aux_loss(aux, cfg)
            metrics["moe_lb"] = aux["lb_loss"]
        return loss, metrics

    def _grads(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(state: TrainState, batch):
        if num_microbatches <= 1:
            (loss, metrics), grads = _grads(state.params, batch)
        else:
            k = num_microbatches
            B = batch["tokens"].shape[0]
            assert B % k == 0, (B, k)

            def slice_mb(x, i):
                return jax.lax.dynamic_slice_in_dim(x, i * (B // k), B // k)

            def body(carry, i):
                grads_acc = carry
                mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
                (loss, metrics), g = _grads(state.params, mb)
                grads_acc = jax.tree.map(lambda a, b: a + b, grads_acc, g)
                return grads_acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, (losses, metricses) = jax.lax.scan(
                body, zeros, jnp.arange(k))
            grads = jax.tree.map(lambda g: g / k, grads)
            metrics = jax.tree.map(lambda m: m.mean(), metricses)
        new_params, new_opt, gnorm = optimizer.update(grads, state.opt,
                                                      state.params)
        metrics = dict(metrics, grad_norm=gnorm)
        return TrainState(params=new_params, opt=new_opt,
                          version=state.version + 1), metrics

    return train_step


def make_lm_train_step(model: Model, optimizer: AdamW):
    cfg = model.cfg

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch["tokens"])
        loss = LO.lm_loss(logits, batch["tokens"], batch.get("mask"))
        if cfg.uses_moe:
            loss = loss + moe_aux_loss(aux, cfg)
        return loss, {"loss": loss}

    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch)
        new_params, new_opt, gnorm = optimizer.update(grads, state.opt,
                                                      state.params)
        return TrainState(params=new_params, opt=new_opt,
                          version=state.version + 1), dict(metrics,
                                                           grad_norm=gnorm)

    return train_step


def make_logprob_fn(model: Model):
    """Recompute per-token logprobs of given trajectories under ``params``
    (used for ref/behavior logprobs on the training side)."""
    def logprob_fn(params, tokens):
        logits, _ = model.forward(params, tokens)
        return LO.token_logprobs(logits, tokens)
    return logprob_fn


def default_optimizer(lr: float = 3e-4) -> AdamW:
    return AdamW(lr=constant(lr))

from repro.rl.engine import (GenRequest, GenResult, InferenceEngine,
                             KVHandoff)
from repro.rl.trainer import (TrainState, default_optimizer, grpo_batch_spec,
                              init_train_state, make_grpo_train_step,
                              make_lm_train_step, make_logprob_fn)

"""Observability plane: in-process Prometheus-style metrics, the
``/metrics`` HTTP endpoint + terminal dashboard, the heartbeat watchdog
that feeds silent-hang detection into FT recovery, and the collector
wiring that maps every data-plane ``stats()`` surface into the registry.

See the README's "Observability" section for the operator view.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricFamily,
                               MetricsRegistry, REGISTRY)
from repro.obs.server import CONTENT_TYPE, MetricsServer
from repro.obs.watchdog import (Watchdog, watch_engines,
                                watch_env_managers, watch_service)
from repro.obs.instrument import (instrument_buffer, instrument_proxy,
                                  instrument_runner, instrument_service,
                                  instrument_serverless)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricFamily", "MetricsRegistry",
    "REGISTRY", "CONTENT_TYPE", "MetricsServer", "Watchdog",
    "watch_engines", "watch_env_managers", "watch_service",
    "instrument_buffer", "instrument_proxy", "instrument_runner",
    "instrument_service", "instrument_serverless",
]

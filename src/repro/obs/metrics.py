"""In-process metrics plane (paper §8–§9 operational story): a
thread-safe ``Counter`` / ``Gauge`` / ``Histogram`` registry with label
sets, rendered in Prometheus text exposition format (v0.0.4).

Two feed paths, chosen per metric by cost:

- **scrape-time collectors** — callables registered on the registry and
  invoked at render time; they map the data plane's audited ``stats()``
  snapshots onto gauges/counters, so the hot path pays nothing between
  scrapes (see :mod:`repro.obs.instrument`);
- **event-time observation** — latency histograms (TTFT, inter-token
  gap, serverless invoke) are fed by cheap hooks at the moment the
  event happens, since percentiles cannot be reconstructed from totals.

Locking: every metric child owns a private leaf ``Lock`` around its
value; families guard their children map; the registry guards the
family/collector tables. Collectors run OUTSIDE the registry lock —
they call into engine/proxy/service ``stats()`` which take data-plane
locks, and holding the registry lock across those would couple the
scrape path into the data plane's lock order.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Callable, Dict, List, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# latency-shaped default buckets (seconds): sub-ms dispatch overheads up
# through multi-second step times
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _escape_help(s: str) -> str:
    return s.replace("\\", r"\\").replace("\n", r"\n")


def _escape_label(s: str) -> str:
    return (s.replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    if f.is_integer() and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def format_labels(names: Sequence[str], values: Sequence[str]) -> str:
    if not names:
        return ""
    inner = ",".join(f'{n}="{_escape_label(str(v))}"'
                     for n, v in zip(names, values))
    return "{" + inner + "}"


class Counter:
    """Monotone total. ``set_total`` exists for scrape-time collectors
    that mirror an absolute counter maintained by the data plane
    (``engine.decode_tokens`` etc.); it clamps to monotone."""

    kind = "counter"

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0          # guarded by: _lock

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += amount

    def set_total(self, value: float) -> None:
        with self._lock:
            if value > self._value:
                self._value = float(value)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _render(self, name: str, labels: str, out: List[str]) -> None:
        out.append(f"{name}{labels} {_fmt(self.value)}")


class Gauge:
    kind = "gauge"

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0          # guarded by: _lock

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value -= amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _render(self, name: str, labels: str, out: List[str]) -> None:
        out.append(f"{name}{labels} {_fmt(self.value)}")


class Histogram:
    """Fixed-bucket histogram; per-bucket counts are stored
    non-cumulative and cumulated at render (exposition requires
    monotone ``le`` buckets ending at ``+Inf``)."""

    kind = "histogram"

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS):
        bounds = sorted(float(b) for b in buckets)
        if not bounds:
            raise ValueError("histogram needs at least one bucket")
        if bounds[-1] != math.inf:
            bounds.append(math.inf)
        self.bounds: Tuple[float, ...] = tuple(bounds)
        self._lock = threading.Lock()
        self._counts = [0] * len(self.bounds)   # guarded by: _lock
        self._sum = 0.0                         # guarded by: _lock

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            for i, b in enumerate(self.bounds):
                if v <= b:
                    self._counts[i] += 1
                    break
            self._sum += v

    def snapshot(self) -> Tuple[List[int], float, int]:
        """(cumulative bucket counts, sum, total count)."""
        with self._lock:
            counts = list(self._counts)
            total_sum = self._sum
        cum, acc = [], 0
        for c in counts:
            acc += c
            cum.append(acc)
        return cum, total_sum, acc

    def percentile(self, q: float) -> float:
        """Bucket-upper-bound estimate of the q-th percentile (what a
        PromQL ``histogram_quantile`` would see)."""
        cum, _, total = self.snapshot()
        if total == 0:
            return 0.0
        rank = q * total
        for i, c in enumerate(cum):
            if c >= rank:
                b = self.bounds[i]
                return b if b != math.inf else self.bounds[max(0, i - 1)]
        return self.bounds[-2] if len(self.bounds) > 1 else 0.0

    def _render(self, name: str, labels: str, out: List[str]) -> None:
        cum, total_sum, count = self.snapshot()
        # re-open the label set to append `le`
        base = labels[1:-1] + "," if labels else ""
        for b, c in zip(self.bounds, cum):
            out.append(f'{name}_bucket{{{base}le="{_fmt(b)}"}} {c}')
        out.append(f"{name}_sum{labels} {_fmt(total_sum)}")
        out.append(f"{name}_count{labels} {count}")


class MetricFamily:
    """One named metric + its labelled children."""

    def __init__(self, name: str, help_text: str, kind: str,
                 labelnames: Sequence[str], factory: Callable):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        self.name = name
        self.help_text = help_text
        self.kind = kind
        self.labelnames = tuple(labelnames)
        self._factory = factory
        self._lock = threading.Lock()
        self._children = {}        # guarded by: _lock

    def labels(self, **kv) -> object:
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(kv)} != declared "
                f"{sorted(self.labelnames)}")
        key = tuple(str(kv[ln]) for ln in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._factory()
                self._children[key] = child
            return child

    def child(self) -> object:
        """The unlabelled child (only for label-free families)."""
        if self.labelnames:
            raise ValueError(f"{self.name} declares labels; use .labels()")
        return self.labels()

    def render_into(self, out: List[str]) -> None:
        out.append(f"# HELP {self.name} {_escape_help(self.help_text)}")
        out.append(f"# TYPE {self.name} {self.kind}")
        with self._lock:
            children = sorted(self._children.items())
        for key, child in children:
            child._render(self.name, format_labels(self.labelnames, key),
                          out)


class MetricsRegistry:
    """Family table + scrape-time collectors. ``render()`` runs the
    collectors first (outside the registry lock), then renders every
    family in registration order."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families = {}        # guarded by: _lock
        self._collectors = []      # guarded by: _lock

    def _get_or_create(self, name, help_text, kind, labelnames, factory):
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} re-registered as {kind} "
                        f"{tuple(labelnames)} (was {fam.kind} "
                        f"{fam.labelnames})")
                return fam
            fam = MetricFamily(name, help_text, kind, labelnames, factory)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help_text: str,
                labelnames: Sequence[str] = ()) -> MetricFamily:
        return self._get_or_create(name, help_text, "counter",
                                   labelnames, Counter)

    def gauge(self, name: str, help_text: str,
              labelnames: Sequence[str] = ()) -> MetricFamily:
        return self._get_or_create(name, help_text, "gauge",
                                   labelnames, Gauge)

    def histogram(self, name: str, help_text: str,
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS
                  ) -> MetricFamily:
        return self._get_or_create(name, help_text, "histogram",
                                   labelnames,
                                   lambda: Histogram(buckets))

    def register_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            self._collectors.append(fn)

    def families(self) -> List[MetricFamily]:
        with self._lock:
            return list(self._families.values())

    def collect(self) -> None:
        """Run every registered collector (outside the registry lock:
        collectors call data-plane ``stats()`` which take their own
        locks)."""
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            fn()

    def render(self) -> str:
        self.collect()
        out: List[str] = []
        for fam in self.families():
            fam.render_into(out)
        return "\n".join(out) + "\n"


# the process-default registry the launchers and benchmarks share
REGISTRY = MetricsRegistry()

"""Dependency-free ``/metrics`` HTTP endpoint over a
:class:`~repro.obs.metrics.MetricsRegistry` — stdlib
``ThreadingHTTPServer`` only, Prometheus text exposition content type.

Scrapes run collectors, which call data-plane ``stats()`` under the
plane's own locks; a scrape therefore waits (bounded by one decode
macro-step) for any in-flight dispatch, exactly like an external
Prometheus scrape would.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class _Handler(BaseHTTPRequestHandler):
    def do_GET(self):  # noqa: N802 (stdlib handler API)
        if self.path.split("?")[0] not in ("/", "/metrics"):
            self.send_error(404, "only /metrics is served")
            return
        try:
            body = self.server.registry.render().encode("utf-8")
        except Exception as e:               # surface scrape failures
            self.send_error(500, f"scrape failed: {type(e).__name__}")
            return
        self.send_response(200)
        self.send_header("Content-Type", CONTENT_TYPE)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):       # keep the launcher output clean
        pass


class MetricsServer:
    """``MetricsServer(registry, port=0).start()`` — port 0 binds an
    ephemeral port, readable from ``.port`` after ``start()``."""

    def __init__(self, registry, port: int = 0, host: str = "127.0.0.1"):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.registry = registry
        self._thread = None

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}/metrics"

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-http",
            daemon=True)
        self._thread.start()
        return self

    def close(self, timeout: float = 2.0) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.close()

"""Heartbeat watchdog: live detection of *silently hung* components
(paper §8 — the FT plane's PR 5 gap: only injected faults were
recoverable, a wedged ``step()`` went unnoticed forever).

Data-plane components publish **beats** — bare monotonically-advancing
counters bumped OUTSIDE their locks (``engine.beats`` at the end of
every ``step()``, ``service.beats`` after every tick). A beat that keeps
advancing proves the component's thread is cycling; the watchdog never
acquires a data-plane lock to read one (a wedged ``step()`` holds
``_step_lock`` forever — any probe that touched it would hang the
monitor too).

A target stalls when its beat has not advanced within ``deadline_s``
*while work is queued* (idle components re-arm). ``on_stall`` fires
once per stall episode from the monitor thread, which holds no locks —
so a handler may take service barriers, hard-kill engines, and drive
``FTSupervisor`` recovery.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional


class WatchTarget:
    def __init__(self, name: str, progress_fn: Callable[[], object],
                 queued_fn: Callable[[], bool],
                 on_stall: Optional[Callable[[], None]],
                 deadline_s: float):
        self.name = name
        self.progress_fn = progress_fn
        self.queued_fn = queued_fn
        self.on_stall = on_stall
        self.deadline_s = deadline_s
        # poll-thread-only state (single poller by contract)
        self.last_value: Optional[object] = None
        self.last_beat_t: Optional[float] = None
        self.stalled = False
        self.stall_count = 0


class Watchdog:
    """``register()`` targets, ``start()`` the monitor thread (or drive
    ``check_once()`` manually for deterministic tests)."""

    def __init__(self, deadline_s: float = 2.0, poll_s: float = 0.05,
                 registry=None, clock: Callable[[], float] = time.monotonic):
        self.deadline_s = deadline_s
        self.poll_s = poll_s
        self._clock = clock
        self._lock = threading.Lock()
        self._targets: Dict[str, WatchTarget] = {}  # guarded by: _lock
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._stalls_fam = None
        self._age_fam = None
        if registry is not None:
            self._stalls_fam = registry.counter(
                "repro_watchdog_stalls_total",
                "stall episodes detected (beat silent past deadline "
                "with work queued)", ("component",))
            self._age_fam = registry.gauge(
                "repro_watchdog_beat_age_seconds",
                "seconds since the component's beat last advanced",
                ("component",))

    def register(self, name: str, progress_fn: Callable[[], object],
                 queued_fn: Callable[[], bool],
                 on_stall: Optional[Callable[[], None]] = None,
                 deadline_s: Optional[float] = None) -> None:
        t = WatchTarget(name, progress_fn, queued_fn, on_stall,
                        self.deadline_s if deadline_s is None
                        else deadline_s)
        with self._lock:
            self._targets[name] = t

    def targets(self) -> List[str]:
        with self._lock:
            return sorted(self._targets)

    def check_once(self, now: Optional[float] = None) -> List[str]:
        """One poll pass; returns the names whose stall fired this
        pass. Probes and handlers run with NO watchdog lock held."""
        with self._lock:
            targets = list(self._targets.values())
        if now is None:
            now = self._clock()
        fired: List[WatchTarget] = []
        for t in targets:
            try:
                v = t.progress_fn()
            except Exception:
                continue                     # plane mid-mutation: skip poll
            if t.last_value is None or v != t.last_value:
                t.last_value = v
                t.last_beat_t = now
                t.stalled = False
                self._export_age(t, 0.0)
                continue
            self._export_age(t, now - (t.last_beat_t or now))
            try:
                queued = bool(t.queued_fn())
            except Exception:
                continue
            if not queued:
                t.last_beat_t = now          # idle: deadline re-arms
                continue
            if not t.stalled and now - t.last_beat_t >= t.deadline_s:
                t.stalled = True
                t.stall_count += 1
                if self._stalls_fam is not None:
                    self._stalls_fam.labels(component=t.name).inc()
                fired.append(t)
        for t in fired:
            if t.on_stall is not None:
                t.on_stall()
        return [t.name for t in fired]

    def _export_age(self, t: WatchTarget, age: float) -> None:
        if self._age_fam is not None:
            self._age_fam.labels(component=t.name).set(age)

    def start(self) -> "Watchdog":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="obs-watchdog", daemon=True)
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.poll_s):
            self.check_once()

    def close(self, timeout: float = 2.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# wiring helpers — callbacks are passed in so this module stays
# import-independent of the FT plane
# ---------------------------------------------------------------------------
def watch_engines(wd: Watchdog, proxy,
                  recover: Optional[Callable] = None,
                  deadline_s: Optional[float] = None) -> None:
    """One target per engine handle: beat = ``engine.beats`` (bumped
    outside all engine locks), queued = ``has_pending``. ``recover``
    is called with the HANDLE on stall (e.g.
    ``supervisor.recover_hung_engine``)."""
    for i, h in enumerate(proxy.handles):
        name = f"engine:{h.name or h.pool or i}"
        on_stall = (None if recover is None
                    else (lambda h=h: recover(h)))
        wd.register(name,
                    progress_fn=lambda e=h.engine: e.beats,
                    queued_fn=lambda e=h.engine: e.has_pending,
                    on_stall=on_stall, deadline_s=deadline_s)


def watch_service(wd: Watchdog, svc,
                  on_stall: Optional[Callable[[], None]] = None,
                  deadline_s: Optional[float] = None) -> None:
    """Pump-loop liveness: the service beat advances every tick (idle
    ticks included), so silence while the loop should be running means
    the pump thread is wedged or dead."""
    wd.register("service:pump",
                progress_fn=lambda: svc.beats,
                queued_fn=svc.loop_expected_alive,
                on_stall=on_stall, deadline_s=deadline_s)


def watch_env_managers(wd: Watchdog, runner,
                       recover: Optional[Callable[[], None]] = None,
                       deadline_s: Optional[float] = None) -> None:
    """Aggregate EnvManager progress: total generated tokens across the
    runner's active managers. Stalls (GENERATING but no token growth)
    indicate lost routes; ``recover`` should re-home them (e.g.
    ``supervisor.recover_stalled_ems``). Probes read the live
    collections racily and skip the poll on mutation races."""
    def progress():
        return sum(len(em.tokens) for em in list(runner.active))

    def queued():
        return any(em.state.name == "GENERATING"
                   for em in list(runner.active))

    wd.register("env-managers", progress_fn=progress, queued_fn=queued,
                on_stall=recover, deadline_s=deadline_s)

"""Curses-free terminal dashboard over the ``/metrics`` endpoint.

``python -m repro.obs.dashboard --url http://127.0.0.1:9100/metrics``
scrapes the Prometheus text exposition (stdlib ``urllib`` only), parses
it with the minimal grammar below, and redraws the terminal with plain
ANSI escapes (clear + home) every ``--interval`` seconds; ``--once``
prints a single frame and exits (usable in a pipe — the ANSI clear is
suppressed when stdout is not a tty).

Histogram families are condensed to count / mean / ~p50 / ~p99
(percentiles estimated from bucket upper bounds, the same estimator
``repro.obs.metrics.Histogram.percentile`` uses).
"""
from __future__ import annotations

import argparse
import sys
import time
import urllib.request
from typing import Dict, List, Tuple


def parse_exposition(text: str) -> List[Tuple[str, str, float]]:
    """Parse Prometheus text format into ``(name, labels, value)``
    samples (labels kept as the raw ``{...}`` string, ``""`` when
    absent). Comment/HELP/TYPE and blank lines are skipped; a malformed
    line raises — the dashboard should be loud about a bad exporter."""
    out = []
    for line in text.splitlines():
        if not line.strip() or line.startswith("#"):
            continue
        if "}" in line:
            head, _, tail = line.partition("}")
            name, _, labels = head.partition("{")
            labels = "{" + labels + "}"
            value = tail.strip().split()[0]
        else:
            name, value = line.split()[:2]
            labels = ""
        out.append((name, labels, float(value)))
    return out


def _labels_of(raw: str) -> Dict[str, str]:
    """Label-string -> dict for the simple label values this repo emits
    (no embedded commas/quotes in values; the golden-format test covers
    the escaping path, the dashboard only needs the common case)."""
    if not raw or raw == "{}":
        return {}
    out = {}
    for part in raw[1:-1].split(","):
        if not part:
            continue
        k, _, v = part.partition("=")
        out[k] = v.strip('"')
    return out


def _histogram_rows(samples) -> Tuple[List[str], set]:
    """Condense ``*_bucket``/``*_sum``/``*_count`` triples into one row
    per (family, label set). Returns the rows plus the sample names
    consumed (so the plain renderer skips them)."""
    fams: Dict[Tuple[str, str], Dict] = {}
    consumed = set()
    for name, labels, value in samples:
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix):
                base = name[: -len(suffix)]
                lab = _labels_of(labels)
                le = lab.pop("le", None)
                key = (base, ",".join(f"{k}={v}"
                                      for k, v in sorted(lab.items())))
                f = fams.setdefault(key, {"buckets": [], "sum": 0.0,
                                          "count": 0.0})
                if suffix == "_bucket":
                    f["buckets"].append((float(le), value))
                elif suffix == "_sum":
                    f["sum"] = value
                else:
                    f["count"] = value
                consumed.add(name)
                break
    rows = []
    for (base, lab), f in sorted(fams.items()):
        n = f["count"]
        mean = f["sum"] / n if n else 0.0
        rows.append(f"  {base}{'{' + lab + '}' if lab else '':<40} "
                    f"n={int(n):<8} mean={mean:.4f}s "
                    f"p50={_pct(f['buckets'], n, 0.5):.4f}s "
                    f"p99={_pct(f['buckets'], n, 0.99):.4f}s")
    return rows, consumed


def _pct(buckets: List[Tuple[float, float]], count: float,
         q: float) -> float:
    if not count:
        return 0.0
    rank = q * count
    prev_bound = 0.0
    for bound, cum in sorted(buckets):
        if cum >= rank:
            return bound if bound != float("inf") else prev_bound
        prev_bound = bound
    return prev_bound


def render(text: str) -> str:
    samples = parse_exposition(text)
    hist_rows, consumed = _histogram_rows(samples)
    groups: Dict[str, List[str]] = {}
    for name, labels, value in samples:
        if name in consumed:
            continue
        # group by subsystem: repro_engine_*, repro_proxy_*, ...
        parts = name.split("_", 2)
        group = "_".join(parts[:2]) if len(parts) > 2 else name
        v = f"{int(value)}" if value == int(value) else f"{value:.4f}"
        groups.setdefault(group, []).append(
            f"  {name}{labels:<44} {v}")
    lines = [time.strftime("== repro obs dashboard — %H:%M:%S =="), ""]
    for group in sorted(groups):
        lines.append(group)
        lines.extend(sorted(groups[group]))
        lines.append("")
    if hist_rows:
        lines.append("latency histograms")
        lines.extend(hist_rows)
    return "\n".join(lines)


def scrape(url: str, timeout: float = 5.0) -> str:
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return resp.read().decode("utf-8")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--url", default="http://127.0.0.1:9100/metrics")
    ap.add_argument("--interval", type=float, default=2.0)
    ap.add_argument("--once", action="store_true",
                    help="print one frame and exit")
    args = ap.parse_args(argv)
    clear = "\x1b[2J\x1b[H" if sys.stdout.isatty() else ""
    while True:
        try:
            frame = render(scrape(args.url))
        except OSError as e:
            frame = f"scrape failed: {e} ({args.url})"
        sys.stdout.write(clear + frame + "\n")
        sys.stdout.flush()
        if args.once:
            return 0
        time.sleep(args.interval)


if __name__ == "__main__":
    raise SystemExit(main())

"""Collector wiring: the bridge between the data plane's ``stats()``
snapshots and the metrics registry.

Two feed paths, chosen per metric:

- **scrape-time collectors** — registered callables the registry runs at
  render time, mapping a component's immutable ``stats()`` snapshot onto
  gauges and (via :meth:`Counter.set_total`) monotone counters. Zero
  hot-path cost: nothing is touched until someone scrapes.
- **event-time observations** — histograms (latency distributions can't
  be reconstructed from totals), fed by the data plane's bare hook
  attributes, which every plane fires OUTSIDE its locks:
  ``proxy.on_ttft`` / ``proxy.on_gap`` (per-request SLO timings from the
  lifecycle records) and ``serverless.on_invoke`` (reward-call wall
  time).

``instrument_runner`` wires the whole training stack (proxy + engines,
buffer, serverless, service tenants, per-step ``StepMetrics`` gauges);
the pieces are also usable à la carte from a serving-only deployment.
Instrument each component at most once per registry.
"""
from __future__ import annotations

from repro.obs.metrics import DEFAULT_BUCKETS, MetricsRegistry

# engine row keys mirrored as monotone counters / point-in-time gauges
# (labels: engine, role). Keys absent from a row — the paged-KV block on
# a dense engine — are skipped.
ENGINE_COUNTERS = (
    ("steps", "engine step() calls"),
    ("busy_steps", "steps that dispatched work"),
    ("decode_dispatches", "decode macro-step dispatches"),
    ("prefill_tokens", "prompt tokens prefetched"),
    ("decode_tokens", "tokens decoded"),
    ("recomputes", "in-flight KV recomputes after weight swaps"),
    ("handoffs_out", "KV handoffs exported (PD prefill side)"),
    ("handoffs_in", "KV handoffs imported (PD decode side)"),
    ("crashes", "engine process crashes (injected or watchdog-killed)"),
    ("sharding_drops", "requests bounced off a mid-resize TP group"),
    ("sync_bytes", "weight-sync bytes pulled"),
    ("rejected_too_long", "requests rejected for context overflow"),
    ("shared_prefix_tokens", "prefill tokens served from shared prefix"),
    ("prefix_hits", "prefix-cache hits"),
    ("prefix_misses", "prefix-cache misses"),
)
ENGINE_GAUGES = (
    ("weight_version", "weight version currently loaded"),
    ("queue_len", "queued requests awaiting a KV slot"),
    ("active_slots", "occupied KV slots"),
    ("max_slots", "KV slot capacity"),
    ("free_pages", "free KV pages (paged engines)"),
    ("page_highwater", "peak KV pages in use (paged engines)"),
    ("prefix_cached_pages", "pages pinned by the prefix cache"),
)
PROXY_COUNTERS = (
    ("requests", "requests submitted"),
    ("aborted", "requests aborted"),
    ("handoffs", "prefill->decode KV handoffs brokered"),
    ("recoveries", "requests re-homed by FT recovery"),
    ("role_switches", "dynamic prefill<->decode role switches"),
    ("switch_migrations", "requests migrated by a role switch"),
)
TENANT_COUNTERS = ("submitted", "rejected", "admitted", "completed",
                   "aborted", "failed", "scored", "stream_tokens",
                   "tokens_out", "reward_retries")
TENANT_GAUGES = ("inflight", "queued", "active_ems", "pending_rewards",
                 "vtime")


def instrument_proxy(reg: MetricsRegistry, proxy) -> None:
    """Engine + proxy counters/gauges (scrape-time, one ``proxy.stats()``
    snapshot per scrape) and the request-level SLO histograms
    (event-time, via the proxy's lifecycle hooks)."""
    eng_c = {k: reg.counter(f"repro_engine_{k}_total", h,
                            ("engine", "role"))
             for k, h in ENGINE_COUNTERS}
    eng_g = {k: reg.gauge(f"repro_engine_{k}", h, ("engine", "role"))
             for k, h in ENGINE_GAUGES}
    beats_g = reg.gauge("repro_engine_beats",
                        "liveness beat (bumped outside all engine locks "
                        "at the end of every step)", ("engine", "role"))
    prox_c = {k: reg.counter(f"repro_proxy_{k}_total", h)
              for k, h in PROXY_COUNTERS}
    routed_g = reg.gauge("repro_proxy_routed_requests",
                         "requests currently routed to an engine")
    pool_g = reg.gauge("repro_proxy_routed_by_pool",
                       "routed requests per engine pool", ("pool",))
    ttft_h = reg.histogram("repro_slo_ttft_seconds",
                           "submit -> first generated token",
                           buckets=DEFAULT_BUCKETS)
    gap_h = reg.histogram("repro_slo_intertoken_seconds",
                          "per-token gap between stream deliveries",
                          buckets=DEFAULT_BUCKETS)
    proxy.on_ttft = lambda s: ttft_h.child().observe(s)
    proxy.on_gap = lambda s: gap_h.child().observe(s)

    def collect():
        st = proxy.stats()
        for row in st["engines"]:
            lab = {"engine": row["name"] or row["pool"],
                   "role": row["role"]}
            for k, fam in eng_c.items():
                if k in row:
                    fam.labels(**lab).set_total(row[k])
            for k, fam in eng_g.items():
                if k in row:
                    fam.labels(**lab).set(row[k])
        for h in proxy.handles:
            beats_g.labels(engine=h.name or h.pool,
                           role=h.role).set(h.engine.beats)
        for k, fam in prox_c.items():
            fam.child().set_total(st[k])
        routed_g.child().set(st["routed_requests"])
        for pool, n in st["routed_by_pool"].items():
            pool_g.labels(pool=pool).set(n)

    reg.register_collector(collect)


def instrument_buffer(reg: MetricsRegistry, buffer) -> None:
    depth = reg.gauge("repro_buffer_depth",
                      "scored trajectories awaiting training")
    version = reg.gauge("repro_buffer_version",
                        "trainer weight version the buffer enforces")
    counters = {
        "total_put": reg.counter("repro_buffer_put_total",
                                 "trajectories accepted"),
        "total_evicted": reg.counter("repro_buffer_evicted_total",
                                     "trajectories evicted as stale"),
        "total_consumed": reg.counter("repro_buffer_consumed_total",
                                      "trajectories handed to the trainer"),
        "total_deduped": reg.counter("repro_buffer_deduped_total",
                                     "replayed trajectories dropped by "
                                     "traj_id dedup"),
    }

    def collect():
        st = buffer.stats()
        depth.child().set(st["depth"])
        version.child().set(st["current_version"])
        for k, fam in counters.items():
            fam.child().set_total(st[k])

    reg.register_collector(collect)


def instrument_serverless(reg: MetricsRegistry, sls) -> None:
    inflight = reg.gauge("repro_serverless_inflight",
                         "invocations currently executing")
    peak = reg.gauge("repro_serverless_peak_instances",
                     "peak concurrent instances")
    counters = {
        "invocations": reg.counter("repro_serverless_invocations_total",
                                   "serverless invocations"),
        "cold_starts": reg.counter("repro_serverless_cold_starts_total",
                                   "cold starts"),
        "failures": reg.counter("repro_serverless_failures_total",
                                "lost invocations (incl. injected)"),
        "payload_bytes": reg.counter("repro_serverless_payload_bytes_total",
                                     "invocation payload bytes"),
    }
    lat_h = reg.histogram("repro_serverless_invoke_latency_seconds",
                          "wall time of one live invocation",
                          buckets=DEFAULT_BUCKETS)
    sls.on_invoke = lambda url, s: lat_h.child().observe(s)

    def collect():
        snap = sls.snapshot()
        inflight.child().set(sls.inflight)
        peak.child().set(snap.peak_instances)
        for k, fam in counters.items():
            fam.child().set_total(getattr(snap, k))

    reg.register_collector(collect)


def instrument_service(reg: MetricsRegistry, svc) -> None:
    """Per-tenant admission/QoS counters and occupancy gauges (labels:
    tenant) plus the service beat."""
    cnt = {k: reg.counter(f"repro_service_{k}_total",
                          f"tenant {k} events", ("tenant",))
           for k in TENANT_COUNTERS}
    gau = {k: reg.gauge(f"repro_service_{k}",
                        f"tenant {k} (instantaneous)", ("tenant",))
           for k in TENANT_GAUGES}
    beats = reg.gauge("repro_service_beats",
                      "pump-loop liveness beat (bumped after every tick)")

    def collect():
        beats.child().set(svc.beats)
        for name, row in svc.stats().items():
            for k, fam in cnt.items():
                fam.labels(tenant=name).set_total(row[k])
            for k, fam in gau.items():
                fam.labels(tenant=name).set(row[k])

    reg.register_collector(collect)


def instrument_runner(reg: MetricsRegistry, runner) -> None:
    """The whole training stack: proxy + engines, buffer, serverless,
    service tenants, and one ``repro_step_<field>`` gauge per
    ``STEP_METRICS_SCHEMA`` entry reflecting the latest completed
    trainer step."""
    from repro.core.scheduler import STEP_METRICS_SCHEMA
    instrument_proxy(reg, runner.proxy)
    instrument_buffer(reg, runner.buffer)
    instrument_serverless(reg, runner.serverless)
    instrument_service(reg, runner.service)
    step_g = {name: reg.gauge(f"repro_step_{name}",
                              f"latest StepMetrics.{name}")
              for name, _ in STEP_METRICS_SCHEMA}

    def collect():
        hist = runner.history
        if not hist:
            return
        for name, val in hist[-1].to_dict().items():
            step_g[name].child().set(val)

    reg.register_collector(collect)

"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
interpreter executes kernel bodies in Python for correctness validation)
and False on real TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, block_k: int = 256,
                     interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return _decode(q, k_cache, v_cache, lengths, block_k=block_k,
                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, lw, u, *, chunk: int = 32,
               interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return _rwkv6(r, k, v, lw, u, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan(x, delta, Bm, Cm, A_log, D, *, chunk: int = 64,
               block_d: int = 128, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return _mamba(x, delta, Bm, Cm, A_log, D, chunk=chunk, block_d=block_d,
                  interpret=interpret)

"""Pallas TPU Mamba selective scan.

The hardware-aware scan: per (batch, d_inner-block), chunks of the sequence
stream through VMEM while the [bd, ds] state stays resident in fp32
scratch; within a chunk the recurrence h_t = a_t*h_{t-1} + b_t runs as an
in-register fori_loop (ds and the chunk fit VMEM, so nothing [S, di, ds]
ever touches HBM — the memory property the jnp path approximates with
chunked associative scans).

Grid: (batch * d_inner_blocks, num_chunks), chunks innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_log_ref, d_ref, y_ref, h_out_ref,
            h_ref, *, chunk: int, block_d: int, ds: int):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)                 # [C, bd]
    dt = dt_ref[0].astype(jnp.float32)               # [C, bd]
    Bm = b_ref[0].astype(jnp.float32)                # [C, ds]
    Cm = c_ref[0].astype(jnp.float32)                # [C, ds]
    A = -jnp.exp(a_log_ref[...].astype(jnp.float32))  # [bd, ds]
    D = d_ref[0].astype(jnp.float32)                 # [bd]

    def body(t, carry):
        h, y = carry                                 # h: [bd, ds]
        a_t = jnp.exp(dt[t][:, None] * A)            # [bd, ds]
        b_t = (dt[t] * x[t])[:, None] * Bm[t][None, :]
        h = a_t * h + b_t
        y_t = jnp.sum(h * Cm[t][None, :], axis=1) + D * x[t]
        y = jax.lax.dynamic_update_slice(y, y_t[None, :], (t, 0))
        return h, y

    h0 = h_ref[...]
    y0 = jnp.zeros((chunk, block_d), jnp.float32)
    h_fin, y = jax.lax.fori_loop(0, chunk, body, (h0, y0))
    h_ref[...] = h_fin
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit():
        h_out_ref[0] = h_ref[...]


def mamba_scan(x, delta, Bm, Cm, A_log, D, *, chunk: int = 64,
               block_d: int = 128, interpret: bool = True):
    """x/delta: [B,S,di]; Bm/Cm: [B,S,ds]; A_log: [di,ds]; D: [di].

    Returns (y [B,S,di] fp32, h_out [B,di,ds] fp32)."""
    B, S, di = x.shape
    ds = A_log.shape[1]
    chunk = min(chunk, S)
    block_d = min(block_d, di)
    assert S % chunk == 0 and di % block_d == 0
    nd = di // block_d
    nc = S // chunk

    def xd_map(bd, ic):
        return (bd // nd, ic, bd % nd)

    def bc_map(bd, ic):
        return (bd // nd, ic, 0)

    def a_map(bd, ic):
        return (bd % nd, 0)

    def d_map(bd, ic):
        return (0, bd % nd)

    y, h_out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, block_d=block_d, ds=ds),
        grid=(B * nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), xd_map),
            pl.BlockSpec((1, chunk, block_d), xd_map),
            pl.BlockSpec((1, chunk, ds), bc_map),
            pl.BlockSpec((1, chunk, ds), bc_map),
            pl.BlockSpec((block_d, ds), a_map),
            pl.BlockSpec((1, block_d), d_map),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), xd_map),
            pl.BlockSpec((1, block_d, ds),
                         lambda bd, ic: (bd // nd, bd % nd, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, ds), jnp.float32)],
        interpret=interpret,
    )(x, delta, Bm, Cm, A_log, D.reshape(1, di))
    return y, h_out

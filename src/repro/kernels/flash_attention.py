"""Pallas TPU flash attention (prefill hot path).

Online-softmax blocked attention with explicit VMEM tiling: the grid is
(batch*heads, q_blocks, kv_blocks); kv_blocks is the innermost (sequential
on TPU) dimension, so the fp32 accumulator/max/denominator VMEM scratch
persists across kv steps for one (head, q-block). GQA is handled in the
K/V BlockSpec index maps (q head -> kv head). Causal masking skips
fully-masked kv blocks via @pl.when and masks the diagonal block in-kernel.

Target: TPU MXU — block shapes default to 128x128 over (seq, seq) with the
full head_dim kept resident; validated on CPU with interpret=True against
the pure-jnp oracle in ref.py.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_q: int, block_k: int, causal: bool):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    diag_ok = ((ik * block_k) <= (iq * block_q + block_q - 1)) \
        if causal else True

    @pl.when(diag_ok)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # [bq, hd]
        k = k_ref[0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    scale: Optional[float] = None,
                    interpret: bool = True):
    """q: [B,H,S,hd]; k/v: [B,kvH,S,hd] (GQA: H % kvH == 0) -> [B,H,S,hd]."""
    B, H, S, hd = q.shape
    kvH = k.shape[1]
    assert H % kvH == 0, (H, kvH)
    G = H // kvH
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * kvH, S, hd)
    vf = v.reshape(B * kvH, S, hd)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        b = bh // H
        h = bh % H
        return (b * kvH + h // G, ik, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal),
        grid=(B * H, S // block_q, S // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)

"""Pallas TPU RWKV6 chunked WKV scan.

Implements the same chunked linear-attention formulation as the jnp model
path (models/rwkv6.py): per chunk, intra-chunk contributions are two
[C,C]x[C,hd] matmuls (MXU-friendly) plus the u-bonus diagonal; the cross-
chunk state S in R^{hd x hd} lives in fp32 VMEM scratch and is carried
sequentially across the chunk grid dimension. Decay stability relies on the
model's log-decay clamp (|lw| <= 2.5 per token, chunk <= 32 -> exponents
< 88, see models/rwkv6.py).

Grid: (batch*heads, num_chunks), chunk dim innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_out_ref, S_ref, *,
            chunk: int):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        S_ref[...] = jnp.zeros_like(S_ref)

    r = r_ref[0].astype(jnp.float32)                 # [C, hd]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                 # [1, hd] -> broadcast

    cs = jnp.cumsum(lw, axis=0)                      # [C, hd]
    total = cs[-1]                                   # [hd]

    q_in = r * jnp.exp(cs - lw)                      # r_i * exp(cs_{i-1})
    k_in = k * jnp.exp(-cs)
    scores = jax.lax.dot_general(q_in, k_in, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(cols < rows, scores, 0.0)     # strictly causal
    y_intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)  # [C,1]
    y_intra = y_intra + diag * v

    S_in = S_ref[...]                                # [hd, hd] fp32
    y_inter = jax.lax.dot_general(q_in, S_in, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S' = diag(exp(total)) S + sum_j exp(total - cs_j) k_j v_j^T
    k_tail = k * jnp.exp(total[None, :] - cs)        # [C, hd]
    T = jax.lax.dot_general(k_tail, v, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    S_ref[...] = jnp.exp(total)[:, None] * S_in + T

    @pl.when(ic == nc - 1)
    def _emit_state():
        s_out_ref[0] = S_ref[...]


def rwkv6_scan(r, k, v, lw, u, *, chunk: int = 32, interpret: bool = True):
    """r/k/v: [B,S,H,hd]; lw: [B,S,H,hd] fp32 (clamped log decay);
    u: [H,hd]. Returns (y [B,S,H,hd] fp32, S_out [B,H,hd,hd] fp32)."""
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    # stability bound: within-chunk exponents reach chunk * |LW_MIN| and must
    # stay below fp32 exp overflow (~88); see models/rwkv6.py LW_MIN = -2.5
    assert chunk * 2.5 <= 85.0, f"chunk {chunk} breaks the decay-clamp bound"
    nc = S // chunk

    def to_bh(t):
        return jnp.moveaxis(t, 2, 1).reshape(B * H, S, -1)

    rf, kf, vf, lwf = (to_bh(t) for t in (r, k, v, lw))
    uf = u.reshape(H, 1, hd)

    def x_map(bh, ic):
        return (bh, ic, 0)

    def u_map(bh, ic):
        return (bh % H, 0, 0)

    y, s_out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), x_map),
            pl.BlockSpec((1, chunk, hd), x_map),
            pl.BlockSpec((1, chunk, hd), x_map),
            pl.BlockSpec((1, chunk, hd), x_map),
            pl.BlockSpec((1, 1, hd), u_map),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), x_map),
            pl.BlockSpec((1, hd, hd), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, uf)
    y = jnp.moveaxis(y.reshape(B, H, S, hd), 1, 2)
    return y, s_out.reshape(B, H, hd, hd)

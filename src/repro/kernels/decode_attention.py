"""Pallas TPU decode attention: one new token against a long KV cache (the
decode_32k / long_500k serving hot path).

Grid: (batch*heads, kv_blocks); kv_blocks iterates sequentially so the
online-softmax scratch persists per (batch, head). Cache positions >=
``lengths[b]`` are masked. The cache block stream is the bandwidth-bound
working set this kernel tiles through VMEM — exactly the workload the
paper routes to bandwidth-optimized hardware (R1).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_k: int, heads: int):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)
    bh = pl.program_id(0)
    b = bh // heads

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(ik * block_k < length)
    def _step():
        q = q_ref[0].astype(jnp.float32)             # [1, hd]
        k = k_ref[0].astype(jnp.float32)             # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        idx = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.where(idx < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     block_k: int = 256, scale: Optional[float] = None,
                     interpret: bool = True):
    """q: [B,H,hd]; caches: [B,kvH,S,hd]; lengths: [B] -> [B,H,hd]."""
    B, H, hd = q.shape
    kvH, S = k_cache.shape[1], k_cache.shape[2]
    assert H % kvH == 0
    G = H // kvH
    block_k = min(block_k, S)
    assert S % block_k == 0
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qf = q.reshape(B * H, 1, hd)
    kf = k_cache.reshape(B * kvH, S, hd)
    vf = v_cache.reshape(B * kvH, S, hd)

    def q_map(bh, ik):
        return (bh, 0, 0)

    def kv_map(bh, ik):
        b = bh // H
        h = bh % H
        return (b * kvH + h // G, ik, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k, heads=H),
        grid=(B * H, S // block_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # lengths, whole array
            pl.BlockSpec((1, 1, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qf, kf, vf)
    return out.reshape(B, H, hd)

"""Pallas TPU decode attention: one new token against a long KV cache (the
decode_32k / long_500k serving hot path).

Grid: (batch*heads, kv_blocks); kv_blocks iterates sequentially so the
online-softmax scratch persists per (batch, head). Cache positions >=
``lengths[b]`` are masked. The cache block stream is the bandwidth-bound
working set this kernel tiles through VMEM — exactly the workload the
paper routes to bandwidth-optimized hardware (R1).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_k: int, heads: int):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)
    bh = pl.program_id(0)
    b = bh // heads

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(ik * block_k < length)
    def _step():
        q = q_ref[0].astype(jnp.float32)             # [1, hd]
        k = k_ref[0].astype(jnp.float32)             # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        idx = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.where(idx < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     block_k: int = 256, scale: Optional[float] = None,
                     interpret: bool = True):
    """q: [B,H,hd]; caches: [B,kvH,S,hd]; lengths: [B] -> [B,H,hd]."""
    B, H, hd = q.shape
    kvH, S = k_cache.shape[1], k_cache.shape[2]
    assert H % kvH == 0
    G = H // kvH
    block_k = min(block_k, S)
    assert S % block_k == 0
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qf = q.reshape(B * H, 1, hd)
    kf = k_cache.reshape(B * kvH, S, hd)
    vf = v_cache.reshape(B * kvH, S, hd)

    def q_map(bh, ik):
        return (bh, 0, 0)

    def kv_map(bh, ik):
        b = bh // H
        h = bh % H
        return (b * kvH + h // G, ik, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k, heads=H),
        grid=(B * H, S // block_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # lengths, whole array
            pl.BlockSpec((1, 1, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qf, kf, vf)
    return out.reshape(B, H, hd)


def _ragged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, page: int,
                   heads: int):
    """Page-table-walking variant of ``_kernel``: the kv block for grid
    step (bh, ip) is POOL ROW ``tbl_ref[b, ip]`` (scalar-prefetched, so
    the index map can address it), and ``pl.when`` skips the step for
    pages at/after the row's length — an inactive slot (length 0) skips
    every page and never streams a byte of KV."""
    ip = pl.program_id(1)
    npg = pl.num_programs(1)
    bh = pl.program_id(0)
    b = bh // heads

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(ip * page < length)
    def _step():
        q = q_ref[0].astype(jnp.float32)             # [1, hd]
        k = k_ref[0, 0].astype(jnp.float32)          # [page, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        idx = ip * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        s = jnp.where(idx < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ip == npg - 1)
    def _finish():
        # an all-skipped row (inactive slot) has l == 0: emit zeros
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def ragged_paged_decode(q, k_pool, v_pool, tables, lengths, *,
                        scale: Optional[float] = None,
                        interpret: bool = True):
    """Ragged paged decode attention over a shared KV page pool.

    q: [B,H,hd] one new token per row; k_pool/v_pool: [N,kvH,page,hd]
    pooled pages (the engine's per-period pool leaf; row N-1 may be a
    trash row — it is simply never addressed because page skipping cuts
    at ``lengths``); tables: [B,P] int32 page ids per row; lengths: [B]
    valid context length (0 marks an inactive row, whose output is
    zeros and whose pages are never streamed).

    Unlike ``decode_attention`` — whose sequential kv-block grid this
    extends — the kv operand is indexed THROUGH the page table via a
    scalar-prefetched index map (``PrefetchScalarGridSpec``), so the
    bytes moved per row scale with ``ceil(length/page)`` pages instead
    of the dense ``B * S`` cache slab. Returns [B,H,hd].
    """
    B, H, hd = q.shape
    kvH, page = k_pool.shape[1], k_pool.shape[2]
    P = tables.shape[1]
    assert H % kvH == 0
    G = H // kvH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qf = q.reshape(B * H, 1, hd)

    def q_map(bh, ip, tbl, lens):
        return (bh, 0, 0)

    def kv_map(bh, ip, tbl, lens):
        b = bh // H
        h = bh % H
        return (tbl[b, ip], h // G, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * H, P),
        in_specs=[
            pl.BlockSpec((1, 1, hd), q_map),
            pl.BlockSpec((1, 1, page, hd), kv_map),
            pl.BlockSpec((1, 1, page, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_ragged_kernel, scale=scale, page=page, heads=H),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, 1, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), qf, k_pool,
      v_pool)
    return out.reshape(B, H, hd)

"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
the per-kernel tests assert against)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_ref(q, k, v, causal: bool = True,
              scale: Optional[float] = None):
    """q: [B,H,S,hd]; k/v: [B,kvH,S,hd] -> [B,H,S,hd] (fp32 math)."""
    B, H, S, hd = q.shape
    kvH = k.shape[1]
    G = H // kvH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, kvH, G, S, hd).astype(jnp.float32)
    s = jnp.einsum("bkgsh,bkth->bkgst", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bkth->bkgsh", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, hd).astype(q.dtype)


def decode_ref(q, k_cache, v_cache, lengths,
               scale: Optional[float] = None):
    """q: [B,H,hd]; caches: [B,kvH,S,hd]; lengths: [B] -> [B,H,hd]."""
    B, H, hd = q.shape
    kvH, S = k_cache.shape[1], k_cache.shape[2]
    G = H // kvH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, kvH, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bkth->bkgt", qg,
                   k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None, :] < lengths[:, None]      # [B,S]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,bkth->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def rwkv6_ref(r, k, v, lw, u, S0=None):
    """Sequential WKV6 recurrence (the definitional oracle).

    r/k/v/lw: [B,S,H,hd] (lw = clamped log decay, fp32); u: [H,hd];
    S0: [B,H,hd,hd]. Returns (y [B,S,H,hd] fp32, S_out)."""
    B, S, H, hd = r.shape
    if S0 is None:
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(lw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(Sst, xs):
        r_t, k_t, v_t, w_t = xs                       # [B,H,hd]
        kv = k_t[..., :, None] * v_t[..., None, :]    # [B,H,hd,hd]
        y = jnp.einsum("bhe,bhef->bhf", r_t,
                       Sst + uf[None, :, :, None] * kv)
        S_new = w_t[..., None] * Sst + kv
        return S_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, w))
    S_out, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1), S_out


def mamba_ref(x, delta, Bm, Cm, A_log, D, h0=None):
    """Sequential selective scan oracle.

    x/delta: [B,S,di]; Bm/Cm: [B,S,ds]; A_log: [di,ds] (A = -exp(A_log));
    D: [di]. Returns (y [B,S,di] fp32, h_out [B,di,ds])."""
    B, S, di = x.shape
    ds = A_log.shape[1]
    A = -jnp.exp(A_log.astype(jnp.float32))
    if h0 is None:
        h0 = jnp.zeros((B, di, ds), jnp.float32)
    xf = x.astype(jnp.float32)
    df = delta.astype(jnp.float32)

    def step(h, xs):
        x_t, d_t, B_t, C_t = xs
        a = jnp.exp(d_t[..., None] * A[None])          # [B,di,ds]
        b = (d_t * x_t)[..., None] * B_t[:, None, :]
        h = a * h + b
        y = jnp.sum(h * C_t[:, None, :], axis=-1) + D[None] * x_t
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0)
               for t in (xf, df, Bm.astype(jnp.float32),
                         Cm.astype(jnp.float32)))
    h_out, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_out
